#!/usr/bin/env python
"""Build the API reference for ``repro.core`` + ``repro.dist`` +
``repro.analysis`` and verify cross-references.

Two generator paths, one contract:

* **pdoc** (preferred; the ``docs`` CI job installs it) — renders the
  HTML site into ``docs/api/``.
* **stdlib fallback** — when pdoc is absent (the pinned dev environment
  ships without it), an ``inspect``-based generator renders Markdown
  pages into ``docs/api/``, one per module: module docstring, public
  classes with signatures, public methods, functions. Same inputs, same
  structure, no extra dependency.

Either way the build **fails (exit 1) on broken cross-references**: every
``:class:`` / ``:meth:`` / ``:func:`` / ``:attr:`` / ``:data:`` role in
every docstring of the documented packages must resolve to a real object
(relative to the defining module, the documented packages, or builtins).
A docs page that points at a renamed class is worse than no page — this
is the check the ``docs`` CI job exists to run.

    PYTHONPATH=src python docs/build.py [--out docs/api] [--check-only]
"""
from __future__ import annotations

import argparse
import importlib
import inspect
import pathlib
import pkgutil
import re
import sys
from typing import Any, Iterator

PACKAGES = ("repro.core", "repro.dist", "repro.analysis")

_ROLE_RE = re.compile(r":(?:class|meth|func|attr|data|obj):`([^`]+)`")


# ---------------------------------------------------------------------------
# cross-reference checking
# ---------------------------------------------------------------------------


def iter_modules() -> Iterator[Any]:
    for pkg_name in PACKAGES:
        pkg = importlib.import_module(pkg_name)
        yield pkg
        for info in pkgutil.iter_modules(pkg.__path__, prefix=pkg_name + "."):
            yield importlib.import_module(info.name)


def _iter_docstrings(mod: Any) -> Iterator[tuple[str, str, list]]:
    """(owner-label, docstring, extra-contexts) for the module, its
    classes, their methods and its functions — everything the generated
    pages will show. Extra contexts make class-relative roles (a bare
    ``:meth:`cancel```) resolvable the way Sphinx would."""
    local_classes = [
        c
        for c in vars(mod).values()
        if inspect.isclass(c) and c.__module__ == mod.__name__
    ]
    if mod.__doc__:
        yield mod.__name__, mod.__doc__, local_classes
    for cname, cls in vars(mod).items():
        if cname.startswith("_") or not inspect.isclass(cls):
            continue
        if cls.__module__ != mod.__name__:
            continue  # re-export; documented at its definition site
        if cls.__doc__:
            yield f"{mod.__name__}.{cname}", cls.__doc__, [cls, *local_classes]
        for mname, meth in vars(cls).items():
            if mname.startswith("_") and mname not in ("__init__",):
                continue
            doc = inspect.getdoc(meth) if callable(meth) else None
            if doc:
                yield f"{mod.__name__}.{cname}.{mname}", doc, [cls, *local_classes]
    for fname, fn in vars(mod).items():
        if fname.startswith("_") or not inspect.isfunction(fn):
            continue
        if fn.__module__ == mod.__name__ and fn.__doc__:
            yield f"{mod.__name__}.{fname}", fn.__doc__, local_classes


def _resolve(ref: str, mod: Any, extra_contexts: list = ()) -> bool:
    """Can ``ref`` (role target, possibly ``~``-prefixed and dotted, or
    the explicit-title form ``Text <target>``) be resolved to a real
    object?"""
    titled = re.fullmatch(r".*<(.+)>", ref, flags=re.DOTALL)
    if titled:
        ref = titled.group(1)
    name = ref.lstrip("~")
    contexts: list[Any] = [mod, *extra_contexts]
    for pkg_name in PACKAGES + ("repro",):
        try:
            contexts.append(importlib.import_module(pkg_name))
        except ImportError:  # pragma: no cover - packages exist by construction
            pass
    parts = name.split(".")
    # absolute import path (repro.dist.shm_arena.ShmArena)
    for split in range(len(parts), 0, -1):
        mod_path, attrs = ".".join(parts[:split]), parts[split:]
        try:
            obj: Any = importlib.import_module(mod_path)
        except ImportError:
            continue
        try:
            for a in attrs:
                obj = getattr(obj, a)
            return True
        except AttributeError:
            continue
    # relative to a known namespace (Future, Future.cancel, np.ndarray…)
    for ctx in contexts:
        obj = ctx
        try:
            for a in parts:
                obj = getattr(obj, a)
            return True
        except AttributeError:
            continue
    return hasattr(__builtins__, parts[0]) or parts[0] in dir(__builtins__)


def check_cross_references() -> list[str]:
    """Every docstring role target must resolve. Returns failure lines."""
    failures: list[str] = []
    checked = 0
    for mod in iter_modules():
        for owner, doc, extra in _iter_docstrings(mod):
            for match in _ROLE_RE.finditer(doc):
                checked += 1
                if not _resolve(match.group(1), mod, extra):
                    failures.append(f"{owner}: unresolvable reference {match.group(0)}")
    print(f"cross-reference check: {checked} refs in {len(list(iter_modules()))} modules")
    return failures


# ---------------------------------------------------------------------------
# generators
# ---------------------------------------------------------------------------


def build_with_pdoc(out: pathlib.Path) -> None:
    import pdoc

    pdoc.pdoc(*PACKAGES, output_directory=out)
    print(f"pdoc site written to {out}")


def _signature(obj: Any) -> str:
    try:
        return str(inspect.signature(obj))
    except (ValueError, TypeError):
        return "(...)"


def _md_escape_doc(doc: str) -> str:
    """Docstrings are reST-flavored; fence doctest blocks so Markdown
    renderers keep them verbatim."""
    out: list[str] = []
    in_code = False
    for line in doc.splitlines():
        is_code = line.lstrip().startswith((">>>", "...")) or (
            in_code and line.strip() and line.startswith("    ")
        )
        if is_code and not in_code:
            out.append("```python")
            in_code = True
        elif not is_code and in_code and not line.strip():
            out.append("```")
            in_code = False
        out.append(line)
    if in_code:
        out.append("```")
    return "\n".join(out)


def build_fallback(out: pathlib.Path) -> None:
    """Markdown API reference with stdlib ``inspect`` only."""
    out.mkdir(parents=True, exist_ok=True)
    index = ["# API reference", "", "Generated by `docs/build.py` (stdlib fallback).", ""]
    for mod in iter_modules():
        page = out / (mod.__name__ + ".md")
        lines = [f"# `{mod.__name__}`", ""]
        if mod.__doc__:
            lines += [_md_escape_doc(inspect.cleandoc(mod.__doc__)), ""]
        for cname, cls in sorted(vars(mod).items()):
            if cname.startswith("_") or not inspect.isclass(cls):
                continue
            if cls.__module__ != mod.__name__:
                continue
            lines += [f"## class `{cname}{_signature(cls)}`", ""]
            if cls.__doc__:
                lines += [_md_escape_doc(inspect.cleandoc(cls.__doc__)), ""]
            for mname, meth in sorted(vars(cls).items()):
                if mname.startswith("_") or not callable(meth):
                    continue
                doc = inspect.getdoc(meth)
                lines += [f"### `{cname}.{mname}{_signature(meth)}`", ""]
                if doc:
                    lines += [_md_escape_doc(doc), ""]
        for fname, fn in sorted(vars(mod).items()):
            if fname.startswith("_") or not inspect.isfunction(fn):
                continue
            if fn.__module__ != mod.__name__:
                continue
            lines += [f"## `{fname}{_signature(fn)}`", ""]
            if fn.__doc__:
                lines += [_md_escape_doc(inspect.cleandoc(fn.__doc__)), ""]
        page.write_text("\n".join(lines))
        summary = (inspect.cleandoc(mod.__doc__).splitlines()[0] if mod.__doc__ else "")
        index.append(f"- [`{mod.__name__}`]({page.name}) — {summary}")
    (out / "index.md").write_text("\n".join(index) + "\n")
    print(f"markdown API reference written to {out}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent / "api"))
    ap.add_argument(
        "--check-only", action="store_true", help="only verify cross-references"
    )
    args = ap.parse_args()

    failures = check_cross_references()
    if failures:
        print("\nBROKEN CROSS-REFERENCES:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("all cross-references resolve")
    if args.check_only:
        return 0

    out = pathlib.Path(args.out)
    try:
        import pdoc  # noqa: F401

        build_with_pdoc(out)
    except ImportError:
        build_fallback(out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
