"""Paper benchmark reproduction (Figs. 1-2): fib task graphs, wall + CPU time.

The paper compares its pool against Taskflow on recursive-Fibonacci task
graphs. Taskflow (C++) is unavailable, so the comparison set is the designs
the paper positions itself against (see core/baseline.py):

  ws-fast      the paper's pool, FastDeque (GIL-atomic Chase-Lev analogue)
  ws-chaselev  the paper's pool, faithful Chase-Lev ring-buffer port
  naive        single locked global queue (pre-work-stealing design)
  stdlib       concurrent.futures.ThreadPoolExecutor driving the same graph
  serial       topological execution on one thread (zero-overhead floor)

With a single-core container wall≈CPU; the discriminating figure is
scheduling overhead per task (us/task over the serial floor).
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from typing import Callable

from repro.core import (
    ChaseLevDeque,
    NaiveThreadPool,
    SerialExecutor,
    TaskGraph,
    ThreadPool,
)

NUM_THREADS = 4  # fixed worker count for comparability across executors


def build_fib_graph(g: TaskGraph, n: int, results: dict, key: str = "r"):
    """Full recursion DAG of fib(n) without memoization (paper §3)."""
    if n < 2:
        return g.add(lambda k=key, v=n: results.__setitem__(k, v))
    left = build_fib_graph(g, n - 1, results, key + "l")
    right = build_fib_graph(g, n - 2, results, key + "r")
    join = g.add(lambda k=key: results.__setitem__(k, results[k + "l"] + results[k + "r"]))
    return join.succeed(left, right)


def build_wide_graph(g: TaskGraph, width: int, results: list):
    """Fan-out/fan-in: one root, `width` independent tasks, one join."""
    root = g.add(lambda: None)
    mids = []
    for i in range(width):
        t = g.add(lambda i=i: results.append(i))
        t.succeed(root)
        mids.append(t)
    return g.add(lambda: None).succeed(*mids)


def build_chain_graph(g: TaskGraph, length: int, acc: list):
    return g.chain([lambda: acc.append(1)] * length)


def build_wavefront_graph(g: TaskGraph, n: int, cells: dict):
    """n×n wavefront: cell (i,j) depends on (i-1,j) and (i,j-1) — the
    canonical task-graph benchmark from the Taskflow suite."""
    tasks = {}
    for i in range(n):
        for j in range(n):
            t = g.add(lambda i=i, j=j: cells.__setitem__((i, j), 1))
            deps = []
            if i > 0:
                deps.append(tasks[(i - 1, j)])
            if j > 0:
                deps.append(tasks[(i, j - 1)])
            if deps:
                t.succeed(*deps)
            tasks[(i, j)] = t
    return tasks


class StdlibExecutor:
    """Runs a Task graph on concurrent.futures.ThreadPoolExecutor — the
    stdlib incumbent, with successor dispatch in done-callbacks."""

    def __init__(self, num_threads: int) -> None:
        self._ex = concurrent.futures.ThreadPoolExecutor(max_workers=num_threads)

    def run(self, graph) -> None:
        from repro.core import iter_graph

        tasks = iter_graph(list(graph))
        for t in tasks:
            t.reset()
        done = threading.Event()
        remaining = [len(tasks)]
        lock = threading.Lock()

        def execute(task):
            task.run()
            for s in task.successors:
                if s.decrement():
                    self._ex.submit(execute, s)
            with lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()

        for t in tasks:
            if t.num_predecessors == 0:
                self._ex.submit(execute, t)
        done.wait()

    def close(self) -> None:
        self._ex.shutdown()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


EXECUTORS: dict[str, Callable[[], object]] = {
    "ws-fast": lambda: ThreadPool(NUM_THREADS),
    "ws-chaselev": lambda: ThreadPool(NUM_THREADS, deque_cls=ChaseLevDeque),
    "naive": lambda: NaiveThreadPool(NUM_THREADS),
    "stdlib": lambda: StdlibExecutor(NUM_THREADS),
    "serial": lambda: SerialExecutor(),
}


def _time_graph(make_executor, build, repeats: int = 3) -> tuple[float, float, int]:
    """Best-of-N wall and CPU seconds to run a freshly built graph."""
    best_wall, best_cpu, ntasks = float("inf"), float("inf"), 0
    with make_executor() as ex:
        for _ in range(repeats):
            g = TaskGraph()
            build(g)
            ntasks = len(g)
            w0, c0 = time.perf_counter(), time.process_time()
            ex.run(g)
            w1, c1 = time.perf_counter(), time.process_time()
            best_wall = min(best_wall, w1 - w0)
            best_cpu = min(best_cpu, c1 - c0)
    return best_wall, best_cpu, ntasks


def bench_fib(ns=(10, 15, 18, 20), repeats: int = 3) -> list[dict]:
    """Paper Figs. 1-2: wall and CPU time for fib(n) task graphs."""
    rows = []
    for n in ns:
        for name, make in EXECUTORS.items():
            results: dict = {}
            wall, cpu, ntasks = _time_graph(
                make, lambda g: build_fib_graph(g, n, results), repeats
            )
            rows.append(
                dict(
                    bench=f"fib({n})",
                    executor=name,
                    tasks=ntasks,
                    wall_ms=wall * 1e3,
                    cpu_ms=cpu * 1e3,
                    us_per_task=wall * 1e6 / ntasks,
                )
            )
    return rows


def bench_shapes(repeats: int = 3) -> list[dict]:
    """Chain / wide / wavefront shapes (Taskflow benchmark suite shapes)."""
    shapes = {
        "chain(4096)": lambda g: build_chain_graph(g, 4096, []),
        "wide(4096)": lambda g: build_wide_graph(g, 4096, []),
        "wavefront(64x64)": lambda g: build_wavefront_graph(g, 64, {}),
    }
    rows = []
    for shape, build in shapes.items():
        for name, make in EXECUTORS.items():
            wall, cpu, ntasks = _time_graph(make, build, repeats)
            rows.append(
                dict(
                    bench=shape,
                    executor=name,
                    tasks=ntasks,
                    wall_ms=wall * 1e3,
                    cpu_ms=cpu * 1e3,
                    us_per_task=wall * 1e6 / ntasks,
                )
            )
    return rows


def bench_gil_releasing_overlap(repeats: int = 3) -> list[dict]:
    """What the pool is *for* on a TPU host: overlapping GIL-releasing work
    (device steps, IO). Tasks sleep 1ms (stands in for a device call); an
    ideal 4-thread pool gets 4x overlap even on one core."""
    rows = []
    N, DUR = 64, 0.001
    for name, make in EXECUTORS.items():
        def build(g):
            for _ in range(N):
                g.add(lambda: time.sleep(DUR))

        wall, cpu, ntasks = _time_graph(make, build, repeats)
        rows.append(
            dict(
                bench=f"overlap({N}x{DUR * 1e3:.0f}ms)",
                executor=name,
                tasks=ntasks,
                wall_ms=wall * 1e3,
                cpu_ms=cpu * 1e3,
                us_per_task=wall * 1e6 / ntasks,
                speedup_vs_serial=(N * DUR) / wall,
            )
        )
    return rows


def run_all(fast: bool = False) -> list[dict]:
    ns = (10, 15) if fast else (10, 15, 18, 20)
    repeats = 2 if fast else 3
    rows = []
    rows += bench_fib(ns=ns, repeats=repeats)
    rows += bench_shapes(repeats=repeats)
    rows += bench_gil_releasing_overlap(repeats=repeats)
    return rows
