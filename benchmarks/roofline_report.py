"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from artifacts.

    PYTHONPATH=src python -m benchmarks.roofline_report [--update-experiments]

Reads benchmarks/artifacts/*.json (written by repro.launch.dryrun) and
prints markdown tables; with --update-experiments it rewrites the marked
sections of EXPERIMENTS.md in place.
"""
from __future__ import annotations

import argparse
import json
import pathlib

ART = pathlib.Path(__file__).parent / "artifacts"
EXP = pathlib.Path(__file__).parent.parent / "EXPERIMENTS.md"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load() -> list[dict]:
    rows = []
    for f in sorted(ART.glob("*.json")):
        try:
            rows.append(json.loads(f.read_text()))
        except Exception:
            pass
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]), r["mesh"]))
    return rows


def gib(x) -> str:
    return f"{x / 2**30:.2f}"


def dryrun_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | ok | compile_s | args GiB/dev | temp GiB/dev "
        "| HLO GFLOP/dev | coll MiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | **FAIL** | - | - | - | - | - |"
            )
            continue
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']:.1f} "
            f"| {gib(m.get('argument_size_in_bytes', 0))} "
            f"| {gib(m.get('temp_size_in_bytes', 0))} "
            f"| {r['hlo_flops_per_device'] / 1e9:.1f} "
            f"| {r['collectives']['total_bytes'] / 2**20:.1f} |"
        )
    return "\n".join(out)


def roofline_table(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | compute_s | memory_s | collective_s | dominant "
        "| bound_ms | MODEL_FLOPS/chip | useful_ratio |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.3e} | {rf['memory_s']:.3e} | {rf['collective_s']:.3e} "
            f"| **{rf['dominant']}** | {rf['dominant_s'] * 1e3:.1f} "
            f"| {rf['model_flops_per_chip']:.2e} | {rf['useful_flops_ratio']:.2f} |"
        )
    return "\n".join(out)


def collective_breakdown(rows: list[dict]) -> str:
    out = [
        "| arch | shape | mesh | all-gather | all-reduce | reduce-scatter | all-to-all | permute |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if not r.get("ok"):
            continue
        bk = r["collectives"]["bytes_by_kind"]
        mb = lambda k: f"{bk.get(k, 0) / 2**20:.0f}"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {mb('all-gather')} "
            f"| {mb('all-reduce')} | {mb('reduce-scatter')} | {mb('all-to-all')} "
            f"| {mb('collective-permute')} |"
        )
    return "\n".join(out)


def replace_section(text: str, marker: str, body: str) -> str:
    begin, end = f"<!-- {marker}:begin -->", f"<!-- {marker}:end -->"
    if begin not in text:
        return text + f"\n{begin}\n{body}\n{end}\n"
    pre = text.split(begin)[0]
    post = text.split(end)[1] if end in text else ""
    return pre + begin + "\n" + body + "\n" + end + post


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-experiments", action="store_true")
    args = ap.parse_args()
    rows = load()
    n_ok = sum(1 for r in rows if r.get("ok"))
    summary = f"{n_ok}/{len(rows)} cells compiled OK."
    dt = dryrun_table(rows)
    rt = roofline_table(rows)
    cb = collective_breakdown(rows)
    print(summary)
    print("\n## Dry-run\n" + dt)
    print("\n## Roofline\n" + rt)
    print("\n## Collective breakdown\n" + cb)
    if args.update_experiments and EXP.exists():
        text = EXP.read_text()
        text = replace_section(text, "dryrun-table", summary + "\n\n" + dt)
        text = replace_section(text, "roofline-table", rt)
        text = replace_section(text, "collective-table", cb)
        EXP.write_text(text)
        print(f"\nupdated {EXP}")


if __name__ == "__main__":
    main()
