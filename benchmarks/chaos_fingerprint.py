"""Seeded socket chaos fingerprint: the CI artifact for §16 determinism.

Runs the same seeded chaos battery as ``tests/dist/test_socket_chaos.py``
— a fan-out of idempotent retryable tasks on a live :class:`SocketPool`
under a :class:`FaultInjector` mixing injected failures, delays and
**real worker kills** — twice on fresh pools, and verifies the injected
schedules are **byte-identical** before writing the digest.

The injector keys every decision on ``(seed, task, occurrence)``, so two
runs can only diverge if the *pool* makes occurrence counts
interleaving-dependent (e.g. a kill swallowed because the monitor
respawned the worker before the dispatcher noticed). The fingerprint is
therefore a transport-determinism canary, uploaded per CI run so a
diverging schedule is diffable across commits, not just a red X.

Output JSON: ``{seed, tasks, fingerprint, counts, schedule, stats}``
where ``fingerprint`` is a blake2b digest of the canonical schedule
serialization and ``counts`` tallies faults by kind. Exit 1 when the two
runs disagree, when any fault kind never fired (a battery that injected
nothing certifies nothing), or when either run returns wrong values.

    PYTHONPATH=src python benchmarks/chaos_fingerprint.py \
        --seed 2026 --out benchmarks/artifacts/chaos_fingerprint.json
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

from repro.core import ChaosError, Executor, FaultInjector, RetryPolicy, TaskGraph
from repro.dist import SocketPool, WorkerDiedError

_POLICY = RetryPolicy(
    max_attempts=10, backoff=0.0, retry_on=(ChaosError, WorkerDiedError)
)
_CHAOS = dict(fail_rate=0.2, delay_rate=0.08, kill_rate=0.1, delay_s=0.001)


def run_battery(seed: int, ntasks: int) -> tuple[list, list, dict]:
    """One full battery on a fresh pool -> (schedule, values, stats)."""
    with SocketPool(2, name="ci-chaos-sock") as pool:
        inj = FaultInjector(
            seed=seed, match=lambda t: (t.name or "").startswith("k:"), **_CHAOS
        )
        g = TaskGraph("sock-chaos")
        tasks = [
            g.add(lambda i=i: i * i, name=f"k:{i}", retry=_POLICY, idempotent=True)
            for i in range(ntasks)
        ]
        sink = g.gather(tasks, name="collect")
        with inj.on(pool):
            Executor(pool=pool).run(g).result(180)
        return inj.schedule(), list(sink.result), pool.stats()


def fingerprint(schedule: list) -> str:
    """Canonical digest of an injected-fault schedule."""
    blob = json.dumps(schedule, separators=(",", ":")).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=2026)
    ap.add_argument("--tasks", type=int, default=24)
    ap.add_argument(
        "--out",
        default=str(
            pathlib.Path(__file__).parent / "artifacts" / "chaos_fingerprint.json"
        ),
    )
    args = ap.parse_args()

    failures: list[str] = []
    expected = [i * i for i in range(args.tasks)]
    runs = [run_battery(args.seed, args.tasks) for _ in range(2)]
    for which, (_sched, values, _stats) in zip("ab", runs):
        if values != expected:
            failures.append(f"run {which} returned wrong values")

    (sched_a, _va, stats_a), (sched_b, _vb, _sb) = runs
    blob_a = json.dumps(sched_a, separators=(",", ":")).encode()
    blob_b = json.dumps(sched_b, separators=(",", ":")).encode()
    if blob_a != blob_b:
        failures.append(
            f"schedules diverged: {fingerprint(sched_a)} != {fingerprint(sched_b)}"
        )

    counts = {"fail": 0, "delay": 0, "kill": 0}
    for _name, _occ, kind in sched_a:
        counts[kind] += 1
    for kind, n in counts.items():
        if n == 0:
            failures.append(f"no {kind} fault ever fired — nothing certified")

    payload = {
        "seed": args.seed,
        "tasks": args.tasks,
        "fingerprint": fingerprint(sched_a),
        "counts": counts,
        "schedule": sched_a,
        "stats": {
            k: v
            for k, v in stats_a.items()
            if isinstance(v, (int, float, str, bool))
        },
    }
    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=1))

    print(f"seed {args.seed}: {len(sched_a)} injected faults {counts}")
    print(f"fingerprint {payload['fingerprint']} (identical across both runs)")
    print(f"wrote {out}")
    if failures:
        print("\nFAIL: " + "; ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
