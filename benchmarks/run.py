"""Benchmark harness. One section per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable tables).

Sections:
  fig1_fig2   paper Figs. 1-2: fib task-graph wall/CPU time across executors
  shapes      chain/wide/wavefront task graphs (Taskflow suite shapes)
  overlap     GIL-releasing overlap (the TPU-host regime)
  pipeline    task-graph-derived 1F1B vs GPipe schedule quality
  roofline    summarises dry-run artifacts if present (benchmarks/artifacts/)

Env:
  BENCH_FAST=1   smaller fib sizes / fewer repeats (CI mode)
"""
from __future__ import annotations

import json
import os
import pathlib


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def section_paper(fast: bool) -> None:
    from benchmarks.paper_bench import run_all

    rows = run_all(fast=fast)
    print("\n# paper Figs.1-2 (fib task graphs) + graph shapes + overlap")
    print(f"{'bench':<20}{'executor':<13}{'tasks':>7}{'wall_ms':>10}{'cpu_ms':>10}{'us/task':>9}")
    for r in rows:
        extra = f"  speedup={r['speedup_vs_serial']:.1f}x" if "speedup_vs_serial" in r else ""
        print(
            f"{r['bench']:<20}{r['executor']:<13}{r['tasks']:>7}"
            f"{r['wall_ms']:>10.2f}{r['cpu_ms']:>10.2f}{r['us_per_task']:>9.2f}{extra}"
        )
    print("\n# CSV")
    for r in rows:
        _emit(
            f"{r['bench']}/{r['executor']}",
            r["us_per_task"],
            f"wall_ms={r['wall_ms']:.2f};cpu_ms={r['cpu_ms']:.2f};tasks={r['tasks']}",
        )


def section_pipeline_schedules() -> None:
    from repro.core import (
        gpipe_schedule,
        peak_activation_buffers,
        pipeline_schedule,
        pipeline_task_graph,
    )

    print("\n# task-graph-derived pipeline schedules (1F1B from the paper's policy)")
    print(
        f"{'S':>3}{'M':>5}{'1f1b_ticks':>12}{'gpipe_ticks':>12}"
        f"{'1f1b_peak':>11}{'gpipe_peak':>11}{'bubble':>9}"
    )
    for S, M in [(2, 8), (4, 16), (8, 32), (16, 64)]:
        t1 = pipeline_task_graph(S, M)
        r1 = pipeline_schedule(S, M)
        p1 = max(peak_activation_buffers(t1, r1, S))
        t2 = pipeline_task_graph(S, M, memory_limited=False)
        r2 = gpipe_schedule(S, M)
        p2 = max(peak_activation_buffers(t2, r2, S))
        bubble = r1.makespan / (2 * M) - 1
        print(f"{S:>3}{M:>5}{r1.makespan:>12.0f}{r2.makespan:>12.0f}{p1:>11}{p2:>11}{bubble:>9.1%}")
        _emit(
            f"pipeline/S{S}xM{M}",
            r1.makespan,
            f"gpipe_ticks={r2.makespan:.0f};peak_1f1b={p1};peak_gpipe={p2};bubble={bubble:.3f}",
        )


def section_roofline() -> None:
    art = pathlib.Path(__file__).parent / "artifacts"
    files = sorted(art.glob("*.json")) if art.exists() else []
    if not files:
        print("\n# roofline: no dry-run artifacts yet (run launch/dryrun.py)")
        return
    print("\n# roofline terms from dry-run artifacts (see EXPERIMENTS.md §Roofline)")
    for f in files:
        try:
            d = json.loads(f.read_text())
        except Exception:
            continue
        r = d.get("roofline", {})
        if not r:
            continue
        _emit(
            f"roofline/{d.get('arch')}/{d.get('shape')}/{d.get('mesh')}",
            r.get("dominant_s", 0.0) * 1e6,
            f"compute_s={r.get('compute_s', 0):.3e};memory_s={r.get('memory_s', 0):.3e};"
            f"collective_s={r.get('collective_s', 0):.3e};dominant={r.get('dominant', '?')}",
        )


def main() -> None:
    fast = os.environ.get("BENCH_FAST", "0") == "1"
    section_paper(fast)
    section_pipeline_schedules()
    section_roofline()


if __name__ == "__main__":
    main()
