"""Serving benchmark: overload Poisson trace through three servers.

Replays one arrival trace (Poisson interarrivals, per-request token budgets,
arrival rate deliberately beyond the service rate — an *overload* trace)
through three servers over the same model and params:

* **static**  — the classic batch server (what examples/serve_lm.py used to
  be): wait until ``batch`` requests have arrived, prefill them together,
  decode the whole batch in lockstep until the *longest* member finishes,
  repeat. Slots of finished sequences burn compute; late arrivals wait for
  the next batch to form.
* **continuous-flat** — ``repro.serve.ServeEngine`` with the whole-slot
  ``SlotKVCache`` (one ``max_len`` reservation per sequence, unbounded
  admit queue): iteration-level batching on the work-stealing pool.
* **continuous-paged** — the same engine with the §13 paged KV pool plus
  admission control: a bounded admit queue (``max_waiting = 2×slots``,
  ``QueueFull`` backpressure — the client retries, modelling a closed
  loop) and per-request deadlines grading the §9 prefill bands.

Every continuous request is **streamed**, so the report carries end-to-end
latency percentiles: TTFT (submit → first token) and inter-token latency
(gaps between ``RequestHandle.token_times``), p50/p90/p99 in ms. Static
has no per-request delivery times — it reports wall/throughput only.

All servers count only each request's own budgeted tokens, so tokens/s
isolates scheduling quality. A verification pass checks both engines'
outputs for every request are bit-identical (token-for-token) to
sequential single-request decode; ``max_len`` is rounded up to a page
multiple so all four programs attend over equally-sized caches (in bf16,
reduction tiling over differently-padded widths can flip greedy argmax at
a near-tie, which is numerics, not scheduling).

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch tinyllama-1.1b]
        [--quick] [--requests 32] [--slots 8]
        [--out benchmarks/artifacts/BENCH_serve.json]

``--quick`` presets CI-sized dimensions (the committed gate baseline
``benchmarks/BENCH_serve_quick.json`` is a ``--quick`` run; the serve gate
in ``check_graph_regression.py`` compares quick-vs-quick). Runs on CPU
with the arch's reduced config; emits a JSON report.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.models import build_model
from repro.models.lm import extend_caches
from repro.serve import QueueFull, ServeEngine


def make_trace(rng, n, prompt_len, min_new, max_new, mean_gap_s):
    """(prompts, budgets, arrival_times) — Poisson arrivals, varied budgets."""
    prompts = [rng.integers(0, 2**31 - 1, size=prompt_len) for _ in range(n)]
    budgets = [int(rng.integers(min_new, max_new + 1)) for _ in range(n)]
    gaps = rng.exponential(mean_gap_s, size=n)
    arrivals = np.cumsum(gaps)
    return prompts, budgets, arrivals


def clip_vocab(prompts, vocab):
    return [np.asarray(p % vocab, np.int32) for p in prompts]


def _pcts(xs_s: list) -> dict:
    """p50/p90/p99/max of a list of seconds, reported in ms."""
    a = np.asarray(xs_s, np.float64) * 1e3
    return {
        "p50": round(float(np.percentile(a, 50)), 2),
        "p90": round(float(np.percentile(a, 90)), 2),
        "p99": round(float(np.percentile(a, 99)), 2),
        "max": round(float(a.max()), 2),
    }


def latency_summary(handles) -> dict:
    """TTFT + inter-token latency percentiles from streamed handles."""
    ttfts = [h.ttft for h in handles]
    assert all(t is not None for t in ttfts), "a request never delivered a token"
    itls = []
    for h in handles:
        ts = h.token_times
        itls.extend(b - a for a, b in zip(ts, ts[1:]))
    out = {"ttft_ms": _pcts(ttfts)}
    if itls:
        out["itl_ms"] = _pcts(itls)
    return out


# ---------------------------------------------------------------------------
# static-batch baseline
# ---------------------------------------------------------------------------


class StaticBatchServer:
    """Batched prefill + lockstep decode until the longest member finishes."""

    def __init__(self, model, params, batch, prompt_len, max_new):
        self.model, self.params, self.batch = model, params, batch
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self.prompt_len, self.max_new = prompt_len, max_new

    def run_group(self, prompts, budgets):
        """Decode one full batch; returns per-request generated ids."""
        B = len(prompts)
        toks = jnp.asarray(np.stack(prompts))  # (B, S) — equal lengths
        logits, caches = self._prefill(self.params, {"tokens": toks})
        caches = extend_caches(caches, self.max_new)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [[int(tok[i, 0])] for i in range(B)]
        ticks = max(budgets)
        for i in range(ticks - 1):  # static: everyone decodes to the longest
            logits, caches = self._decode(
                self.params, tok, caches, jnp.asarray(self.prompt_len + i, jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            for b in range(B):
                if len(outs[b]) < budgets[b]:  # budget reached -> discard
                    outs[b].append(int(tok[b, 0]))
        jax.block_until_ready(tok)
        return outs

    def serve(self, prompts, budgets, arrivals, t0):
        """Replay the trace: form full batches in arrival order."""
        outs = [None] * len(prompts)
        for g0 in range(0, len(prompts), self.batch):
            idx = list(range(g0, min(g0 + self.batch, len(prompts))))
            # batch formation: wait for the last member to arrive
            wait = t0 + arrivals[idx[-1]] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            group = self.run_group([prompts[i] for i in idx], [budgets[i] for i in idx])
            for i, o in zip(idx, group):
                outs[i] = o
        return outs


# ---------------------------------------------------------------------------
# sequential single-request reference (bit-identity oracle)
# ---------------------------------------------------------------------------


def sequential_reference(model, params, prompts, budgets, width=None):
    """Decode each request alone, one token at a time.

    ``width``: KV capacity to provision (default: exactly prompt+budget).
    The bit-identity check passes the engine's ``max_len`` so both programs
    attend over equally-sized (identically masked) caches — in bf16, the
    reduction tiling over differently-padded cache widths can flip greedy
    argmax at a near-tie, which is numerics, not scheduling.
    """
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    outs = []
    for prompt, budget in zip(prompts, budgets):
        logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])})
        extra = (width - int(prompt.size)) if width is not None else budget
        caches = extend_caches(caches, extra)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [int(tok[0, 0])]
        for i in range(budget - 1):
            logits, caches = decode(
                params, tok, caches, jnp.asarray(prompt.size + i, jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# continuous engine client
# ---------------------------------------------------------------------------


def serve_continuous(engine, prompts, budgets, arrivals, t0, deadline=None):
    """Replay the trace; returns (handles, outputs, admit_retries).

    ``QueueFull`` backpressure is handled as a closed loop: the feeder
    retries the rejected submit after a short sleep — work is delayed at
    the client, never dropped.
    """
    handles = [None] * len(prompts)
    retries = 0

    def feeder():
        nonlocal retries
        for i, (p, n) in enumerate(zip(prompts, budgets)):
            wait = t0 + arrivals[i] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            while True:
                try:
                    handles[i] = engine.submit(p, n, deadline=deadline)
                    break
                except QueueFull:
                    retries += 1
                    time.sleep(0.002)

    th = threading.Thread(target=feeder)
    th.start()
    th.join()
    outs = [list(map(int, h.result(600))) for h in handles]
    return handles, outs, retries


def run_engine(model, params, args, layout, trace, max_len, buckets):
    """One timed replay through a fresh engine; returns (row, outputs)."""
    prompts, budgets, arrivals = trace
    kw = {}
    deadline = None
    if layout == "paged":
        kw.update(page_size=args.page_size, max_waiting=2 * args.slots)
        deadline = args.deadline_s
    engine = ServeEngine(
        model,
        params,
        max_slots=args.slots,
        max_len=max_len,
        kv_layout=layout,
        prefill_buckets=buckets,
        **kw,
    )
    engine.generate(prompts[: args.slots], 2)  # warmup compiles
    pre = engine.stats()
    t0 = time.perf_counter()
    handles, outs, retries = serve_continuous(
        engine, prompts, budgets, arrivals, t0, deadline=deadline
    )
    engine.drain(600)
    wall = time.perf_counter() - t0
    stats = engine.stats()
    engine.close()

    assert all(len(o) == b for o, b in zip(outs, budgets))
    total_tokens = sum(budgets)
    ticks = stats["ticks"] - pre["ticks"]
    row = {
        "server": f"continuous-{layout}",
        "wall_s": round(wall, 4),
        "tokens_per_s": round(total_tokens / wall, 2),
        "ticks": ticks,
        # occupancy over the timed replay only (warmup ticks excluded)
        "mean_occupancy": round(
            (
                stats["mean_occupancy"] * stats["ticks"]
                - pre["mean_occupancy"] * pre["ticks"]
            )
            / max(ticks, 1),
            3,
        ),
        "completed": stats["completed"] - pre["completed"],
        "preemptions": stats["preemptions"],
        "rejected": stats["rejected"],
        "deadline_misses": stats["deadline_misses"],
        "admit_retries": retries,
        "pool_steals": stats["pool"]["steals"],
        "kv": {
            "page_size": stats["kv"]["page_size"],
            "pages_total": stats["kv"]["pages_total"],
            # flat slots are one page each, so slot peak == page peak there
            "peak_pages_live": stats["kv"].get("peak_pages_live", stats["kv"]["peak_live"]),
            "fragmentation": stats["kv"]["fragmentation"],
        },
        **latency_summary(handles),
    }
    return row, outs


QUICK = dict(requests=24, slots=4, prompt_len=16, min_new=8, max_new=16, mean_gap_ms=2.0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--quick", action="store_true", help="CI-sized preset (see QUICK)")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mean-gap-ms", type=float, default=3.0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument(
        "--deadline-s",
        type=float,
        default=600.0,
        help="per-request TTFT deadline on the paged server (generous by "
        "default: exercises the §9 deadline bands without ever shedding "
        "work, so throughput stays comparable across servers)",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.quick:
        for k, v in QUICK.items():
            setattr(args, k, v)

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    prompts, budgets, arrivals = make_trace(
        rng, args.requests, args.prompt_len, args.min_new, args.max_new,
        args.mean_gap_ms / 1e3,
    )
    prompts = clip_vocab(prompts, cfg.vocab_size)
    trace = (prompts, budgets, arrivals)
    total_tokens = sum(budgets)
    # round up to a page multiple so flat slots, paged gathers and the
    # sequential reference all attend over the same cache width (bit-identity)
    need = args.prompt_len + args.max_new + 1
    max_len = -(-need // args.page_size) * args.page_size
    buckets = (args.prompt_len,) if ServeEngine.supports_prefill_buckets(cfg) else None

    # -- static baseline (warmup compiles, then timed replay) ---------------
    static = StaticBatchServer(model, params, args.slots, args.prompt_len, args.max_new)
    static.run_group(prompts[: args.slots], [2] * args.slots)  # warmup
    t0 = time.perf_counter()
    static_outs = static.serve(prompts, budgets, arrivals, t0)
    static_wall = time.perf_counter() - t0
    assert all(len(o) == b for o, b in zip(static_outs, budgets))

    # -- continuous engines (same warmup treatment, same trace) -------------
    flat_row, flat_outs = run_engine(model, params, args, "flat", trace, max_len, buckets)
    paged_row, paged_outs = run_engine(
        model, params, args, "paged", trace, max_len, buckets
    )

    identical = None
    if not args.no_verify:
        refs = sequential_reference(model, params, prompts, budgets, width=max_len)
        identical = all(r == c for r, c in zip(refs, flat_outs)) and all(
            r == c for r, c in zip(refs, paged_outs)
        )

    report = {
        "meta": {
            "arch": cfg.name,
            "quick": args.quick,
            "requests": args.requests,
            "slots": args.slots,
            "prompt_len": args.prompt_len,
            "max_len": max_len,
            "page_size": args.page_size,
            "budgets": {
                "min": args.min_new,
                "max": args.max_new,
                "total_tokens": total_tokens,
            },
            "mean_gap_ms": args.mean_gap_ms,
            "seed": args.seed,
        },
        "rows": [
            {
                "server": "static",
                "wall_s": round(static_wall, 4),
                "tokens_per_s": round(total_tokens / static_wall, 2),
            },
            flat_row,
            paged_row,
        ],
        "speedup_vs_static": round(static_wall / paged_row["wall_s"], 3),
        "paged_over_flat_tokens_per_s": round(
            paged_row["tokens_per_s"] / flat_row["tokens_per_s"], 3
        ),
        "outputs_match_sequential_decode": identical,
    }
    print(json.dumps(report, indent=2))
    if args.out:
        path = pathlib.Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report, indent=2))
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
