"""Serving benchmark: continuous batching vs static batching, Poisson trace.

Replays one arrival trace (Poisson interarrivals, per-request token budgets)
through two servers over the same model and params:

* **static**  — the classic batch server (what examples/serve_lm.py used to
  be): wait until ``batch`` requests have arrived, prefill them together,
  decode the whole batch in lockstep until the *longest* member finishes,
  repeat. Slots of finished sequences burn compute; late arrivals wait for
  the next batch to form.
* **continuous** — ``repro.serve.ServeEngine``: iteration-level batching on
  the work-stealing pool (low-priority prefill tasks, high-priority decode
  ticks, join/retire between ticks).

Both count only each request's own budgeted tokens, so the tokens/s ratio
isolates scheduling quality. A verification pass checks the engine's output
for every request is bit-identical (token-for-token) to sequential
single-request decode.

    PYTHONPATH=src python benchmarks/serve_bench.py [--arch tinyllama-1.1b]
        [--requests 24] [--slots 8] [--out benchmarks/artifacts/serve_bench.json]

Runs on CPU with the arch's reduced config in ~a minute; emits a JSON report.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.models import build_model
from repro.models.lm import extend_caches
from repro.serve import ServeEngine


def make_trace(rng, n, prompt_len, min_new, max_new, mean_gap_s):
    """(prompts, budgets, arrival_times) — Poisson arrivals, varied budgets."""
    prompts = [rng.integers(0, 2**31 - 1, size=prompt_len) for _ in range(n)]
    budgets = [int(rng.integers(min_new, max_new + 1)) for _ in range(n)]
    gaps = rng.exponential(mean_gap_s, size=n)
    arrivals = np.cumsum(gaps)
    return prompts, budgets, arrivals


def clip_vocab(prompts, vocab):
    return [np.asarray(p % vocab, np.int32) for p in prompts]


# ---------------------------------------------------------------------------
# static-batch baseline
# ---------------------------------------------------------------------------


class StaticBatchServer:
    """Batched prefill + lockstep decode until the longest member finishes."""

    def __init__(self, model, params, batch, prompt_len, max_new):
        self.model, self.params, self.batch = model, params, batch
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))
        self.prompt_len, self.max_new = prompt_len, max_new

    def run_group(self, prompts, budgets):
        """Decode one full batch; returns per-request generated ids."""
        B = len(prompts)
        toks = jnp.asarray(np.stack(prompts))  # (B, S) — equal lengths
        logits, caches = self._prefill(self.params, {"tokens": toks})
        caches = extend_caches(caches, self.max_new)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [[int(tok[i, 0])] for i in range(B)]
        ticks = max(budgets)
        for i in range(ticks - 1):  # static: everyone decodes to the longest
            logits, caches = self._decode(
                self.params, tok, caches, jnp.asarray(self.prompt_len + i, jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            for b in range(B):
                if len(outs[b]) < budgets[b]:  # budget reached -> discard
                    outs[b].append(int(tok[b, 0]))
        jax.block_until_ready(tok)
        return outs

    def serve(self, prompts, budgets, arrivals, t0):
        """Replay the trace: form full batches in arrival order."""
        outs = [None] * len(prompts)
        for g0 in range(0, len(prompts), self.batch):
            idx = list(range(g0, min(g0 + self.batch, len(prompts))))
            # batch formation: wait for the last member to arrive
            wait = t0 + arrivals[idx[-1]] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            group = self.run_group([prompts[i] for i in idx], [budgets[i] for i in idx])
            for i, o in zip(idx, group):
                outs[i] = o
        return outs


# ---------------------------------------------------------------------------
# sequential single-request reference (bit-identity oracle)
# ---------------------------------------------------------------------------


def sequential_reference(model, params, prompts, budgets, width=None):
    """Decode each request alone, one token at a time.

    ``width``: KV capacity to provision (default: exactly prompt+budget).
    The bit-identity check passes the engine's ``max_len`` so both programs
    attend over equally-sized (identically masked) caches — in bf16, the
    reduction tiling over differently-padded cache widths can flip greedy
    argmax at a near-tie, which is numerics, not scheduling.
    """
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(2,))
    outs = []
    for prompt, budget in zip(prompts, budgets):
        logits, caches = prefill(params, {"tokens": jnp.asarray(prompt[None, :])})
        extra = (width - int(prompt.size)) if width is not None else budget
        caches = extend_caches(caches, extra)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out = [int(tok[0, 0])]
        for i in range(budget - 1):
            logits, caches = decode(
                params, tok, caches, jnp.asarray(prompt.size + i, jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            out.append(int(tok[0, 0]))
        outs.append(out)
    return outs


# ---------------------------------------------------------------------------
# continuous engine client
# ---------------------------------------------------------------------------


def serve_continuous(engine, prompts, budgets, arrivals, t0):
    handles = [None] * len(prompts)

    def feeder():
        for i, (p, n) in enumerate(zip(prompts, budgets)):
            wait = t0 + arrivals[i] - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            handles[i] = engine.submit(p, n)

    th = threading.Thread(target=feeder)
    th.start()
    th.join()
    return [list(map(int, h.result(600))) for h in handles]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--min-new", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--mean-gap-ms", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-verify", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)
    prompts, budgets, arrivals = make_trace(
        rng, args.requests, args.prompt_len, args.min_new, args.max_new,
        args.mean_gap_ms / 1e3,
    )
    prompts = clip_vocab(prompts, cfg.vocab_size)
    total_tokens = sum(budgets)
    max_len = args.prompt_len + args.max_new + 1
    buckets = (args.prompt_len,) if ServeEngine.supports_prefill_buckets(cfg) else None

    # -- static baseline (warmup compiles, then timed replay) ---------------
    static = StaticBatchServer(model, params, args.slots, args.prompt_len, args.max_new)
    static.run_group(prompts[: args.slots], [2] * args.slots)  # warmup
    t0 = time.perf_counter()
    static_outs = static.serve(prompts, budgets, arrivals, t0)
    static_wall = time.perf_counter() - t0

    # -- continuous engine (same warmup treatment, same trace) --------------
    engine = ServeEngine(
        model, params, max_slots=args.slots, max_len=max_len, prefill_buckets=buckets
    )
    engine.generate(prompts[: args.slots], 2)  # warmup
    pre_stats = engine.stats()
    t0 = time.perf_counter()
    cont_outs = serve_continuous(engine, prompts, budgets, arrivals, t0)
    engine.drain(600)
    cont_wall = time.perf_counter() - t0
    stats = engine.stats()
    engine.close()

    assert all(len(o) == b for o, b in zip(static_outs, budgets))
    assert all(len(o) == b for o, b in zip(cont_outs, budgets))

    identical = None
    if not args.no_verify:
        refs = sequential_reference(model, params, prompts, budgets, width=max_len)
        identical = all(r == c for r, c in zip(refs, cont_outs))

    report = {
        "arch": cfg.name,
        "requests": args.requests,
        "slots": args.slots,
        "prompt_len": args.prompt_len,
        "budgets": {"min": args.min_new, "max": args.max_new, "total_tokens": total_tokens},
        "mean_gap_ms": args.mean_gap_ms,
        "static": {
            "wall_s": round(static_wall, 4),
            "tokens_per_s": round(total_tokens / static_wall, 2),
        },
        "continuous": {
            "wall_s": round(cont_wall, 4),
            "tokens_per_s": round(total_tokens / cont_wall, 2),
            "ticks": stats["ticks"] - pre_stats["ticks"],
            # occupancy over the timed replay only (warmup ticks excluded)
            "mean_occupancy": round(
                (
                    stats["mean_occupancy"] * stats["ticks"]
                    - pre_stats["mean_occupancy"] * pre_stats["ticks"]
                )
                / max(stats["ticks"] - pre_stats["ticks"], 1),
                3,
            ),
            "pool_steals": stats["pool"]["steals"],
        },
        "speedup": round(static_wall / cont_wall, 3),
        "outputs_match_sequential_decode": identical,
    }
    print(json.dumps(report, indent=2))
    if args.out:
        path = pathlib.Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(report, indent=2))
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
