"""Chaos benchmark: §14 fault tolerance under deterministic injection.

Runs one graph shape — a wide fan-out of small compute bodies feeding a
gather sink, every body carrying a ``RetryPolicy`` — through three
configurations of the work-stealing pool:

  no-fault     plain pool, no injector installed: the §14 machinery's
               *passive* cost (policy fields checked on the failure path
               only — this row must track graph_bench's fan-out numbers)
  seam-only    a :class:`repro.core.FaultInjector` installed with every
               rate at 0: the cost of routing dispatch through the §11
               ``_offload`` seam with no fault ever fired
  chaos        the seeded injector firing real faults (body failures,
               delays, synthetic worker loss) — every failure retried
               through the scheduler's deferred-backoff path

Each row reports wall time, injected-fault counts, the retries/timeouts
the pool actually performed, and **correct**: whether every task's final
value survived the faults bit-identically (the point of §14 — chaos
changes the schedule, never the answer). A final self-check re-runs the
chaos row with the same seed and asserts the injected schedule is
byte-identical — the determinism contract, enforced on every bench run.

    PYTHONPATH=src python benchmarks/chaos_bench.py [--quick] \
        [--out BENCH_chaos.json] [--seed 7] [--threads 4]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys
import time
from typing import Optional

from repro.core import (
    ChaosError,
    FaultInjector,
    RetryPolicy,
    TaskGraph,
    ThreadPool,
)
from repro.dist.process_pool import WorkerDiedError

POLICY = RetryPolicy(
    max_attempts=10, backoff=0.0, retry_on=(ChaosError, WorkerDiedError)
)


def build_graph(n: int) -> tuple[TaskGraph, object]:
    g = TaskGraph("chaos-bench")
    tasks = [
        g.add(lambda i=i: sum(range(64)) + i, name=f"b:{i}", retry=POLICY)
        for i in range(n)
    ]
    sink = g.gather(tasks, name="collect")
    return g, sink


def run_once(
    pool: ThreadPool, n: int, inj: Optional[FaultInjector]
) -> tuple[float, bool]:
    g, sink = build_graph(n)
    t0 = time.perf_counter()
    if inj is not None:
        with inj.on(pool):
            pool.run(g)
    else:
        pool.run(g)
    wall = time.perf_counter() - t0
    expect = [sum(range(64)) + i for i in range(n)]
    return wall, list(sink.result) == expect


def bench(quick: bool, threads: int, seed: int) -> list[dict]:
    n = 300 if quick else 2000
    repeats = 3 if quick else 5
    rates = dict(fail_rate=0.15, delay_rate=0.05, kill_rate=0.02, delay_s=0.0005)
    rows = []
    with ThreadPool(threads) as pool:
        run_once(pool, n, None)  # warm-up
        for label in ("no-fault", "seam-only", "chaos"):
            before = pool.stats()
            walls, correct, counts = [], True, {"fail": 0, "delay": 0, "kill": 0}
            for rep in range(repeats):
                if label == "no-fault":
                    inj = None
                elif label == "seam-only":
                    inj = FaultInjector(seed=seed)
                else:
                    inj = FaultInjector(seed=seed + rep, **rates)
                wall, ok = run_once(pool, n, inj)
                walls.append(wall)
                correct = correct and ok
                if inj is not None:
                    for k, v in inj.counts().items():
                        counts[k] += v
            after = pool.stats()
            rows.append(
                {
                    "config": label,
                    "tasks": n,
                    "repeats": repeats,
                    "wall_ms": min(walls) * 1e3,
                    "us_per_task": min(walls) / n * 1e6,
                    "injected": counts,
                    "retries": after["retries"] - before["retries"],
                    "timeouts": after["timeouts"] - before["timeouts"],
                    "correct": correct,
                }
            )
        # determinism self-check: same seed => byte-identical schedule
        a = FaultInjector(seed=seed, **rates)
        b = FaultInjector(seed=seed, **rates)
        run_once(pool, n, a)
        run_once(pool, n, b)
        assert a.schedule() == b.schedule(), "chaos schedule is not deterministic"
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes / fewer repeats (CI)")
    ap.add_argument("--out", default=None, help="also write a JSON perf record")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--threads", type=int, default=4)
    args = ap.parse_args()

    rows = bench(args.quick, args.threads, args.seed)
    print(
        f"{'config':<12}{'tasks':>7}{'wall_ms':>10}{'us/task':>9}"
        f"{'fail':>6}{'delay':>7}{'kill':>6}{'retries':>9}{'correct':>9}"
    )
    for r in rows:
        inj = r["injected"]
        print(
            f"{r['config']:<12}{r['tasks']:>7}{r['wall_ms']:>10.2f}"
            f"{r['us_per_task']:>9.2f}{inj['fail']:>6}{inj['delay']:>7}"
            f"{inj['kill']:>6}{r['retries']:>9}{str(r['correct']):>9}"
        )
    if not all(r["correct"] for r in rows):
        print("FAILED: surviving results diverged from the no-fault values")
        return 1
    chaos = next(r for r in rows if r["config"] == "chaos")
    if chaos["retries"] < chaos["injected"]["fail"]:
        print("FAILED: fewer retries than injected failures — recovery leaked")
        return 1
    print("determinism self-check: same seed produced an identical schedule")

    if args.out:
        out = pathlib.Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(
            json.dumps(
                {
                    "meta": {
                        "bench": "chaos_bench",
                        "quick": args.quick,
                        "seed": args.seed,
                        "threads": args.threads,
                        "cpu_count": os.cpu_count(),
                        "timestamp": time.time(),
                    },
                    "rows": rows,
                },
                indent=1,
            )
        )
        print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
