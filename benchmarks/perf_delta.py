"""§Perf before/after tables: artifacts_baseline/ vs artifacts/.

    PYTHONPATH=src python -m benchmarks.perf_delta [--update-experiments]
"""
from __future__ import annotations

import argparse
import json
import pathlib

BASE = pathlib.Path(__file__).parent / "artifacts_baseline"
AFTER = pathlib.Path(__file__).parent / "artifacts"
EXP = pathlib.Path(__file__).parent.parent / "EXPERIMENTS.md"

CELLS = [
    ("deepseek-v2-236b", "train_4k", "16x16"),
    ("deepseek-coder-33b", "decode_32k", "16x16"),
    ("deepseek-v2-236b", "decode_32k", "16x16"),
    # padding + chunked-attention side effects on other key cells
    ("qwen1.5-4b", "train_4k", "16x16"),
    ("phi4-mini-3.8b", "decode_32k", "16x16"),
    ("granite-moe-1b-a400m", "decode_32k", "16x16"),
    ("deepseek-coder-33b", "prefill_32k", "16x16"),
    ("tinyllama-1.1b", "train_4k", "16x16"),
]


def load(d: pathlib.Path, arch, shape, mesh):
    f = d / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def fmt_mem(r):
    m = r["memory"]
    return (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30


def rows():
    out = []
    for arch, shape, mesh in CELLS:
        b, a = load(BASE, arch, shape, mesh), load(AFTER, arch, shape, mesh)
        if not (b and a and b.get("ok") and a.get("ok")):
            continue
        out.append(
            dict(
                cell=f"{arch}/{shape}",
                mem_b=fmt_mem(b),
                mem_a=fmt_mem(a),
                coll_b=b["collectives"]["total_bytes"] / 2**30,
                coll_a=a["collectives"]["total_bytes"] / 2**30,
                dom_b=b["roofline"]["dominant"],
                dom_a=a["roofline"]["dominant"],
                bound_b=b["roofline"]["dominant_s"],
                bound_a=a["roofline"]["dominant_s"],
            )
        )
    return out


def table(rs) -> str:
    out = [
        "| cell | args+temp GiB (before→after) | coll GiB/dev/step | dominant | bound s |",
        "|---|---|---|---|---|",
    ]
    for r in rs:
        out.append(
            f"| {r['cell']} | {r['mem_b']:.1f} → **{r['mem_a']:.1f}** "
            f"| {r['coll_b']:.1f} → **{r['coll_a']:.1f}** "
            f"| {r['dom_b']} → {r['dom_a']} "
            f"| {r['bound_b']:.2f} → **{r['bound_a']:.2f}** |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-experiments", action="store_true")
    args = ap.parse_args()
    t = table(rows())
    print(t)
    if args.update_experiments and EXP.exists():
        import re

        text = EXP.read_text()
        begin, end = "<!-- perf-after:begin -->", "<!-- perf-after:end -->"
        pre, rest = text.split(begin)
        _, post = rest.split(end)
        EXP.write_text(pre + begin + "\n" + t + "\n" + end + post)
        print("updated EXPERIMENTS.md")


if __name__ == "__main__":
    main()
