"""§Perf before/after tables: artifacts_baseline/ vs artifacts/.

Emits the graph-overhead table (roofline cells) and, when both directories
hold a ``BENCH_serve.json``, a serve-latency table diffing tokens/s, p50/p99
TTFT and p50/p99 inter-token latency per server row.

    PYTHONPATH=src python -m benchmarks.perf_delta [--update-experiments]
"""
from __future__ import annotations

import argparse
import json
import pathlib

BASE = pathlib.Path(__file__).parent / "artifacts_baseline"
AFTER = pathlib.Path(__file__).parent / "artifacts"
EXP = pathlib.Path(__file__).parent.parent / "EXPERIMENTS.md"

CELLS = [
    ("deepseek-v2-236b", "train_4k", "16x16"),
    ("deepseek-coder-33b", "decode_32k", "16x16"),
    ("deepseek-v2-236b", "decode_32k", "16x16"),
    # padding + chunked-attention side effects on other key cells
    ("qwen1.5-4b", "train_4k", "16x16"),
    ("phi4-mini-3.8b", "decode_32k", "16x16"),
    ("granite-moe-1b-a400m", "decode_32k", "16x16"),
    ("deepseek-coder-33b", "prefill_32k", "16x16"),
    ("tinyllama-1.1b", "train_4k", "16x16"),
]


def load(d: pathlib.Path, arch, shape, mesh):
    f = d / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def fmt_mem(r):
    m = r["memory"]
    return (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)) / 2**30


def rows():
    out = []
    for arch, shape, mesh in CELLS:
        b, a = load(BASE, arch, shape, mesh), load(AFTER, arch, shape, mesh)
        if not (b and a and b.get("ok") and a.get("ok")):
            continue
        out.append(
            dict(
                cell=f"{arch}/{shape}",
                mem_b=fmt_mem(b),
                mem_a=fmt_mem(a),
                coll_b=b["collectives"]["total_bytes"] / 2**30,
                coll_a=a["collectives"]["total_bytes"] / 2**30,
                dom_b=b["roofline"]["dominant"],
                dom_a=a["roofline"]["dominant"],
                bound_b=b["roofline"]["dominant_s"],
                bound_a=a["roofline"]["dominant_s"],
            )
        )
    return out


def table(rs) -> str:
    out = [
        "| cell | args+temp GiB (before→after) | coll GiB/dev/step | dominant | bound s |",
        "|---|---|---|---|---|",
    ]
    for r in rs:
        out.append(
            f"| {r['cell']} | {r['mem_b']:.1f} → **{r['mem_a']:.1f}** "
            f"| {r['coll_b']:.1f} → **{r['coll_a']:.1f}** "
            f"| {r['dom_b']} → {r['dom_a']} "
            f"| {r['bound_b']:.2f} → **{r['bound_a']:.2f}** |"
        )
    return "\n".join(out)


def serve_rows():
    """Before/after serve-latency rows from BENCH_serve.json in each dir."""
    b_file, a_file = BASE / "BENCH_serve.json", AFTER / "BENCH_serve.json"
    if not (b_file.exists() and a_file.exists()):
        return []
    b = {r["server"]: r for r in json.loads(b_file.read_text())["rows"]}
    a = {r["server"]: r for r in json.loads(a_file.read_text())["rows"]}
    out = []
    for server in sorted(set(b) & set(a)):
        rb, ra = b[server], a[server]

        def pct(r, fam, q):
            return r.get(fam, {}).get(q)

        out.append(
            dict(
                server=server,
                tps_b=rb["tokens_per_s"],
                tps_a=ra["tokens_per_s"],
                ttft50_b=pct(rb, "ttft_ms", "p50"),
                ttft50_a=pct(ra, "ttft_ms", "p50"),
                ttft99_b=pct(rb, "ttft_ms", "p99"),
                ttft99_a=pct(ra, "ttft_ms", "p99"),
                itl50_b=pct(rb, "itl_ms", "p50"),
                itl50_a=pct(ra, "itl_ms", "p50"),
                itl99_b=pct(rb, "itl_ms", "p99"),
                itl99_a=pct(ra, "itl_ms", "p99"),
            )
        )
    return out


def _ms_pair(b, a):
    if b is None or a is None:
        return "—"
    return f"{b:.0f} → **{a:.0f}**"


def serve_table(rs) -> str:
    out = [
        "| server | tokens/s (before→after) | TTFT p50 ms | TTFT p99 ms "
        "| ITL p50 ms | ITL p99 ms |",
        "|---|---|---|---|---|---|",
    ]
    for r in rs:
        out.append(
            f"| {r['server']} | {r['tps_b']:.0f} → **{r['tps_a']:.0f}** "
            f"| {_ms_pair(r['ttft50_b'], r['ttft50_a'])} "
            f"| {_ms_pair(r['ttft99_b'], r['ttft99_a'])} "
            f"| {_ms_pair(r['itl50_b'], r['itl50_a'])} "
            f"| {_ms_pair(r['itl99_b'], r['itl99_a'])} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update-experiments", action="store_true")
    args = ap.parse_args()
    t = table(rows())
    srs = serve_rows()
    if srs:
        t += "\n\nServe latency (overload Poisson trace):\n\n" + serve_table(srs)
    print(t)
    if args.update_experiments and EXP.exists():
        text = EXP.read_text()
        begin, end = "<!-- perf-after:begin -->", "<!-- perf-after:end -->"
        pre, rest = text.split(begin)
        _, post = rest.split(end)
        EXP.write_text(pre + begin + "\n" + t + "\n" + end + post)
        print("updated EXPERIMENTS.md")


if __name__ == "__main__":
    main()
