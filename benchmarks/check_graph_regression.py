"""CI perf-regression gate for the task-graph scheduler (DESIGN.md §9/§11).

Compares a fresh ``graph_bench`` run against a committed baseline and
fails (exit 1) when any work-stealing row regresses by more than
``--threshold``× in ``overhead_us_per_task``, or when the process
backend's cpu-bound row drops below ``--min-process-speedup`` versus the
best thread-backend row (the §11 gate: the backend built for CPU-bound
bodies must never be slower than the backend it exists to beat — the
floor is deliberately a sanity bound, not the ≥2× headline, because
shared CI runners undercut real parallelism unpredictably; dedicated
multi-core hosts show the headline figure).

The cpu-bound shape is excluded from the *overhead* gate: its wall time
is compute, so "overhead over the serial floor" there measures parallel
speedup jitter, not scheduler cost.

Rows are matched by **shape prefix** (``chain(1024)`` and ``chain(8192)``
both match ``chain``), so a baseline at one size can in principle gate a
run at another. In practice CI gates quick-vs-quick: per-task overhead at
quick sizes carries un-amortized fixed costs (pool spin-up, root
scheduling) that the full-size ``BENCH_graph.json`` rows do not, so the
committed gate baseline is ``benchmarks/BENCH_graph_quick.json`` — quick
sizes, with each overhead recorded as the noise envelope (max) of several
runs. Only ws-fast rows at the baseline's default thread count
participate. The absolute slack (``--slack-us``) keeps near-zero-overhead
rows from failing on jitter — at ~1 µs overheads a 1.5× ratio is smaller
than CI-runner noise, while the regression class this gate exists for
(a lock back on the task path) shows up at 5–10 µs.

    PYTHONPATH=src python benchmarks/check_graph_regression.py \
        --baseline benchmarks/BENCH_graph_quick.json \
        --new benchmarks/artifacts/BENCH_graph.json --slack-us 1.5
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_THREADS = 4


def shape_prefix(bench: str) -> str:
    """``chain(8192)`` -> ``chain``; ``wavefront(64x64)`` -> ``wavefront``."""
    return bench.split("(", 1)[0]


def ws_rows(payload: dict, threads: int) -> dict[str, float]:
    """Map shape-prefix -> overhead_us_per_task for ws-fast rows.

    Rows written before the --threads sweep carry no ``threads`` field;
    they were all recorded at the default worker count. The cpu-bound
    shape never carries an overhead figure (module docs).
    """
    out: dict[str, float] = {}
    for row in payload["rows"]:
        if row.get("executor") != "ws-fast":
            continue
        if row.get("threads", DEFAULT_THREADS) != threads:
            continue
        if "overhead_us_per_task" not in row:
            continue
        out[shape_prefix(row["bench"])] = row["overhead_us_per_task"]
    return out


def process_speedups(payload: dict) -> dict[str, float]:
    """Map shape-prefix -> speedup_vs_thread for ws-process rows."""
    return {
        shape_prefix(row["bench"]): row["speedup_vs_thread"]
        for row in payload["rows"]
        if row.get("executor") == "ws-process" and "speedup_vs_thread" in row
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_graph.json")
    ap.add_argument("--new", required=True, help="freshly generated BENCH_graph.json")
    ap.add_argument("--threads", type=int, default=DEFAULT_THREADS)
    ap.add_argument("--threshold", type=float, default=1.5, help="max allowed ratio")
    ap.add_argument("--slack-us", type=float, default=1.0, help="absolute noise floor (µs)")
    ap.add_argument(
        "--min-process-speedup",
        type=float,
        default=0.9,
        help="floor for ws-process speedup_vs_thread on the cpu-bound shape "
        "(sanity bound for shared runners; see module docs)",
    )
    args = ap.parse_args()

    baseline = ws_rows(json.loads(pathlib.Path(args.baseline).read_text()), args.threads)
    new_payload = json.loads(pathlib.Path(args.new).read_text())
    fresh = ws_rows(new_payload, args.threads)

    if not baseline:
        print("no ws-fast baseline rows found — nothing to gate")
        return 0

    failures: list[str] = []
    compared = 0
    print(f"{'shape':<18}{'baseline us':>12}{'new us':>10}{'limit us':>10}  verdict")
    for shape, base in sorted(baseline.items()):
        if shape not in fresh:
            print(f"{shape:<18}{base:>12.2f}{'—':>10}{'—':>10}  missing in new run (skipped)")
            continue
        compared += 1
        new = fresh[shape]
        limit = base * args.threshold + args.slack_us
        verdict = "ok" if new <= limit else "REGRESSION"
        print(f"{shape:<18}{base:>12.2f}{new:>10.2f}{limit:>10.2f}  {verdict}")
        if new > limit:
            failures.append(shape)

    for shape in sorted(set(fresh) - set(baseline)):
        print(f"{shape:<18}{'—':>12}{fresh[shape]:>10.2f}{'—':>10}  new shape (no baseline)")

    # §11 gate: the process backend must beat (or at worst match, within
    # the configured floor) the thread backend on the cpu-bound shape
    speedup_failures: list[str] = []
    speedups = process_speedups(new_payload)
    for shape, speed in sorted(speedups.items()):
        verdict = "ok" if speed >= args.min_process_speedup else "REGRESSION"
        print(
            f"{shape:<18}ws-process speedup_vs_thread "
            f"{speed:.2f}x (floor {args.min_process_speedup:.2f}x)  {verdict}"
        )
        if speed < args.min_process_speedup:
            speedup_failures.append(shape)

    if failures or speedup_failures:
        if failures:
            print(
                f"\nFAIL: overhead regression >{args.threshold}x in: "
                f"{', '.join(failures)}"
            )
        if speedup_failures:
            print(
                f"\nFAIL: §11 process backend below the "
                f"{args.min_process_speedup:.2f}x speedup floor in: "
                f"{', '.join(speedup_failures)}"
            )
        return 1
    if compared == 0:
        # never fail open: a gate that compared nothing (renamed shapes,
        # thread-count mismatch, empty run) must not pass vacuously
        print("\nFAIL: no baseline shape matched the new run — the gate compared nothing")
        return 1
    print(f"\nOK: no scheduler-overhead regression ({compared} shapes compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
