"""CI perf-regression gate for the task-graph scheduler (DESIGN.md §9/§11).

Compares a fresh ``graph_bench`` run against a committed baseline and
fails (exit 1) when any work-stealing row regresses by more than
``--threshold``× in ``overhead_us_per_task``, or when the process
backend's cpu-bound row drops below ``--min-process-speedup`` versus the
best thread-backend row (the §11 gate: the backend built for CPU-bound
bodies must never be slower than the backend it exists to beat — the
floor is deliberately a sanity bound, not the ≥2× headline, because
shared CI runners undercut real parallelism unpredictably; dedicated
multi-core hosts show the headline figure).

The cpu-bound shape is excluded from the *overhead* gate: its wall time
is compute, so "overhead over the serial floor" there measures parallel
speedup jitter, not scheduler cost.

The §12 replay gate runs entirely inside the fresh payload: the chain
shape's ``ws-replay`` row must beat its own ``ws-fast`` row within the
noise envelope (``--replay-slack-us``) — the fused-segment dispatch that
replay exists for must stay cheaper than live dispatch of the same chain,
on the same host, in the same run. Fusion-poor shapes (wavefront,
random-dag) legitimately track live dispatch, so only the chain shapes
participate. Additionally ``--full-baseline`` (default: the committed
full-size ``BENCH_graph.json``) enforces the absolute §12 acceptance
figure: the committed chain ``ws-replay`` overhead at the gate thread
count must stay at or below ``--replay-chain-max-us``.

Rows are matched by **shape prefix** (``chain(1024)`` and ``chain(8192)``
both match ``chain``), so a baseline at one size can in principle gate a
run at another. In practice CI gates quick-vs-quick: per-task overhead at
quick sizes carries un-amortized fixed costs (pool spin-up, root
scheduling) that the full-size ``BENCH_graph.json`` rows do not, so the
committed gate baseline is ``benchmarks/BENCH_graph_quick.json`` — quick
sizes, with each overhead recorded as the noise envelope (max) of several
runs. Only ws-fast rows at the baseline's default thread count
participate. The absolute slack (``--slack-us``) keeps near-zero-overhead
rows from failing on jitter — at ~1 µs overheads a 1.5× ratio is smaller
than CI-runner noise, while the regression class this gate exists for
(a lock back on the task path) shows up at 5–10 µs.

The §16 socket gate runs entirely inside the fresh payload and never
passes vacuously: every shape named in ``--socket-shapes`` (default
``chain,cpu-bound`` — pass an empty string to disarm) must carry a
``ws-socket`` row, the cpu-bound socket row must finish within
``--max-socket-vs-process``× of the same run's ``ws-process`` wall (the
transport may tax compute, not swallow it), and the chain socket row's
``us_per_task`` — the pure per-task TCP round-trip — must stay under
``--max-socket-us-per-task``. Both bounds are deliberately generous
sanity rails for shared runners: the regression class they exist for
(a serialized dispatcher, a lost-wakeup stall in the slot handoff, a
cache gone quadratic) shows up as a 10–100× blowout, not a 2× dip.

The §13 serve gate (``--serve-baseline`` + ``--serve-new``, both required
to arm it) reads ``serve_bench`` payloads and fails when any of:

* a ``continuous-flat`` / ``continuous-paged`` row is missing, the paged
  row did not complete every request, or the payload skipped output
  verification — the gate never passes vacuously;
* ``outputs_match_sequential_decode`` is not ``true`` (paged decode must
  stay bit-identical to sequential decode);
* the in-run throughput ratio ``paged_over_flat_tokens_per_s`` drops
  below ``--serve-throughput-floor``. The floor is deliberately below the
  ≥0.9× figure seen on dedicated hosts: on shared CI runners the flat
  row's wall time jitters ±15%, and the regression class this arm exists
  for (a per-tick re-gather bug, a lock on the scatter path) shows up as
  a 2–5× collapse, not a 10% dip;
* the fresh paged/flat p99 TTFT exceeds the committed quick baseline's
  by more than ``--serve-ttft-threshold``× plus ``--serve-ttft-slack-ms``
  — an admission stall or priority inversion blows p99 TTFT up by
  seconds (queue depth × tick time), far past the envelope.

Like the overhead gate, CI compares quick-vs-quick: the committed serve
baseline is ``benchmarks/BENCH_serve_quick.json``, a ``--quick`` run
recorded on a contended 2-vCPU host as the noise envelope (max p99 over
several runs).

    PYTHONPATH=src python benchmarks/check_graph_regression.py \
        --baseline benchmarks/BENCH_graph_quick.json \
        --new benchmarks/artifacts/BENCH_graph.json --slack-us 1.5 \
        --serve-baseline benchmarks/BENCH_serve_quick.json \
        --serve-new benchmarks/artifacts/BENCH_serve.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

DEFAULT_THREADS = 4


def shape_prefix(bench: str) -> str:
    """``chain(8192)`` -> ``chain``; ``wavefront(64x64)`` -> ``wavefront``."""
    return bench.split("(", 1)[0]


def ws_rows(payload: dict, threads: int, executor: str = "ws-fast") -> dict[str, float]:
    """Map shape-prefix -> overhead_us_per_task for one executor's rows.

    Rows written before the --threads sweep carry no ``threads`` field;
    they were all recorded at the default worker count. The cpu-bound
    shape never carries an overhead figure (module docs).
    """
    out: dict[str, float] = {}
    for row in payload["rows"]:
        if row.get("executor") != executor:
            continue
        if row.get("threads", DEFAULT_THREADS) != threads:
            continue
        if "overhead_us_per_task" not in row:
            continue
        out[shape_prefix(row["bench"])] = row["overhead_us_per_task"]
    return out


def serve_rows(payload: dict) -> dict[str, dict]:
    """Map server name -> row for a serve_bench payload."""
    return {row["server"]: row for row in payload.get("rows", []) if "server" in row}


def serve_gate(args) -> list[str]:
    """§13 serve gate (module docs). Returns failure labels; prints verdicts."""
    failures: list[str] = []
    base = json.loads(pathlib.Path(args.serve_baseline).read_text())
    fresh_payload = json.loads(pathlib.Path(args.serve_new).read_text())
    brows, frows = serve_rows(base), serve_rows(fresh_payload)

    for name in ("continuous-flat", "continuous-paged"):
        if name not in frows:
            print(f"FAIL: serve: no {name} row in the fresh run")
            failures.append(f"serve {name} (missing)")
    if failures:
        return failures

    paged = frows["continuous-paged"]
    requests = fresh_payload.get("meta", {}).get("requests")
    if requests is None or paged.get("completed") != requests:
        print(
            f"serve              paged completed {paged.get('completed')} of "
            f"{requests} requests  REGRESSION"
        )
        failures.append("serve completion")
    if fresh_payload.get("outputs_match_sequential_decode") is not True:
        print(
            "serve              outputs_match_sequential_decode is "
            f"{fresh_payload.get('outputs_match_sequential_decode')!r} "
            "(bit-identity unverified)  REGRESSION"
        )
        failures.append("serve bit-identity")

    ratio = fresh_payload.get("paged_over_flat_tokens_per_s")
    if ratio is None:
        print("FAIL: serve: no paged_over_flat_tokens_per_s in the fresh run")
        failures.append("serve throughput (missing)")
    else:
        verdict = "ok" if ratio >= args.serve_throughput_floor else "REGRESSION"
        print(
            f"serve              paged/flat tokens/s {ratio:.3f}x "
            f"(floor {args.serve_throughput_floor:.2f}x)  {verdict}"
        )
        if ratio < args.serve_throughput_floor:
            failures.append("serve throughput")

    for name in ("continuous-flat", "continuous-paged"):
        bp = brows.get(name, {}).get("ttft_ms", {}).get("p99")
        fp = frows[name].get("ttft_ms", {}).get("p99")
        if bp is None or fp is None:
            print(f"FAIL: serve: no p99 TTFT for {name} (baseline={bp}, new={fp})")
            failures.append(f"serve {name} p99 TTFT (missing)")
            continue
        limit = bp * args.serve_ttft_threshold + args.serve_ttft_slack_ms
        verdict = "ok" if fp <= limit else "REGRESSION"
        print(
            f"serve              {name} p99 TTFT {fp:.1f}ms vs baseline "
            f"{bp:.1f}ms (limit {limit:.1f}ms)  {verdict}"
        )
        if fp > limit:
            failures.append(f"serve {name} p99 TTFT")
    return failures


def socket_gate(payload: dict, args) -> list[str]:
    """§16 socket-transport gate (module docs). Returns failure labels."""
    wanted = [s.strip() for s in args.socket_shapes.split(",") if s.strip()]
    if not wanted:
        return []
    failures: list[str] = []
    sock: dict[str, dict] = {}
    proc_wall: dict[str, float] = {}
    for row in payload["rows"]:
        prefix = shape_prefix(row["bench"])
        if row.get("executor") == "ws-socket":
            sock[prefix] = row
        elif row.get("executor") == "ws-process":
            proc_wall[prefix] = row["wall_ms"]
    for shape in wanted:
        row = sock.get(shape)
        if row is None:
            print(f"FAIL: socket: no ws-socket {shape} row in the fresh run")
            failures.append(f"socket {shape} (missing)")
            continue
        if shape in proc_wall:
            ratio = row["wall_ms"] / proc_wall[shape]
            limit = args.max_socket_vs_process
            verdict = "ok" if ratio <= limit else "REGRESSION"
            print(
                f"{shape:<18}ws-socket wall {ratio:.2f}x of ws-process "
                f"(max {limit:.2f}x)  {verdict}"
            )
            if ratio > limit:
                failures.append(f"socket {shape} vs process")
        else:
            per_task = row["us_per_task"]
            limit = args.max_socket_us_per_task
            verdict = "ok" if per_task <= limit else "REGRESSION"
            print(
                f"{shape:<18}ws-socket {per_task:.1f}us/task round-trip "
                f"(max {limit:.1f}us)  {verdict}"
            )
            if per_task > limit:
                failures.append(f"socket {shape} round-trip")
    return failures


def process_speedups(payload: dict) -> dict[str, float]:
    """Map shape-prefix -> speedup_vs_thread for ws-process rows."""
    return {
        shape_prefix(row["bench"]): row["speedup_vs_thread"]
        for row in payload["rows"]
        if row.get("executor") == "ws-process" and "speedup_vs_thread" in row
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, help="committed BENCH_graph.json")
    ap.add_argument("--new", required=True, help="freshly generated BENCH_graph.json")
    ap.add_argument("--threads", type=int, default=DEFAULT_THREADS)
    ap.add_argument("--threshold", type=float, default=1.5, help="max allowed ratio")
    ap.add_argument("--slack-us", type=float, default=1.0, help="absolute noise floor (µs)")
    ap.add_argument(
        "--min-process-speedup",
        type=float,
        default=0.9,
        help="floor for ws-process speedup_vs_thread on the cpu-bound shape "
        "(sanity bound for shared runners; see module docs)",
    )
    ap.add_argument(
        "--replay-slack-us",
        type=float,
        default=0.5,
        help="noise envelope for the §12 gate: the fresh chain ws-replay row "
        "must not exceed the fresh chain ws-fast row by more than this (µs)",
    )
    ap.add_argument(
        "--replay-chain-max-us",
        type=float,
        default=0.06,
        help="absolute §12 acceptance bound on the committed full-size chain "
        "ws-replay overhead (µs/task)",
    )
    ap.add_argument(
        "--full-baseline",
        default=str(pathlib.Path(__file__).resolve().parent.parent / "BENCH_graph.json"),
        help="committed full-size BENCH_graph.json for the absolute replay "
        "bound (pass an empty string to skip)",
    )
    ap.add_argument(
        "--socket-shapes",
        default="chain,cpu-bound",
        help="comma-separated shape prefixes that must carry a ws-socket row "
        "in the fresh run (§16 gate; empty string disarms it)",
    )
    ap.add_argument(
        "--max-socket-vs-process",
        type=float,
        default=3.0,
        help="max allowed ratio of the cpu-bound ws-socket wall over the same "
        "run's ws-process wall (generous rail; see module docs)",
    )
    ap.add_argument(
        "--max-socket-us-per-task",
        type=float,
        default=2000.0,
        help="ceiling on the chain ws-socket us_per_task — the per-task TCP "
        "round-trip (generous rail; see module docs)",
    )
    ap.add_argument(
        "--serve-baseline",
        default="",
        help="committed quick serve_bench payload (BENCH_serve_quick.json); "
        "must be paired with --serve-new to arm the §13 serve gate",
    )
    ap.add_argument(
        "--serve-new",
        default="",
        help="freshly generated serve_bench payload (BENCH_serve.json)",
    )
    ap.add_argument(
        "--serve-throughput-floor",
        type=float,
        default=0.7,
        help="floor on the fresh paged/flat tokens-per-second ratio "
        "(sanity bound for shared runners; see module docs)",
    )
    ap.add_argument(
        "--serve-ttft-threshold",
        type=float,
        default=2.0,
        help="max allowed ratio of fresh p99 TTFT over the serve baseline's",
    )
    ap.add_argument(
        "--serve-ttft-slack-ms",
        type=float,
        default=75.0,
        help="absolute noise floor on the p99 TTFT limit (ms)",
    )
    args = ap.parse_args()
    if bool(args.serve_baseline) != bool(args.serve_new):
        ap.error("--serve-baseline and --serve-new must be passed together")

    baseline = ws_rows(json.loads(pathlib.Path(args.baseline).read_text()), args.threads)
    new_payload = json.loads(pathlib.Path(args.new).read_text())
    fresh = ws_rows(new_payload, args.threads)

    if not baseline:
        print("no ws-fast baseline rows found — nothing to gate")
        return 0

    failures: list[str] = []
    compared = 0
    print(f"{'shape':<18}{'baseline us':>12}{'new us':>10}{'limit us':>10}  verdict")
    for shape, base in sorted(baseline.items()):
        if shape not in fresh:
            print(f"{shape:<18}{base:>12.2f}{'—':>10}{'—':>10}  missing in new run (skipped)")
            continue
        compared += 1
        new = fresh[shape]
        limit = base * args.threshold + args.slack_us
        verdict = "ok" if new <= limit else "REGRESSION"
        print(f"{shape:<18}{base:>12.2f}{new:>10.2f}{limit:>10.2f}  {verdict}")
        if new > limit:
            failures.append(shape)

    for shape in sorted(set(fresh) - set(baseline)):
        print(f"{shape:<18}{'—':>12}{fresh[shape]:>10.2f}{'—':>10}  new shape (no baseline)")

    # §11 gate: the process backend must beat (or at worst match, within
    # the configured floor) the thread backend on the cpu-bound shape
    speedup_failures: list[str] = []
    speedups = process_speedups(new_payload)
    for shape, speed in sorted(speedups.items()):
        verdict = "ok" if speed >= args.min_process_speedup else "REGRESSION"
        print(
            f"{shape:<18}ws-process speedup_vs_thread "
            f"{speed:.2f}x (floor {args.min_process_speedup:.2f}x)  {verdict}"
        )
        if speed < args.min_process_speedup:
            speedup_failures.append(shape)

    # §12 gate A: chain replay beats chain live, fresh run vs itself
    replay_failures: list[str] = []
    fresh_replay = ws_rows(new_payload, args.threads, executor="ws-replay")
    for shape in sorted(fresh_replay):
        if not shape.startswith("chain"):
            continue  # fusion-poor shapes track live dispatch (module docs)
        if shape not in fresh:
            continue
        live, replayed = fresh[shape], fresh_replay[shape]
        limit = live + args.replay_slack_us
        verdict = "ok" if replayed <= limit else "REGRESSION"
        print(
            f"{shape:<18}ws-replay {replayed:.2f}us vs ws-fast {live:.2f}us "
            f"(limit {limit:.2f}us)  {verdict}"
        )
        if replayed > limit:
            replay_failures.append(shape)
    if not any(s.startswith("chain") for s in fresh_replay):
        print("FAIL: no fresh chain ws-replay row — the §12 gate compared nothing")
        replay_failures.append("chain (missing)")

    # §12 gate B: the committed full-size chain replay figure holds
    if args.full_baseline:
        full_path = pathlib.Path(args.full_baseline)
        full_replay = ws_rows(
            json.loads(full_path.read_text()), args.threads, executor="ws-replay"
        )
        chain_full = {s: v for s, v in full_replay.items() if s.startswith("chain")}
        if not chain_full:
            print(f"FAIL: no chain ws-replay row in {full_path}")
            replay_failures.append("chain (full baseline missing)")
        for shape, ovh in sorted(chain_full.items()):
            verdict = "ok" if ovh <= args.replay_chain_max_us else "REGRESSION"
            print(
                f"{shape:<18}committed ws-replay {ovh:.3f}us "
                f"(max {args.replay_chain_max_us:.3f}us)  {verdict}"
            )
            if ovh > args.replay_chain_max_us:
                replay_failures.append(f"{shape} (committed)")

    # §16 gate: the socket transport holds its rails inside the fresh run
    socket_failures = socket_gate(new_payload, args)

    # §13 gate: paged serving must hold throughput and tail latency
    serve_failures: list[str] = []
    if args.serve_baseline:
        serve_failures = serve_gate(args)

    if failures or speedup_failures or replay_failures or serve_failures or socket_failures:
        if replay_failures:
            print(f"\nFAIL: §12 replay gate: {', '.join(replay_failures)}")
        if socket_failures:
            print(f"\nFAIL: §16 socket gate: {', '.join(socket_failures)}")
        if serve_failures:
            print(f"\nFAIL: §13 serve gate: {', '.join(serve_failures)}")
        if failures:
            print(
                f"\nFAIL: overhead regression >{args.threshold}x in: "
                f"{', '.join(failures)}"
            )
        if speedup_failures:
            print(
                "\nFAIL: §11 process backend below the "
                f"{args.min_process_speedup:.2f}x speedup floor in: "
                f"{', '.join(speedup_failures)}"
            )
        return 1
    if compared == 0:
        # never fail open: a gate that compared nothing (renamed shapes,
        # thread-count mismatch, empty run) must not pass vacuously
        print("\nFAIL: no baseline shape matched the new run — the gate compared nothing")
        return 1
    print(f"\nOK: no scheduler-overhead regression ({compared} shapes compared)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
