"""Task-graph microbenchmarks (paper §3 shapes) with a JSON perf record.

Reproduces the paper's microbenchmark setup on four canonical graph
shapes — **linear chain**, **random DAG**, **wavefront**, **fan-out/join**
(alternating wide fan-outs and joins, the scheduler's wakeup/fan-out hot
path) — plus a value-passing chain that measures the dataflow runtime's
argument-delivery overhead (DESIGN.md §8) and two §10 control-flow
shapes: **condition-loop** (a weak-edge cycle iterated N times — the
weak-trigger + re-arm dispatch path) and **subflow-fanout** (a chain of
``takes_runtime`` spawners, each splicing a dynamic fan-out behind a join
— the spawn/join dispatch path) — and one §11 backend shape:
**cpu-bound**, a fan-out of pure-Python compute bodies: the workload the
GIL serializes on threads and the process backend parallelizes. Its
``ws-process`` row carries ``speedup_vs_thread`` (process wall vs the
best ws-fast wall of the same shape), the headline §11 figure. Each
shape runs on:

  ws-fast     the paper's work-stealing pool (FastDeque), live dispatch
              every pass (``pool.run``: full reset + countdown walk)
  ws-replay   the same pool dispatching from the graph's captured
              ReplayPlan (DESIGN.md §12): pass 1 runs live and records,
              pass 2 compiles + first-replays — both excluded as warm-up
              — and the timed passes re-arm and dispatch fused segments
              with no ``reset()`` walk (steady-state shapes only)
  ws-process  the same scheduler, bodies in worker processes
              (repro.dist.ProcessPool; cpu-bound shape only — per-task
              IPC buys nothing for no-op bodies)
  ws-socket   the same scheduler, bodies on TCP-connected workers
              (repro.dist.SocketPool, DESIGN.md §16). Two rows: the
              cpu-bound shape (does compute survive the framed-pickle
              transport? carries ``speedup_vs_thread`` like ws-process)
              and the plain chain (per-task round-trip cost of the
              socket transport itself — its ``us_per_task`` is the §16
              transport-overhead figure the regression gate bounds)
  stdlib      concurrent.futures.ThreadPoolExecutor driving the same
              graphs (static DAG shapes only: no weak-edge/subflow
              dispatch)
  serial      topological execution on one thread (zero-overhead floor)

The discriminating figure is **dependency-counting overhead per task**:
(wall − serial wall of the same shape) / tasks-executed, in µs — what the
scheduler costs on top of the bodies. Control-flow shapes execute more
tasks than the graph holds (loop passes, spawned tasks); builders return
the executed count. Results land in ``BENCH_graph.json`` so the perf
trajectory is diffable across PRs, and
``benchmarks/check_graph_regression.py`` gates CI on it — including the
§10 shapes, so the new dispatch paths cannot silently regress.

    PYTHONPATH=src python benchmarks/graph_bench.py [--quick] \
        [--out BENCH_graph.json] [--trace trace.json] [--threads 1,2,4,8] \
        [--shape cpu-bound]

``--threads`` sweeps the work-stealing pool over several worker counts
(serial/stdlib rows are unaffected; stdlib stays at the default; the
process pool always runs at ``os.cpu_count()`` workers — oversubscribing
processes only adds memory). ``--shape`` filters to shapes whose name
starts with the given prefix. ``--trace`` additionally records one
wavefront run through the Chrome-trace observer (open the file in
chrome://tracing).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import random
import sys
import time
from typing import Callable, Optional

from repro.core import ChromeTraceObserver, SerialExecutor, TaskGraph, ThreadPool

try:
    from benchmarks.paper_bench import StdlibExecutor
except ImportError:  # run as a plain script: benchmarks/ is on sys.path
    from paper_bench import StdlibExecutor

NUM_THREADS = 4


# -- graph builders -------------------------------------------------------------


def build_chain(g: TaskGraph, n: int) -> None:
    g.chain([lambda: None] * n)


def build_chain_dataflow(g: TaskGraph, n: int) -> None:
    """Value-passing chain: each task increments its predecessor's result —
    measures argument delivery on top of plain dependency counting."""
    t = g.add(lambda: 0, name="head")
    for _ in range(n - 1):
        t = t.then(lambda x: x + 1)


def build_random_dag(g: TaskGraph, n: int, *, seed: int = 0, max_preds: int = 3) -> None:
    """Seeded random DAG: task i depends on up to ``max_preds`` earlier
    tasks (always at least one once the graph is non-empty), giving an
    irregular mix of chains, joins and fan-outs."""
    rng = random.Random(seed)
    tasks = []
    for i in range(n):
        t = g.add(lambda: None, name=f"r{i}")
        if tasks:
            k = rng.randint(1, min(max_preds, len(tasks)))
            preds = rng.sample(tasks, k)
            t.after(*preds)
        tasks.append(t)


def build_wavefront(g: TaskGraph, n: int) -> None:
    """n×n wavefront: cell (i,j) depends on (i-1,j) and (i,j-1)."""
    tasks: dict = {}
    for i in range(n):
        for j in range(n):
            t = g.add(lambda: None, name=f"c{i}_{j}")
            deps = []
            if i > 0:
                deps.append(tasks[(i - 1, j)])
            if j > 0:
                deps.append(tasks[(i, j - 1)])
            if deps:
                t.after(*deps)
            tasks[(i, j)] = t


def build_fanout_join(g: TaskGraph, width: int, depth: int) -> None:
    """``depth`` alternating fan-out(``width``)/join stages.

    Each finishing join releases ``width`` successors at once — the
    fused decrement-and-pick fan-out and the parked-worker wakeup chain
    are the whole cost here (1 + depth*(width+1) tasks)."""
    t = g.add(lambda: None, name="fan-root")
    for d in range(depth):
        layer = [g.add(lambda: None, name=f"f{d}_{i}").after(t) for i in range(width)]
        t = g.add(lambda: None, name=f"join{d}").after(*layer)


def build_condition_loop(g: TaskGraph, body_len: int, iters: int) -> int:
    """Weak-edge cycle: entry -> body chain -> condition, looped ``iters``
    times. Exercises the §10 slow path end to end: per-pass re-arm, weak
    trigger of the loop head, counted quiescence. Returns executed count
    (the loop runs the ``body_len + 1`` cycle tasks once per pass)."""
    state = {"i": 0}
    entry = g.add(lambda: state.__setitem__("i", 0), name="entry")
    body = g.chain([lambda: None] * body_len, name="body")
    body[0].after(entry)

    def more() -> int:
        state["i"] += 1
        return 0 if state["i"] < iters else 1

    cond = g.add(more, kind="condition", name="more")
    cond.after(body[-1])
    cond.precede(body[0])
    return 1 + iters * (body_len + 1)


def build_subflow_fanout(g: TaskGraph, width: int, depth: int) -> int:
    """Chain of ``depth`` runtime tasks, each spawning a ``width``-task
    subflow joined before the next spawner. Exercises subflow splice, join
    wiring and the spawned tasks' dispatch. Returns executed count
    (spawner + width spawned + hidden join, per stage)."""

    def spawn(rt) -> None:
        for i in range(width):
            rt.add(lambda: None, name=f"s{i}")

    prev = None
    for d in range(depth):
        s = g.add(spawn, name=f"spawn{d}", takes_runtime=True)
        if prev is not None:
            s.after(prev)
        prev = s
    return depth * (width + 2)


def _burn(iters: int) -> float:
    """Pure-Python compute body: holds the GIL for its whole duration, so
    thread-backend copies serialize while process-backend copies run on
    separate cores. Module-level so every backend can ship it."""
    x = 0.0
    for i in range(iters):
        x += (i * i) % 7
    return x


def build_cpu_bound(g: TaskGraph, width: int, iters: int) -> None:
    """§11 backend shape: root -> ``width`` independent ``_burn`` bodies
    -> gather. Wall time is compute-dominated; the executors differ only
    in where the bodies run (GIL-serialized threads vs worker processes)."""
    root = g.add(lambda: None, name="root")
    layer = [
        g.add(lambda n=iters: _burn(n), name=f"burn{i}").after(root)
        for i in range(width)
    ]
    g.gather(layer, fn=lambda *vs: sum(vs), name="total")


# shapes the stdlib executor cannot run (no weak-edge / subflow dispatch)
STDLIB_UNSUPPORTED = ("condition-loop", "subflow-fanout", "cpu-bound")
# the one shape whose bodies are heavy enough to amortize per-job IPC
PROCESS_SHAPES = ("cpu-bound",)
# §16 socket rows: cpu-bound (compute over the wire, speedup figure) and
# the plain chain (pure per-task transport cost). Exact prefixes — the
# chain-dataflow shape would measure the same wire twice.
SOCKET_SHAPES = ("cpu-bound", "chain")
# steady-state shapes that get a §12 ws-replay row ("chain" also matches
# chain-dataflow); subflow-fanout is spawn-dominated and cpu-bound is
# compute-dominated — replay rows there would measure nothing new
REPLAY_SHAPES = ("chain", "random-dag", "wavefront", "fanout-join", "condition-loop")


def shapes(quick: bool) -> dict[str, Callable[[TaskGraph], Optional[int]]]:
    """Shape name -> builder. A builder returns the *executed*-task count
    when it differs from ``len(graph)`` (control-flow shapes), else None."""
    chain_n = 1024 if quick else 8192
    dag_n = 1024 if quick else 8192
    wf_n = 24 if quick else 64
    fan_w, fan_d = (16, 32) if quick else (32, 128)
    loop_body, loop_iters = (8, 64) if quick else (16, 256)
    sub_w, sub_d = (16, 32) if quick else (32, 128)
    cpu_w = 2 * (os.cpu_count() or 1)
    cpu_n = 240_000 if quick else 600_000
    return {
        f"chain({chain_n})": lambda g: build_chain(g, chain_n),
        f"chain-dataflow({chain_n})": lambda g: build_chain_dataflow(g, chain_n),
        f"random-dag({dag_n})": lambda g: build_random_dag(g, dag_n),
        f"wavefront({wf_n}x{wf_n})": lambda g: build_wavefront(g, wf_n),
        f"fanout-join({fan_w}x{fan_d})": lambda g: build_fanout_join(g, fan_w, fan_d),
        f"condition-loop({loop_body}x{loop_iters})": lambda g: build_condition_loop(
            g, loop_body, loop_iters
        ),
        f"subflow-fanout({sub_w}x{sub_d})": lambda g: build_subflow_fanout(g, sub_w, sub_d),
        f"cpu-bound({cpu_w}x{cpu_n})": lambda g: build_cpu_bound(g, cpu_w, cpu_n),
    }


# -- measurement ----------------------------------------------------------------


def _time_graph(make_executor, build, repeats: int) -> tuple[float, float, int]:
    """Best-of-N wall/CPU seconds; the graph is built once and *re-run*
    each repeat (the re-runnable lifecycle the runtime guarantees). The
    task count is the number of task executions per run — builders report
    it when control flow makes it exceed ``len(graph)``."""
    g = TaskGraph()
    ntasks = build(g) or len(g)
    best_wall, best_cpu = float("inf"), float("inf")
    with make_executor() as ex:
        for _ in range(repeats):
            g.reset()
            w0, c0 = time.perf_counter(), time.process_time()
            ex.run(g)
            w1, c1 = time.perf_counter(), time.process_time()
            best_wall = min(best_wall, w1 - w0)
            best_cpu = min(best_cpu, c1 - c0)
    return best_wall, best_cpu, ntasks


def _time_graph_replay(nthreads: int, build, repeats: int) -> tuple[float, float, int]:
    """Best-of-N replayed passes (DESIGN.md §12).

    Pass 1 runs live and records the schedule; pass 2 compiles the
    ReplayPlan and takes the first replayed pass — both are warm-up and
    excluded. The timed passes dispatch purely from the plan: no
    ``reset()`` (plan re-arm subsumes it), no live countdown walk."""
    g = TaskGraph()
    ntasks = build(g) or len(g)
    best_wall, best_cpu = float("inf"), float("inf")
    with ThreadPool(nthreads) as pool:
        g.as_future(pool).result(300)  # live: record + settle the structure
        g.as_future(pool).result(300)  # compile + first replay
        if g.replay_plan is None:
            raise RuntimeError("replay plan failed to compile for bench shape")
        for _ in range(repeats):
            w0, c0 = time.perf_counter(), time.process_time()
            g.as_future(pool).result(300)
            w1, c1 = time.perf_counter(), time.process_time()
            best_wall = min(best_wall, w1 - w0)
            best_cpu = min(best_cpu, c1 - c0)
        if g.replay_plan is None or g.replay_plan.replays < repeats:
            raise RuntimeError("timed passes fell back to live dispatch")
    return best_wall, best_cpu, ntasks


def run_bench(
    quick: bool, thread_counts: list[int], shape_filter: Optional[str] = None
) -> list[dict]:
    """Rows for every shape × executor; ws-fast is swept over
    ``thread_counts`` (each row carries a ``threads`` field), ws-process
    runs the cpu-bound shape at ``os.cpu_count()`` workers."""
    repeats = 2 if quick else 3
    cores = os.cpu_count() or 1
    rows: list[dict] = []
    serial_wall: dict[str, float] = {}
    for shape, build in shapes(quick).items():
        if shape_filter and not shape.startswith(shape_filter):
            continue
        executors: list[tuple[str, int, Callable[[], object]]] = [
            ("ws-fast", t, (lambda t=t: ThreadPool(t))) for t in thread_counts
        ]
        if shape.startswith(PROCESS_SHAPES):
            from repro.dist import ProcessPool

            executors.append(("ws-process", cores, lambda: ProcessPool(cores)))
        if shape.split("(", 1)[0] in SOCKET_SHAPES:
            from repro.dist import SocketPool

            # cpu-bound wants real parallelism; the chain is sequential by
            # construction, so a small pool measures the same round-trip
            sw = cores if shape.startswith(PROCESS_SHAPES) else 2
            executors.append(("ws-socket", sw, lambda sw=sw: SocketPool(sw)))
        if not shape.startswith(STDLIB_UNSUPPORTED):
            executors.append(("stdlib", NUM_THREADS, lambda: StdlibExecutor(NUM_THREADS)))
        executors.append(("serial", 1, lambda: SerialExecutor()))
        for name, nthreads, make in executors:
            wall, cpu, ntasks = _time_graph(make, build, repeats)
            if name == "serial":
                serial_wall[shape] = wall
            rows.append(
                dict(
                    bench=shape,
                    executor=name,
                    threads=nthreads,
                    tasks=ntasks,
                    wall_ms=wall * 1e3,
                    cpu_ms=cpu * 1e3,
                    us_per_task=wall * 1e6 / ntasks,
                )
            )
        if shape.startswith(REPLAY_SHAPES):
            for t in thread_counts:
                wall, cpu, ntasks = _time_graph_replay(t, build, repeats)
                rows.append(
                    dict(
                        bench=shape,
                        executor="ws-replay",
                        threads=t,
                        tasks=ntasks,
                        wall_ms=wall * 1e3,
                        cpu_ms=cpu * 1e3,
                        us_per_task=wall * 1e6 / ntasks,
                    )
                )
    # dependency-counting overhead: scheduler cost over the serial floor.
    # The cpu-bound shape is compute- not dispatch-dominated: its "overhead"
    # would be parallel speedup noise, so it records speedup instead.
    for r in rows:
        floor = serial_wall.get(r["bench"])
        if floor is not None and not r["bench"].startswith(PROCESS_SHAPES):
            r["overhead_us_per_task"] = (r["wall_ms"] / 1e3 - floor) * 1e6 / r["tasks"]
    # §11 headline: process wall vs the *best* thread-backend wall of the
    # same shape (conservative: the fastest swept ws-fast row)
    best_thread: dict[str, float] = {}
    for r in rows:
        if r["executor"] == "ws-fast":
            b = best_thread.get(r["bench"])
            if b is None or r["wall_ms"] < b:
                best_thread[r["bench"]] = r["wall_ms"]
    for r in rows:
        if r["executor"] in ("ws-process", "ws-socket") and r["bench"].startswith(
            PROCESS_SHAPES
        ):
            if r["bench"] in best_thread:
                r["speedup_vs_thread"] = best_thread[r["bench"]] / r["wall_ms"]
            floor = serial_wall.get(r["bench"])
            if floor is not None:
                r["speedup_vs_serial"] = floor * 1e3 / r["wall_ms"]
    return rows


def record_trace(path: pathlib.Path, quick: bool) -> None:
    """One traced wavefront run on the work-stealing pool."""
    tracer = ChromeTraceObserver()
    n = 16 if quick else 32
    g = TaskGraph("wavefront-trace")
    build_wavefront(g, n)
    with ThreadPool(NUM_THREADS, observers=[tracer]) as pool:
        pool.run(g)
    tracer.save(path, num_workers=NUM_THREADS)
    print(f"wrote {path} ({n}x{n} wavefront; open in chrome://tracing)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="small sizes / fewer repeats (CI)")
    ap.add_argument("--out", default=str(pathlib.Path(__file__).parent.parent / "BENCH_graph.json"))
    ap.add_argument("--trace", default=None, help="also write a Chrome trace of a wavefront run")
    ap.add_argument(
        "--threads",
        default=str(NUM_THREADS),
        help="comma-separated worker counts to sweep the ws-fast pool over (default: 4)",
    )
    ap.add_argument(
        "--shape",
        default=None,
        help="only run shapes whose name starts with this prefix (e.g. cpu-bound)",
    )
    args = ap.parse_args()
    thread_counts = [int(t) for t in args.threads.split(",") if t.strip()]

    rows = run_bench(args.quick, thread_counts, args.shape)
    if not rows:
        print(f"no shape matches --shape {args.shape!r}")
        return 1

    print(
        f"{'bench':<26}{'executor':<12}{'thr':>4}{'tasks':>7}"
        f"{'wall_ms':>10}{'us/task':>9}{'ovh us/task':>13}{'vs thread':>11}"
    )
    for r in rows:
        speed = r.get("speedup_vs_thread")
        print(
            f"{r['bench']:<26}{r['executor']:<12}{r['threads']:>4}{r['tasks']:>7}"
            f"{r['wall_ms']:>10.2f}{r['us_per_task']:>9.2f}"
            f"{r.get('overhead_us_per_task', 0.0):>13.2f}"
            f"{(f'{speed:.2f}x' if speed else ''):>11}"
        )

    out = pathlib.Path(args.out)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(
        json.dumps(
            {
                "meta": {
                    "bench": "graph_bench",
                    "quick": args.quick,
                    "num_threads": NUM_THREADS,
                    "threads_swept": thread_counts,
                    "cpu_count": os.cpu_count(),
                    "shape_filter": args.shape,
                    "timestamp": time.time(),
                },
                "rows": rows,
            },
            indent=1,
        )
    )
    print(f"wrote {out}")

    if args.trace:
        record_trace(pathlib.Path(args.trace), args.quick)


if __name__ == "__main__":
    sys.exit(main())
