"""End-to-end training-driver tests: loss goes down, checkpoints commit
atomically, failure injection restarts and resumes bit-exact."""
import glob

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.runtime import Trainer, TrainerConfig


def tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=128, remat="none", dtype="float32",
    )


def test_loss_decreases(tmp_path):
    tcfg = TrainerConfig(num_steps=30, checkpoint_every=100, log_every=1,
                         seq_len=32, global_batch=8, lr=3e-3)
    with Trainer(tiny_cfg(), tcfg, str(tmp_path / "ckpt")) as tr:
        out = tr.run(resume=False)
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0] * 0.9, losses[:3] + losses[-3:]
    assert all(np.isfinite(l) for l in losses)


def test_checkpoint_commit_and_gc(tmp_path):
    tcfg = TrainerConfig(num_steps=25, checkpoint_every=5, log_every=10,
                         seq_len=16, global_batch=4, keep_checkpoints=2)
    with Trainer(tiny_cfg(), tcfg, str(tmp_path / "ckpt")) as tr:
        tr.run(resume=False)
        steps = tr.ckpt.steps()
    assert len(steps) <= 2  # keep-k GC
    assert steps[-1] == 25
    # no stray tmp dirs (atomic commit)
    assert not glob.glob(str(tmp_path / "ckpt" / "*.tmp"))


def test_failure_injection_restart_resumes_exactly(tmp_path):
    """Crash at step 12, restart, resume from step-10 checkpoint; final
    params must match an uninterrupted run (determinism of data + optimizer)."""
    base = dict(num_steps=20, checkpoint_every=5, log_every=100,
                seq_len=16, global_batch=4, lr=1e-3, seed=7)
    # uninterrupted reference
    with Trainer(tiny_cfg(), TrainerConfig(**base), str(tmp_path / "a")) as tr_a:
        ref = tr_a.run(resume=False)
    # interrupted + restarted
    with Trainer(tiny_cfg(), TrainerConfig(**base, fail_at_step=12), str(tmp_path / "b")) as tr_b:
        out = tr_b.run_with_restarts(max_restarts=2)
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_watchdog_fires():
    tcfg = TrainerConfig(num_steps=5, seq_len=16, global_batch=4,
                         heartbeat_timeout_s=0.0)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        with Trainer(tiny_cfg(), tcfg, d) as tr:
            tr._heartbeat -= 10  # pretend the last step was long ago
            with pytest.raises(TimeoutError):
                tr.run(resume=False)
