"""End-to-end engine tests: continuous-batched greedy decode must equal
sequential single-request decode token-for-token; eviction, cancellation and
input validation."""
import threading
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core import CancelledError, ThreadPool
from repro.models import build_model
from repro.models.lm import extend_caches
from repro.serve import RequestHandle, ServeEngine


def _build(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def sequential_decode(model, params, prompt, budget, width):
    """The pre-existing single-request path, provisioned at ``width`` KV
    capacity (the engine's max_len) so both programs mask identically."""
    logits, caches = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompt[None, :])})
    caches = extend_caches(caches, width - int(prompt.size))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    decode = jax.jit(model.decode_step)
    for i in range(budget - 1):
        logits, caches = decode(params, tok, caches, jnp.asarray(prompt.size + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def test_continuous_batching_matches_single_request_decode():
    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 28
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
        for n in rng.integers(3, 13, size=6)
    ]
    budgets = [int(b) for b in rng.integers(2, 9, size=6)]
    refs = [
        sequential_decode(model, params, p, b, MAX_LEN) for p, b in zip(prompts, budgets)
    ]
    with ServeEngine(
        model, params, max_slots=3, max_len=MAX_LEN, prefill_buckets=(8, 16)
    ) as engine:
        outs = engine.generate(prompts, budgets, timeout=300)
        stats = engine.stats()
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref  # token-for-token
    assert stats["completed"] == 6
    assert stats["kv"]["peak_live"] <= 3  # never exceeded the slot pool


def test_ssm_family_matches_single_request_decode():
    """Recurrent-state caches (no bucketing) through the same engine."""
    cfg, model, params = _build("mamba2-1.3b")
    MAX_LEN = 16
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(2)]
    refs = [sequential_decode(model, params, p, 4, MAX_LEN) for p in prompts]
    with ServeEngine(model, params, max_slots=2, max_len=MAX_LEN) as engine:
        outs = engine.generate(prompts, 4, timeout=300)
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref


def test_submit_async_matches_sync_submit():
    """§10 asyncio bridge: awaited generations equal the sync path and the
    event loop is never blocked by the pool."""
    import asyncio

    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 16
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(3)]
    with ServeEngine(model, params, max_slots=2, max_len=MAX_LEN) as engine:
        sync_outs = engine.generate(prompts, 4, timeout=300)

        async def main():
            return await asyncio.gather(
                *(engine.submit_async(p, 4) for p in prompts)
            )

        async_outs = asyncio.run(main())
    for s, a in zip(sync_outs, async_outs):
        assert list(map(int, a)) == list(map(int, s))


def test_capacity_eviction_truncates():
    cfg, model, params = _build("tinyllama-1.1b")
    with ServeEngine(model, params, max_slots=1, max_len=10) as engine:
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        h = engine.submit(prompt, max_new_tokens=50)  # cannot fit in 10
        out = h.result(300)
    assert h.truncated
    # feeds at positions 4..9 -> prefill token + 6 decode outputs
    assert len(out) == 7
    assert engine.stats()["truncations"] == 1
    assert engine.stats()["kv"]["evictions"] == 1


def test_cancel_waiting_request():
    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(model, params, max_slots=1, max_len=16, prefill_lookahead=0)
    try:
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        handles = [engine.submit(prompt, 6) for _ in range(4)]
        # the tail of the queue has not been admitted yet; cancel it
        cancelled = [h for h in reversed(handles) if h.cancel()]
        assert cancelled, "expected at least one still-waiting request"
        with pytest.raises(CancelledError):
            cancelled[0].result(5)
        # everyone else still completes
        done = [h for h in handles if h not in cancelled]
        for h in done:
            assert len(h.result(300)) == 6
        assert engine.stats()["completed"] == len(done)
    finally:
        engine.close(drain=False)


def test_rejects_unsupported_configs():
    cfg, model, params = _build("mamba2-1.3b")
    with pytest.raises(ValueError):  # SSM state would absorb pad tokens
        ServeEngine(model, params, prefill_buckets=(16,))
    cfg_e, model_e, _ = _build("whisper-medium")
    with pytest.raises(NotImplementedError):
        ServeEngine(model_e, None)


def test_validates_requests_and_shares_pool():
    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(2) as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=8, pool=pool)
        with pytest.raises(ValueError):
            engine.submit(np.zeros(0, np.int32), 2)  # empty prompt
        with pytest.raises(ValueError):
            engine.submit(np.zeros(4, np.int32), 0)  # no budget
        with pytest.raises(ValueError):
            engine.submit(np.zeros(8, np.int32), 2)  # prompt fills max_len
        out = engine.generate([np.arange(3, dtype=np.int32)], 2, timeout=300)
        assert len(out[0]) == 2
        engine.close()  # must not close the shared pool
        pool.run(lambda: None)  # still alive


def test_trace_path_emits_valid_chrome_trace(tmp_path):
    """A serve run with trace_path set writes trace-event JSON on close,
    with the prefill tasks and decode ticks visible as complete events."""
    import json

    cfg, model, params = _build("tinyllama-1.1b")
    trace_file = tmp_path / "serve_trace.json"
    with ServeEngine(
        model, params, max_slots=2, max_len=16, trace_path=str(trace_file)
    ) as engine:
        prompts = [np.arange(3, dtype=np.int32) % cfg.vocab_size for _ in range(2)]
        outs = engine.generate(prompts, 3, timeout=300)
        assert all(len(o) == 3 for o in outs)
    trace = json.loads(trace_file.read_text())
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert any(n.startswith("prefill:") for n in names)
    assert "decode-tick" in names


def test_replayed_tick_keeps_trace_and_stats_truthful(tmp_path):
    """The tick graph replays from its captured plan on every restart
    after the first (DESIGN.md §12); the Chrome trace must still show the
    decode ticks of the replayed passes as per-task complete events, and
    the engine/observer tick counts must agree."""
    import json

    cfg, model, params = _build("tinyllama-1.1b")
    trace_file = tmp_path / "serve_trace_replay.json"
    with ServeEngine(
        model, params, max_slots=2, max_len=16, trace_path=str(trace_file)
    ) as engine:
        prompt = np.arange(3, dtype=np.int32) % cfg.vocab_size
        # sequential generates with an explicit drain in between: results
        # resolve *inside* the tick body, so without the drain a fast next
        # submit can join the still-live run and no restart would happen —
        # the drain guarantees each later batch restarts the tick graph,
        # a §12 replay
        for _ in range(3):
            outs = engine.generate([prompt], 2, timeout=300)
            assert len(outs[0]) == 2
            engine.drain(60)
            engine.pool.wait_idle(30)
        # the pool quiesce also ensures the final tick's on_finish has
        # fired before the trace is written
        s = engine.stats()
    assert s["tick_replays"] >= 1  # at least one restart took the replay path
    trace = json.loads(trace_file.read_text())
    ticks = [e for e in trace["traceEvents"] if e["ph"] == "X" and e["name"] == "decode-tick"]
    # every tick is visible in the trace — live and replayed passes alike
    assert len(ticks) == s["ticks"]


def test_prefill_failure_readmits_waiting_requests():
    """Regression: a failed prefill frees admission capacity — requests
    still waiting behind it must be pumped, not stalled forever."""
    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(model, params, max_slots=1, max_len=16, prefill_lookahead=0)
    try:
        real_prefill = engine._prefill_jit
        POISON = np.full((3,), 1, np.int32)

        def flaky_prefill(p, batch, last_pos):
            if int(np.asarray(batch["tokens"]).sum()) == 3:  # the poison prompt
                raise RuntimeError("injected prefill failure")
            return real_prefill(p, batch, last_pos=last_pos)

        engine._prefill_jit = lambda p, batch, last_pos: flaky_prefill(p, batch, last_pos)
        bad = engine.submit(POISON, 4)
        good = engine.submit(np.arange(2, 6, dtype=np.int32) % cfg.vocab_size, 4)
        with pytest.raises(RuntimeError, match="injected prefill failure"):
            bad.result(60)
        assert len(good.result(120)) == 4  # admitted after the failure
        engine.drain(60)
    finally:
        engine.close(drain=False)


# ---------------------------------------------------------------------------
# §13: paged serving — streaming, deadlines, backpressure, preemption
# ---------------------------------------------------------------------------


def test_streaming_iterator_matches_result():
    """Tokens arrive per decode tick through the blocking iterator and match
    the final result; TTFT and per-token marks are recorded."""
    cfg, model, params = _build("tinyllama-1.1b")
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    with ServeEngine(model, params, max_slots=2, max_len=16) as engine:
        h = engine.submit(prompt, 6)
        streamed = list(h)  # blocks per token, ends at completion
        assert streamed == list(map(int, h.result(0)))
        assert len(streamed) == 6
        assert h.ttft is not None and h.ttft >= 0.0
        assert len(h.token_times) == 6
        assert h.token_times == sorted(h.token_times)
        assert h.first_token_t == h.token_times[0]
        # a second iteration replays the now-complete stream
        assert list(h) == streamed


def test_streaming_async_for():
    """§10 asyncio bridge: ``async for`` delivers the same tokens without
    blocking the event loop."""
    import asyncio

    cfg, model, params = _build("tinyllama-1.1b")
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, size=4).astype(np.int32) for _ in range(2)]
    with ServeEngine(model, params, max_slots=2, max_len=16) as engine:
        sync_outs = engine.generate(prompts, 5, timeout=300)

        async def consume(p):
            h = engine.submit(p, 5)
            return [tok async for tok in h]

        async def main():
            return await asyncio.gather(*(consume(p) for p in prompts))

        async_outs = asyncio.run(main())
    for s, a in zip(sync_outs, async_outs):
        assert a == list(map(int, s))


def test_deadline_miss_fails_fast():
    """A request whose deadline lapses before its prefill starts resolves
    with DeadlineExceeded; deadline-free traffic behind it still completes."""
    import threading as th

    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(1, name="serve-dl") as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=16, pool=pool)
        gate = th.Event()
        pool.submit(lambda: gate.wait(30))  # stall the only worker
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        doomed = engine.submit(prompt, 4, deadline=0.05)
        ok = engine.submit(prompt, 4)
        time.sleep(0.3)  # let the deadline lapse while the worker is held
        gate.set()
        from repro.serve import DeadlineExceeded

        with pytest.raises(DeadlineExceeded):
            doomed.result(60)
        assert len(ok.result(120)) == 4
        assert engine.stats()["deadline_misses"] == 1
        engine.drain(60)
        engine.close(drain=False)


def test_deadline_bands_promote_with_urgency():
    """§13 -> §9 mapping: waiting prefills are graded into priority bands by
    remaining deadline headroom; resumes are always urgent."""
    from repro.serve import PREFILL_PRIORITY, PREFILL_SOON, PREFILL_URGENT
    from repro.serve.engine import GenRequest, _Pending

    cfg, model, params = _build("tinyllama-1.1b")
    with ServeEngine(model, params, max_slots=1, max_len=8) as engine:
        req = GenRequest(np.arange(3, dtype=np.int32), 2, deadline=1.0)
        now = 100.0
        fresh = _Pending(object.__new__(RequestHandle), req, now + 1.0, 0)
        assert engine._band(fresh, now) == PREFILL_PRIORITY
        assert engine._band(fresh, now + 0.6) == PREFILL_SOON
        assert engine._band(fresh, now + 0.8) == PREFILL_URGENT
        nodeadline = _Pending(object.__new__(RequestHandle), GenRequest(req.prompt, 2), None, 1)
        assert engine._band(nodeadline, now) == PREFILL_PRIORITY
        resumed = _Pending(object.__new__(RequestHandle), req, now + 1.0, 2)
        resumed.tokens = [7]
        assert engine._band(resumed, now) == PREFILL_URGENT


def test_bounded_admit_queue_rejects_with_queue_full():
    """Backpressure: beyond max_waiting queued requests, submit raises
    QueueFull instead of growing the queue without bound."""
    import threading as th

    from repro.serve import QueueFull

    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(1, name="serve-bp") as pool:
        engine = ServeEngine(
            model, params, max_slots=1, max_len=16, pool=pool,
            prefill_lookahead=0, max_waiting=2,
        )
        gate = th.Event()
        pool.submit(lambda: gate.wait(30))
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        handles = [engine.submit(prompt, 3) for _ in range(3)]  # 1 inflight + 2 waiting
        with pytest.raises(QueueFull):
            engine.submit(prompt, 3)
        gate.set()
        for h in handles:
            assert len(h.result(120)) == 3
        st = engine.stats()
        assert st["rejected"] == 1
        assert st["completed"] == 3
        engine.close(drain=False)


def test_page_pressure_preempts_and_resumes_bit_identical():
    """The §13 tentpole invariant: with the page pool oversubscribed, the
    engine preempts the youngest resident to the admit queue and resumes it
    by re-prefill — and every request's tokens still equal the sequential
    single-request reference exactly. Preemption moves work, never drops it."""
    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 24
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(3)]
    budgets = [12, 11, 10]
    refs = [
        sequential_decode(model, params, p, b, MAX_LEN) for p, b in zip(prompts, budgets)
    ]
    # 2 residents x 6 pages/seq would need 12 pages; grant only 6 (the
    # one-full-sequence floor) so concurrent growth must hit page pressure
    # whichever order the prefills land in
    with ServeEngine(
        model, params, max_slots=2, max_len=MAX_LEN, page_size=4, num_pages=6
    ) as engine:
        outs = engine.generate(prompts, budgets, timeout=300)
        stats = engine.stats()
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref  # token-for-token across preemption
    assert stats["preemptions"] >= 1
    assert stats["completed"] == 3
    assert stats["kv"]["pages_live"] == 0  # everything returned to the pool


def test_close_drain_never_strands_racing_submits():
    """Regression (§13 satellite): ``close(drain=True)`` used to mark the
    engine closed only *after* draining, so a submit landing in that window
    was admitted onto a pool about to be torn down — its prefill was
    abandoned and the handle never resolved. Now close rejects first, then
    drains: every accepted handle must resolve."""
    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(model, params, max_slots=1, max_len=24)
    prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
    first = engine.submit(prompt, 16)  # a long decode keeps the tick busy
    accepted, stop = [first], False

    def spam():
        while not stop:
            try:
                accepted.append(engine.submit(prompt, 2))
            except RuntimeError:
                return  # engine closed: the race window is shut
            time.sleep(0.002)

    t = threading.Thread(target=spam)
    t.start()
    time.sleep(0.05)
    engine.close(drain=True)  # races the spammer
    stop = True
    t.join(60)
    with pytest.raises(RuntimeError):
        engine.submit(prompt, 2)  # closed engines reject
    for h in accepted:  # nobody stranded: every accepted handle resolved
        assert len(h.result(10)) >= 1


def test_close_drain_waits_for_queued_low_priority_prefill():
    """The documented race shape: a low-priority prefill queued behind the
    decode tick on a single worker. close(drain=True) must complete it, not
    abandon it."""
    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(1, name="serve-close") as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=24, pool=pool)
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        h1 = engine.submit(prompt, 12)  # decode tick occupies the worker
        h2 = engine.submit(prompt, 3)  # prefill waits at PREFILL_PRIORITY
        engine.close(drain=True)
        assert len(h1.result(5)) == 12
        assert len(h2.result(5)) == 3


def test_async_cancellation_before_first_token_frees_everything():
    """§13 satellite: cancelling the awaitable before the first token
    releases the request (no pages were or will be held) and the handle
    resolves with CancelledError — never with tokens."""
    import asyncio
    import threading as th

    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(1, name="serve-cx") as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=16, pool=pool)
        gate = th.Event()
        pool.submit(lambda: gate.wait(30))  # the prefill can never start
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size

        async def main():
            task = asyncio.ensure_future(engine.submit_async(prompt, 4))
            await asyncio.sleep(0.05)
            task.cancel()
            with pytest.raises(asyncio.CancelledError):
                await task

        asyncio.run(main())
        gate.set()
        engine.drain(60)
        st = engine.stats()
        assert st["completed"] == 0 and st["tokens_out"] == 0
        kvs = st["kv"]
        assert kvs["live"] == 0 and kvs["pages_live"] == 0
        assert kvs["page_allocs"] == 0  # never even touched the page pool
        engine.close(drain=False)


def test_cancel_mid_prefill_never_joins():
    """Cancelling while the prefill task is queued/running drops its result
    instead of joining the batch; the future resolves with CancelledError."""
    import threading as th

    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(1, name="serve-cx2") as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=16, pool=pool)
        gate = th.Event()
        pool.submit(lambda: gate.wait(30))
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        h = engine.submit(prompt, 4)  # prefill task queued behind the gate
        assert h.cancel()
        gate.set()
        with pytest.raises(CancelledError):
            h.result(30)
        engine.drain(60)
        assert engine.stats()["kv"]["pages_live"] == 0
        # the stream surface agrees: iteration raises, yields nothing
        with pytest.raises(CancelledError):
            list(h)
        engine.close(drain=False)


def test_prefill_circuit_breaker_trips_then_recovers():
    """§14 degradation: sustained prefill failure trips a circuit breaker —
    submissions during the cooldown fail fast with QueueFull instead of
    queueing doomed work — and a post-cooldown success re-closes it."""
    from repro.serve import QueueFull

    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(
        model, params, max_slots=1, max_len=16,
        prefill_retries=0, breaker_threshold=2, breaker_cooldown=0.5,
    )
    try:
        real_prefill = engine._prefill_jit
        engine._prefill_jit = lambda *a, **k: (_ for _ in ()).throw(
            RuntimeError("prefill is down")
        )
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        for _ in range(2):  # two consecutive exhausted failures -> trip
            h = engine.submit(prompt, 4)
            with pytest.raises(RuntimeError, match="prefill is down"):
                h.result(60)
        with pytest.raises(QueueFull, match="circuit breaker open"):
            engine.submit(prompt, 4)
        s = engine.stats()
        assert s["breaker_trips"] == 1
        assert s["rejected"] >= 1
        # heal the backend, wait out the cooldown: half-open admits again
        engine._prefill_jit = real_prefill
        time.sleep(0.6)
        good = engine.submit(prompt, 4)
        assert len(good.result(120)) == 4
        assert engine.stats()["breaker_trips"] == 1  # did not re-trip
    finally:
        engine.close(drain=False)


def test_transient_prefill_failures_leave_outputs_bit_identical():
    """§14 acceptance: with fault injection upstream of prefill, retried
    requests complete and their token streams are bit-identical to the
    sequential no-fault reference."""
    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 16
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(3)]
    refs = [sequential_decode(model, params, p, 4, MAX_LEN) for p in prompts]
    with ServeEngine(model, params, max_slots=2, max_len=MAX_LEN) as engine:
        real_prefill = engine._prefill_jit
        calls = [0]
        lock = threading.Lock()

        def flaky_prefill(p, batch, last_pos):
            with lock:
                calls[0] += 1
                fail = calls[0] <= 2  # first two attempts die in-flight
            if fail:
                raise RuntimeError("transient prefill fault")
            return real_prefill(p, batch, last_pos=last_pos)

        engine._prefill_jit = lambda p, batch, last_pos: flaky_prefill(p, batch, last_pos)
        outs = engine.generate(prompts, 4, timeout=300)
        s = engine.stats()
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref  # bit-identical despite retries
    assert s["pool"]["retries"] >= 2  # the recovery went through §14 retry
    assert s["breaker_trips"] == 0  # transient, never sustained
