"""End-to-end engine tests: continuous-batched greedy decode must equal
sequential single-request decode token-for-token; eviction, cancellation and
input validation."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core import CancelledError, ThreadPool
from repro.models import build_model
from repro.models.lm import extend_caches
from repro.serve import ServeEngine


def _build(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def sequential_decode(model, params, prompt, budget, width):
    """The pre-existing single-request path, provisioned at ``width`` KV
    capacity (the engine's max_len) so both programs mask identically."""
    logits, caches = jax.jit(model.prefill)(params, {"tokens": jnp.asarray(prompt[None, :])})
    caches = extend_caches(caches, width - int(prompt.size))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [int(tok[0, 0])]
    decode = jax.jit(model.decode_step)
    for i in range(budget - 1):
        logits, caches = decode(params, tok, caches, jnp.asarray(prompt.size + i, jnp.int32))
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    return out


def test_continuous_batching_matches_single_request_decode():
    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 28
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
        for n in rng.integers(3, 13, size=6)
    ]
    budgets = [int(b) for b in rng.integers(2, 9, size=6)]
    refs = [
        sequential_decode(model, params, p, b, MAX_LEN) for p, b in zip(prompts, budgets)
    ]
    with ServeEngine(
        model, params, max_slots=3, max_len=MAX_LEN, prefill_buckets=(8, 16)
    ) as engine:
        outs = engine.generate(prompts, budgets, timeout=300)
        stats = engine.stats()
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref  # token-for-token
    assert stats["completed"] == 6
    assert stats["kv"]["peak_live"] <= 3  # never exceeded the slot pool


def test_ssm_family_matches_single_request_decode():
    """Recurrent-state caches (no bucketing) through the same engine."""
    cfg, model, params = _build("mamba2-1.3b")
    MAX_LEN = 16
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(2)]
    refs = [sequential_decode(model, params, p, 4, MAX_LEN) for p in prompts]
    with ServeEngine(model, params, max_slots=2, max_len=MAX_LEN) as engine:
        outs = engine.generate(prompts, 4, timeout=300)
    for ref, out in zip(refs, outs):
        assert list(map(int, out)) == ref


def test_submit_async_matches_sync_submit():
    """§10 asyncio bridge: awaited generations equal the sync path and the
    event loop is never blocked by the pool."""
    import asyncio

    cfg, model, params = _build("tinyllama-1.1b")
    MAX_LEN = 16
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32) for _ in range(3)]
    with ServeEngine(model, params, max_slots=2, max_len=MAX_LEN) as engine:
        sync_outs = engine.generate(prompts, 4, timeout=300)

        async def main():
            return await asyncio.gather(
                *(engine.submit_async(p, 4) for p in prompts)
            )

        async_outs = asyncio.run(main())
    for s, a in zip(sync_outs, async_outs):
        assert list(map(int, a)) == list(map(int, s))


def test_capacity_eviction_truncates():
    cfg, model, params = _build("tinyllama-1.1b")
    with ServeEngine(model, params, max_slots=1, max_len=10) as engine:
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        h = engine.submit(prompt, max_new_tokens=50)  # cannot fit in 10
        out = h.result(300)
    assert h.truncated
    # feeds at positions 4..9 -> prefill token + 6 decode outputs
    assert len(out) == 7
    assert engine.stats()["truncations"] == 1
    assert engine.stats()["kv"]["evictions"] == 1


def test_cancel_waiting_request():
    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(model, params, max_slots=1, max_len=16, prefill_lookahead=0)
    try:
        prompt = np.arange(4, dtype=np.int32) % cfg.vocab_size
        handles = [engine.submit(prompt, 6) for _ in range(4)]
        # the tail of the queue has not been admitted yet; cancel it
        cancelled = [h for h in reversed(handles) if h.cancel()]
        assert cancelled, "expected at least one still-waiting request"
        with pytest.raises(CancelledError):
            cancelled[0].result(5)
        # everyone else still completes
        done = [h for h in handles if h not in cancelled]
        for h in done:
            assert len(h.result(300)) == 6
        assert engine.stats()["completed"] == len(done)
    finally:
        engine.close(drain=False)


def test_rejects_unsupported_configs():
    cfg, model, params = _build("mamba2-1.3b")
    with pytest.raises(ValueError):  # SSM state would absorb pad tokens
        ServeEngine(model, params, prefill_buckets=(16,))
    cfg_e, model_e, _ = _build("whisper-medium")
    with pytest.raises(NotImplementedError):
        ServeEngine(model_e, None)


def test_validates_requests_and_shares_pool():
    cfg, model, params = _build("tinyllama-1.1b")
    with ThreadPool(2) as pool:
        engine = ServeEngine(model, params, max_slots=1, max_len=8, pool=pool)
        with pytest.raises(ValueError):
            engine.submit(np.zeros(0, np.int32), 2)  # empty prompt
        with pytest.raises(ValueError):
            engine.submit(np.zeros(4, np.int32), 0)  # no budget
        with pytest.raises(ValueError):
            engine.submit(np.zeros(8, np.int32), 2)  # prompt fills max_len
        out = engine.generate([np.arange(3, dtype=np.int32)], 2, timeout=300)
        assert len(out[0]) == 2
        engine.close()  # must not close the shared pool
        pool.run(lambda: None)  # still alive


def test_trace_path_emits_valid_chrome_trace(tmp_path):
    """A serve run with trace_path set writes trace-event JSON on close,
    with the prefill tasks and decode ticks visible as complete events."""
    import json

    cfg, model, params = _build("tinyllama-1.1b")
    trace_file = tmp_path / "serve_trace.json"
    with ServeEngine(
        model, params, max_slots=2, max_len=16, trace_path=str(trace_file)
    ) as engine:
        prompts = [np.arange(3, dtype=np.int32) % cfg.vocab_size for _ in range(2)]
        outs = engine.generate(prompts, 3, timeout=300)
        assert all(len(o) == 3 for o in outs)
    trace = json.loads(trace_file.read_text())
    names = [e["name"] for e in trace["traceEvents"] if e["ph"] == "X"]
    assert any(n.startswith("prefill:") for n in names)
    assert "decode-tick" in names


def test_replayed_tick_keeps_trace_and_stats_truthful(tmp_path):
    """The tick graph replays from its captured plan on every restart
    after the first (DESIGN.md §12); the Chrome trace must still show the
    decode ticks of the replayed passes as per-task complete events, and
    the engine/observer tick counts must agree."""
    import json

    cfg, model, params = _build("tinyllama-1.1b")
    trace_file = tmp_path / "serve_trace_replay.json"
    with ServeEngine(
        model, params, max_slots=2, max_len=16, trace_path=str(trace_file)
    ) as engine:
        prompt = np.arange(3, dtype=np.int32) % cfg.vocab_size
        # sequential generates: the engine drains to idle in between, so
        # each later batch restarts the tick graph — a §12 replay
        for _ in range(3):
            outs = engine.generate([prompt], 2, timeout=300)
            assert len(outs[0]) == 2
        # token futures resolve *inside* the tick body: quiesce the pool so
        # the final tick's on_finish has fired before the trace is written
        engine.drain(60)
        engine.pool.wait_idle(30)
        s = engine.stats()
    assert s["tick_replays"] >= 1  # at least one restart took the replay path
    trace = json.loads(trace_file.read_text())
    ticks = [e for e in trace["traceEvents"] if e["ph"] == "X" and e["name"] == "decode-tick"]
    # every tick is visible in the trace — live and replayed passes alike
    assert len(ticks) == s["ticks"]


def test_prefill_failure_readmits_waiting_requests():
    """Regression: a failed prefill frees admission capacity — requests
    still waiting behind it must be pumped, not stalled forever."""
    cfg, model, params = _build("tinyllama-1.1b")
    engine = ServeEngine(model, params, max_slots=1, max_len=16, prefill_lookahead=0)
    try:
        real_prefill = engine._prefill_jit
        POISON = np.full((3,), 1, np.int32)

        def flaky_prefill(p, batch, last_pos):
            if int(np.asarray(batch["tokens"]).sum()) == 3:  # the poison prompt
                raise RuntimeError("injected prefill failure")
            return real_prefill(p, batch, last_pos=last_pos)

        engine._prefill_jit = lambda p, batch, last_pos: flaky_prefill(p, batch, last_pos)
        bad = engine.submit(POISON, 4)
        good = engine.submit(np.arange(2, 6, dtype=np.int32) % cfg.vocab_size, 4)
        with pytest.raises(RuntimeError, match="injected prefill failure"):
            bad.result(60)
        assert len(good.result(120)) == 4  # admitted after the failure
        engine.drain(60)
    finally:
        engine.close(drain=False)
