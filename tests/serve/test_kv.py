"""Slot cache tests: alloc/free/evict lifecycle, per-family pad walks,
ring re-layout, and write/read roundtrips through a real model prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.kv import PagedKVCache, SlotKVCache, pad_caches_to, ring_modulus


def _tiny_model(arch="tinyllama-1.1b"):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------


def test_alloc_free_exhaustion():
    _cfg, model, _params = _tiny_model()
    kv = SlotKVCache(model, max_slots=3, max_len=8)
    slots = [kv.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert kv.alloc() is None  # exhausted
    assert kv.num_free == 0 and kv.num_live == 3
    kv.free(1)
    assert kv.alloc() == 1  # freed slot is reused
    with pytest.raises(ValueError):
        kv.free(7)  # never allocated


def test_eviction_is_counted_and_reusable():
    _cfg, model, _params = _tiny_model()
    kv = SlotKVCache(model, max_slots=2, max_len=8)
    a = kv.alloc()
    kv.evict(a)
    assert kv.stats()["evictions"] == 1
    assert kv.alloc() == a  # evicted slot back in the pool
    assert kv.stats()["allocs"] == 2
    assert kv.stats()["peak_live"] == 1


# ---------------------------------------------------------------------------
# pad walks (per-family cache layout knowledge)
# ---------------------------------------------------------------------------


def test_pad_gqa_and_passthrough():
    node = {
        "attn": {"k": jnp.ones((2, 1, 4, 2, 3)), "v": jnp.ones((2, 1, 4, 2, 3))},
        "ssm": {"state": jnp.ones((1, 2, 3, 4)), "conv": jnp.ones((1, 8, 4))},
        "cross": {"k": jnp.ones((1, 5, 2, 3)), "v": jnp.ones((1, 5, 2, 3))},
    }
    out = pad_caches_to(node, 3)
    assert out["attn"]["k"].shape == (2, 1, 7, 2, 3)  # scan-stacked seq pad
    assert out["ssm"]["state"].shape == (1, 2, 3, 4)  # fixed-size passthrough
    assert out["cross"]["k"].shape == (1, 5, 2, 3)  # static encoder K/V


def test_pad_mla():
    node = {"attn": {"ckv": jnp.ones((1, 4, 6)), "krope": jnp.ones((1, 4, 2))}}
    out = pad_caches_to(node, 2)
    assert out["attn"]["ckv"].shape == (1, 6, 6)
    assert out["attn"]["krope"].shape == (1, 6, 2)
    # pad region is zero; original values preserved
    np.testing.assert_array_equal(np.asarray(out["attn"]["ckv"])[:, 4:], 0.0)
    np.testing.assert_array_equal(np.asarray(out["attn"]["ckv"])[:, :4], 1.0)


def test_ring_growth_relayout():
    # ring of modulus 3 holding positions [0, 1, 2] grows to modulus 5:
    # entry at position p must land at slot p % 5, empty slots pos == -1
    k = jnp.arange(3, dtype=jnp.float32).reshape(1, 3, 1, 1)
    node = {"attn": {"k": k, "v": k + 10, "pos": jnp.asarray([0, 1, 2], jnp.int32)}}
    out = pad_caches_to(node, 0, ring_w=5)["attn"]
    assert ring_modulus({"attn": out}) == 5
    np.testing.assert_array_equal(np.asarray(out["pos"]), [0, 1, 2, -1, -1])
    np.testing.assert_array_equal(np.asarray(out["k"]).ravel(), [0, 1, 2, 0, 0])
    np.testing.assert_array_equal(np.asarray(out["v"]).ravel(), [10, 11, 12, 0, 0])
    with pytest.raises(ValueError):
        pad_caches_to(node, 0, ring_w=2)  # shrink is invalid


# ---------------------------------------------------------------------------
# write/read roundtrip through a real prefill
# ---------------------------------------------------------------------------


def test_write_roundtrip_matches_prefill():
    cfg, model, params = _tiny_model()
    S, MAX = 6, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})

    kv = SlotKVCache(model, max_slots=2, max_len=MAX)
    slot = kv.alloc()
    kv.write(slot, cache, S)
    got = kv.read_slot(slot)

    def check(path_cache, path_got):
        if isinstance(path_cache, dict):
            for k in path_cache:
                check(path_cache[k], path_got[k])
            return
        a, b = np.asarray(path_cache), np.asarray(path_got)
        # seq axis was padded out to MAX; prefix must match exactly
        sl = [slice(None)] * b.ndim
        for ax in range(b.ndim):
            if a.shape[ax] != b.shape[ax]:
                sl[ax] = slice(0, a.shape[ax])
        np.testing.assert_array_equal(a, b[tuple(sl)])

    check(cache, got)


def test_write_rejects_dead_slot_and_overflow():
    cfg, model, params = _tiny_model()
    tokens = jnp.zeros((1, 4), jnp.int32)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    kv = SlotKVCache(model, max_slots=1, max_len=8)
    with pytest.raises(ValueError):
        kv.write(0, cache, 4)  # not allocated
    slot = kv.alloc()
    with pytest.raises(ValueError):
        kv.write(slot, cache, 9)  # exceeds max_len


# ---------------------------------------------------------------------------
# paged pool (DESIGN.md §13)
# ---------------------------------------------------------------------------


def test_paged_page_accounting():
    _cfg, model, _params = _tiny_model()
    kv = PagedKVCache(model, max_slots=3, max_len=24, page_size=8)
    assert kv.pages_per_seq == 3 and kv.num_pages == 9
    s = kv.alloc(kv.pages_for(5))  # 5 tokens -> 1 page
    assert kv.capacity_tokens(s) == 8 and kv.pages_live == 1
    assert kv.grow_to(s, 17)  # 3 pages
    assert kv.capacity_tokens(s) == 24
    assert not kv.grow_to(s, 25)  # beyond max_len
    t = kv.alloc(2)
    assert kv.pages_live == 5 and kv.free_pages == 4
    kv.free(t)
    assert kv.pages_live == 3 and kv.free_pages == 6
    st = kv.stats()
    assert st["page_allocs"] == 5 and st["page_frees"] == 2
    assert st["peak_pages_live"] == 5


def test_paged_grow_is_all_or_nothing():
    """Page pressure: a grow that cannot be fully served allocates nothing
    (the engine preempts instead of holding a partial claim)."""
    _cfg, model, _params = _tiny_model()
    kv = PagedKVCache(model, max_slots=2, max_len=16, page_size=4, num_pages=4)
    a = kv.alloc(1)
    b = kv.alloc(2)
    assert kv.free_pages == 1
    assert not kv.grow_to(a, 12)  # needs 2 more, only 1 free
    assert kv.capacity_tokens(a) == 4  # nothing was taken
    assert kv.grow_to(a, 8)  # 1 more page fits
    assert kv.free_pages == 0
    kv.free(b)
    assert kv.grow_to(a, 12)  # freed pages are reusable
    assert kv.alloc(1) == b  # the slot too


def test_paged_validates_sizing():
    _cfg, model, _params = _tiny_model()
    with pytest.raises(ValueError):  # pool cannot hold one full sequence
        PagedKVCache(model, max_slots=2, max_len=16, page_size=4, num_pages=3)
    kv = PagedKVCache(model, max_slots=1, max_len=6, page_size=64)
    assert kv.page_size == 6  # clamped to max_len
    assert kv.alloc(kv.pages_per_seq + 1) is None  # over per-seq table size


def test_paged_occupancy_and_fragmentation_stats():
    """§13 satellite: both cache layouts report page-occupancy and internal
    fragmentation; the paged layout's fragmentation is bounded by the page
    size while the flat layout reserves max_len whatever the need."""
    _cfg, model, _params = _tiny_model()
    MAX = 32
    flat = SlotKVCache(model, max_slots=2, max_len=MAX)
    paged = PagedKVCache(model, max_slots=2, max_len=MAX, page_size=8)
    for kv in (flat, paged):
        st = kv.stats()
        assert st["pages_live"] == 0 and st["page_occupancy"] == 0.0
        assert st["fragmentation"] == 0.0  # vacuously: nothing live

    fs = flat.alloc()
    flat.grow_to(fs, 10)  # a 10-token sequence in a 32-token slot
    st = flat.stats()
    assert st["page_size"] == MAX and st["pages_total"] == 2
    assert st["page_occupancy"] == 0.5
    assert st["fragmentation"] == pytest.approx(1 - 10 / 32)  # 22 wasted

    ps = paged.alloc(paged.pages_for(10))  # 2 pages of 8
    paged.grow_to(ps, 10)
    st = paged.stats()
    assert st["pages_live"] == 2 and st["page_occupancy"] == 2 / 8
    assert st["fragmentation"] == pytest.approx(1 - 10 / 16)  # only 6 wasted
    paged.free(ps)
    assert paged.stats()["fragmentation"] == 0.0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "mamba2-1.3b"])
def test_paged_write_read_matches_flat(arch):
    """Bit-identity invariant: a prefill written to pages and gathered back
    equals the flat slot layout exactly (zero page == zero padding)."""
    cfg, model, params = _tiny_model(arch)
    S, MAX = 5, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0, cfg.vocab_size)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})

    flat = SlotKVCache(model, max_slots=2, max_len=MAX)
    paged = PagedKVCache(model, max_slots=2, max_len=MAX, page_size=4)
    fs, ps = flat.alloc(), paged.alloc(paged.pages_for(S))
    flat.write(fs, cache, S)
    paged.write(ps, cache, S)
    a, b = flat.read_slot(fs), paged.read_slot(ps)
    eq = jax.tree.map(lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()), a, b)
    assert all(jax.tree.leaves(eq)), eq


def test_paged_write_validates():
    cfg, model, params = _tiny_model()
    tokens = jnp.zeros((1, 4), jnp.int32)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    kv = PagedKVCache(model, max_slots=1, max_len=8, page_size=4)
    with pytest.raises(ValueError):
        kv.write(0, cache, 4)  # not allocated
    slot = kv.alloc(1)
    with pytest.raises(ValueError):
        kv.write(slot, cache, 9)  # exceeds max_len
    with pytest.raises(ValueError):
        kv.write(slot, cache, 8)  # needs 2 pages, slot holds 1
