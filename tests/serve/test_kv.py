"""Slot cache tests: alloc/free/evict lifecycle, per-family pad walks,
ring re-layout, and write/read roundtrips through a real model prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import build_model
from repro.serve.kv import SlotKVCache, pad_caches_to, ring_modulus


def _tiny_model(arch="tinyllama-1.1b"):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# slot lifecycle
# ---------------------------------------------------------------------------


def test_alloc_free_exhaustion():
    _cfg, model, _params = _tiny_model()
    kv = SlotKVCache(model, max_slots=3, max_len=8)
    slots = [kv.alloc() for _ in range(3)]
    assert sorted(slots) == [0, 1, 2]
    assert kv.alloc() is None  # exhausted
    assert kv.num_free == 0 and kv.num_live == 3
    kv.free(1)
    assert kv.alloc() == 1  # freed slot is reused
    with pytest.raises(ValueError):
        kv.free(7)  # never allocated


def test_eviction_is_counted_and_reusable():
    _cfg, model, _params = _tiny_model()
    kv = SlotKVCache(model, max_slots=2, max_len=8)
    a = kv.alloc()
    kv.evict(a)
    assert kv.stats()["evictions"] == 1
    assert kv.alloc() == a  # evicted slot back in the pool
    assert kv.stats()["allocs"] == 2
    assert kv.stats()["peak_live"] == 1


# ---------------------------------------------------------------------------
# pad walks (per-family cache layout knowledge)
# ---------------------------------------------------------------------------


def test_pad_gqa_and_passthrough():
    node = {
        "attn": {"k": jnp.ones((2, 1, 4, 2, 3)), "v": jnp.ones((2, 1, 4, 2, 3))},
        "ssm": {"state": jnp.ones((1, 2, 3, 4)), "conv": jnp.ones((1, 8, 4))},
        "cross": {"k": jnp.ones((1, 5, 2, 3)), "v": jnp.ones((1, 5, 2, 3))},
    }
    out = pad_caches_to(node, 3)
    assert out["attn"]["k"].shape == (2, 1, 7, 2, 3)  # scan-stacked seq pad
    assert out["ssm"]["state"].shape == (1, 2, 3, 4)  # fixed-size passthrough
    assert out["cross"]["k"].shape == (1, 5, 2, 3)  # static encoder K/V


def test_pad_mla():
    node = {"attn": {"ckv": jnp.ones((1, 4, 6)), "krope": jnp.ones((1, 4, 2))}}
    out = pad_caches_to(node, 2)
    assert out["attn"]["ckv"].shape == (1, 6, 6)
    assert out["attn"]["krope"].shape == (1, 6, 2)
    # pad region is zero; original values preserved
    np.testing.assert_array_equal(np.asarray(out["attn"]["ckv"])[:, 4:], 0.0)
    np.testing.assert_array_equal(np.asarray(out["attn"]["ckv"])[:, :4], 1.0)


def test_ring_growth_relayout():
    # ring of modulus 3 holding positions [0, 1, 2] grows to modulus 5:
    # entry at position p must land at slot p % 5, empty slots pos == -1
    k = jnp.arange(3, dtype=jnp.float32).reshape(1, 3, 1, 1)
    node = {"attn": {"k": k, "v": k + 10, "pos": jnp.asarray([0, 1, 2], jnp.int32)}}
    out = pad_caches_to(node, 0, ring_w=5)["attn"]
    assert ring_modulus({"attn": out}) == 5
    np.testing.assert_array_equal(np.asarray(out["pos"]), [0, 1, 2, -1, -1])
    np.testing.assert_array_equal(np.asarray(out["k"]).ravel(), [0, 1, 2, 0, 0])
    np.testing.assert_array_equal(np.asarray(out["v"]).ravel(), [10, 11, 12, 0, 0])
    with pytest.raises(ValueError):
        pad_caches_to(node, 0, ring_w=2)  # shrink is invalid


# ---------------------------------------------------------------------------
# write/read roundtrip through a real prefill
# ---------------------------------------------------------------------------


def test_write_roundtrip_matches_prefill():
    cfg, model, params = _tiny_model()
    S, MAX = 6, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})

    kv = SlotKVCache(model, max_slots=2, max_len=MAX)
    slot = kv.alloc()
    kv.write(slot, cache, S)
    got = kv.read_slot(slot)

    def check(path_cache, path_got):
        if isinstance(path_cache, dict):
            for k in path_cache:
                check(path_cache[k], path_got[k])
            return
        a, b = np.asarray(path_cache), np.asarray(path_got)
        # seq axis was padded out to MAX; prefix must match exactly
        sl = [slice(None)] * b.ndim
        for ax in range(b.ndim):
            if a.shape[ax] != b.shape[ax]:
                sl[ax] = slice(0, a.shape[ax])
        np.testing.assert_array_equal(a, b[tuple(sl)])

    check(cache, got)


def test_write_rejects_dead_slot_and_overflow():
    cfg, model, params = _tiny_model()
    tokens = jnp.zeros((1, 4), jnp.int32)
    _logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    kv = SlotKVCache(model, max_slots=1, max_len=8)
    with pytest.raises(ValueError):
        kv.write(0, cache, 4)  # not allocated
    slot = kv.alloc()
    with pytest.raises(ValueError):
        kv.write(slot, cache, 9)  # exceeds max_len
