"""Flash-attention kernel vs dense oracle: shape/dtype/mask sweeps
(interpret mode on CPU; the kernel targets TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.ref import attention_ref


def _mk(key, B, H, KV, Sq, Sk, Dh, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, H, Sq, Dh), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, KV, Sk, Dh), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, KV, Sk, Dh), jnp.float32).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,Dh,bq,bk",
    [
        (1, 2, 2, 128, 64, 64, 64),  # MHA
        (2, 4, 2, 128, 64, 64, 32),  # GQA group 2
        (1, 8, 1, 256, 32, 128, 128),  # MQA
        (1, 2, 2, 64, 128, 64, 64),  # single q block
    ],
)
def test_causal_allclose(dtype, B, H, KV, S, Dh, bq, bk):
    q, k, v = _mk(jax.random.PRNGKey(0), B, H, KV, S, S, Dh, dtype)
    got = flash_attention_bhsd(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=TOL[dtype], rtol=TOL[dtype]
    )


@pytest.mark.parametrize("window", [16, 64, 100])
def test_sliding_window_allclose(window):
    q, k, v = _mk(jax.random.PRNGKey(1), 1, 2, 2, 128, 128, 64, jnp.float32)
    got = flash_attention_bhsd(
        q, k, v, causal=True, window=window, block_q=32, block_k=32, interpret=True
    )
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_bidirectional_allclose():
    q, k, v = _mk(jax.random.PRNGKey(2), 1, 2, 2, 64, 64, 32, jnp.float32)
    got = flash_attention_bhsd(q, k, v, causal=False, block_q=32, block_k=32, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_k_len_masks_padded_keys():
    q, k, v = _mk(jax.random.PRNGKey(3), 1, 2, 2, 64, 128, 32, jnp.float32)
    got = flash_attention_bhsd(
        q, k, v, causal=False, k_len=100, block_q=32, block_k=32, interpret=True
    )
    want = attention_ref(q, k, v, causal=False, k_len=100)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
    # and the padded tail genuinely doesn't matter
    v2 = v.at[:, :, 100:].set(1e6)
    got2 = flash_attention_bhsd(
        q, k, v2, causal=False, k_len=100, block_q=32, block_k=32, interpret=True
    )
    np.testing.assert_allclose(got2, want, atol=2e-5, rtol=2e-5)


def test_cross_attention_rectangular():
    q, k, v = _mk(jax.random.PRNGKey(4), 2, 4, 4, 64, 192, 32, jnp.float32)
    got = flash_attention_bhsd(q, k, v, causal=False, block_q=32, block_k=64, interpret=True)
    want = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_model_layout_wrapper():
    from repro.kernels import ops

    B, S, H, KV, Dh = 2, 128, 4, 2, 64
    key = jax.random.PRNGKey(5)
    q = jax.random.normal(key, (B, S, H, Dh), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, Dh), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), causal=True
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)
