"""SSD kernel vs the pure-jnp chunked-scan oracle and a naive recurrence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import ssd_reference
from repro.kernels.ssd import ssd_bshp


def _mk(key, B, S, H, P, N, dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    x = jax.random.normal(k1, (B, S, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(k2, (B, S, H), jnp.float32))
    A = -jnp.exp(jax.random.uniform(k3, (H,), jnp.float32, 0.0, 1.0))
    Bm = jax.random.normal(k4, (B, S, N), jnp.float32).astype(dtype)
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (B, S, N), jnp.float32).astype(dtype)
    return x, dt, A, Bm, Cm


def naive_recurrence(x, dt, A, Bm, Cm):
    """Literal per-token state recurrence (the semantic ground truth)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        dA = jnp.exp(dtt * A)  # (B,H)
        state = state * dA[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", bt, xt * dtt[..., None]
        )
        y = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, y

    init = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (
        x.astype(jnp.float32).transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        Bm.astype(jnp.float32).transpose(1, 0, 2),
        Cm.astype(jnp.float32).transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, init, xs)
    return ys.transpose(1, 0, 2, 3)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_reference_matches_naive_recurrence(chunk):
    x, dt, A, Bm, Cm = _mk(jax.random.PRNGKey(0), 2, 32, 3, 8, 16)
    want = naive_recurrence(x, dt, A, Bm, Cm)
    got = ssd_reference(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize(
    "B,S,H,P,N,chunk",
    [
        (1, 64, 2, 16, 16, 16),
        (2, 128, 4, 32, 32, 32),
        (1, 128, 8, 64, 128, 64),  # mamba2-1.3b-like tile
        (2, 96, 3, 16, 24, 32),  # uneven heads / N
    ],
)
def test_kernel_matches_reference(B, S, H, P, N, chunk):
    x, dt, A, Bm, Cm = _mk(jax.random.PRNGKey(1), B, S, H, P, N)
    want = ssd_reference(x, dt, A, Bm, Cm, chunk=chunk)
    got = ssd_bshp(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_kernel_bf16_inputs():
    x, dt, A, Bm, Cm = _mk(jax.random.PRNGKey(2), 1, 64, 2, 16, 16, dtype=jnp.bfloat16)
    want = ssd_reference(x, dt, A, Bm, Cm, chunk=16)
    got = ssd_bshp(x, dt, A, Bm, Cm, chunk=16, interpret=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=5e-2, rtol=5e-2
    )


def test_final_state_consistency_with_decode_steps():
    """Chunked-scan final state must equal the state after S decode steps
    (prefill→decode handoff correctness)."""
    from repro.models.ssm import ssd_decode_step

    x, dt, A, Bm, Cm = _mk(jax.random.PRNGKey(3), 1, 16, 2, 8, 8)
    _, final = ssd_reference(x, dt, A, Bm, Cm, chunk=8, return_final_state=True)
    state = jnp.zeros((1, 2, 8, 8), jnp.float32)
    for t in range(16):
        _, state = ssd_decode_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], state)
    np.testing.assert_allclose(final, state, atol=1e-4, rtol=1e-4)
