"""AdamW vs a literal numpy reference; schedule and masking properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fallback shim; requirements-dev.txt pins the real one
    from repro.testing import given, settings, st

from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule, global_norm


def numpy_adamw(cfg, lr, params, grads, m, v, count):
    """Textbook AdamW (decoupled weight decay), f32."""
    count = count + 1
    gn = np.sqrt(sum((g.astype(np.float64) ** 2).sum() for g in grads.values()))
    scale = min(1.0, cfg.grad_clip / max(gn, 1e-12)) if cfg.grad_clip else 1.0
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k] * scale
        m1 = cfg.b1 * m[k] + (1 - cfg.b1) * g
        v1 = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mhat = m1 / (1 - cfg.b1**count)
        vhat = v1 / (1 - cfg.b2**count)
        step = mhat / (np.sqrt(vhat) + cfg.eps)
        if params[k].ndim >= 2 and cfg.weight_decay:
            step = step + cfg.weight_decay * params[k]
        out_p[k] = params[k] - lr * step
        out_m[k], out_v[k] = m1, v1
    return out_p, out_m, out_v


@pytest.mark.parametrize("wd", [0.0, 0.1])
def test_matches_numpy_reference(wd):
    cfg = AdamWConfig(lr=1e-2, weight_decay=wd, grad_clip=1.0, keep_master=False)
    rng = np.random.default_rng(0)
    params = {"w": rng.standard_normal((4, 3)).astype(np.float32),
              "b": rng.standard_normal((3,)).astype(np.float32)}
    jp = jax.tree.map(jnp.asarray, params)
    state = adamw_init(cfg, jp)
    m = {k: np.zeros_like(p) for k, p in params.items()}
    v = {k: np.zeros_like(p) for k, p in params.items()}
    np_p = dict(params)
    for step in range(5):
        grads = {k: rng.standard_normal(p.shape).astype(np.float32) for k, p in params.items()}
        jp, state, _ = adamw_update(
            cfg, jnp.asarray(1e-2), jp, jax.tree.map(jnp.asarray, grads), state
        )
        np_p, m, v = numpy_adamw(cfg, 1e-2, np_p, grads, m, v, step)
    for k in params:
        np.testing.assert_allclose(np.asarray(jp[k]), np_p[k], atol=1e-5, rtol=1e-4)


def test_grad_clip_engages():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0, keep_master=False)
    p = {"w": jnp.zeros((4, 4))}
    huge = {"w": jnp.full((4, 4), 1e6)}
    state = adamw_init(cfg, p)
    p2, _, metrics = adamw_update(cfg, jnp.asarray(1.0), p, huge, state)
    assert float(metrics["grad_norm"]) > 1e6
    # post-clip first step magnitude is bounded by lr / (1 + eps-ish)
    assert float(jnp.max(jnp.abs(p2["w"]))) <= 1.001


def test_master_weights_carry_precision():
    """bf16 params + fp32 master: tiny updates accumulate instead of
    vanishing in bf16 rounding."""
    cfg = AdamWConfig(lr=1e-5, weight_decay=0.0, grad_clip=0.0, keep_master=True)
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    state = adamw_init(cfg, p)
    g = {"w": jnp.full((8, 8), 1e-3, jnp.bfloat16)}
    master0 = np.asarray(state["master"]["w"], np.float64).mean()
    for _ in range(3):
        p, state, _ = adamw_update(cfg, jnp.asarray(1e-5), p, g, state)
    master1 = np.asarray(state["master"]["w"], np.float64).mean()
    assert master1 < master0  # monotone drift recorded in fp32


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 500), st.integers(10, 100), st.integers(200, 2000))
def test_cosine_schedule_properties(step, warmup, total):
    lr_fn = cosine_schedule(1.0, warmup, total, min_frac=0.1)
    lr = float(lr_fn(jnp.asarray(step)))
    assert 0.0 <= lr <= 1.0 + 1e-6
    if step >= total:
        assert lr == pytest.approx(0.1, rel=1e-3)  # floor
    if step < warmup:
        assert lr == pytest.approx(step / warmup, rel=1e-4)


def test_global_norm():
    t = {"a": jnp.ones((3,)) * 2.0, "b": jnp.ones((4,)) * 1.0}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(12 + 4))
