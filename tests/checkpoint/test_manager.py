"""Checkpoint manager tests: the composed dataflow save graph (DESIGN.md
§8), atomic commit, keep-k GC, and elastic restore."""
import json

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree():
    return {
        "w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "opt": {"m": np.ones((5,), np.float32), "step": np.asarray(7, np.int32)},
    }


def test_sync_save_load_roundtrip(tmp_path):
    tree = _tree()
    save_pytree(tree, tmp_path / "ck", meta={"note": "x"})
    out = load_pytree(tmp_path / "ck", tree)
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(out["opt"]["m"]), tree["opt"]["m"])


def test_async_save_composed_graph_and_restore(tmp_path):
    """save_async runs prepare -> composed shard writers -> commit as one
    dataflow graph; the manifest is assembled from the writers' returned
    entries (value-passing), not from a shared dict."""
    tree = _tree()
    with CheckpointManager(tmp_path, keep=3) as mgr:
        mgr.save_async(5, tree, meta={"lr": 0.25})
        mgr.wait()
        assert mgr.steps() == [5]
        manifest = json.loads((tmp_path / "step_00000005" / "manifest.json").read_text())
        assert set(manifest["leaves"]) == {"w", "opt.m", "opt.step"}
        assert manifest["meta"] == {"lr": 0.25, "step": 5}
        restored, meta = mgr.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
        assert int(np.asarray(restored["opt"]["step"])) == 7
        assert meta["step"] == 5


def test_keep_k_gc(tmp_path):
    tree = {"a": np.zeros((2,), np.float32)}
    with CheckpointManager(tmp_path, keep=2) as mgr:
        for step in (1, 2, 3, 4):
            mgr.save_async(step, tree)
        mgr.wait()
        assert mgr.steps() == [3, 4]
        assert mgr.latest_step() == 4


def test_no_tmp_residue_after_commit(tmp_path):
    with CheckpointManager(tmp_path) as mgr:
        mgr.save_async(1, {"a": np.zeros((2,), np.float32)})
        mgr.wait()
    residue = [p.name for p in tmp_path.iterdir() if ".tmp" in p.name]
    assert residue == []


def test_restore_missing_raises(tmp_path):
    with CheckpointManager(tmp_path) as mgr:
        with pytest.raises(FileNotFoundError):
            mgr.restore({"a": np.zeros((1,), np.float32)})


def test_empty_tree_save(tmp_path):
    """Regression: an empty pytree save must not commit before prepare."""
    with CheckpointManager(tmp_path) as mgr:
        mgr.save_async(1, {})
        mgr.wait()
        assert mgr.steps() == [1]
        manifest = json.loads((tmp_path / "step_00000001" / "manifest.json").read_text())
        assert manifest["leaves"] == {}


def test_wait_scoped_to_own_saves_on_busy_shared_pool(tmp_path):
    """§10: wait() watches this manager's save futures, not pool-wide
    quiescence — another resident keeping the shared pool busy must not
    time out a wait whose saves are already durable."""
    import threading

    from repro.core import ThreadPool

    release = threading.Event()
    with ThreadPool(2) as pool:
        pool.submit(lambda: release.wait(30))  # unrelated long-running work
        try:
            with CheckpointManager(tmp_path, pool=pool) as mgr:
                mgr.save_async(3, {"a": np.arange(4, dtype=np.float32)})
                mgr.wait(timeout=30)  # must succeed despite the busy pool
                assert mgr.steps() == [3]
        finally:
            release.set()
        pool.wait_idle(10)


def test_async_save_process_backend_roundtrip(tmp_path):
    """backend="process": shard writers run in worker processes (the §10
    subflow is wired for remote dispatch at spawn time); the manifest,
    atomic commit and restore behave identically."""
    tree = _tree()
    with CheckpointManager(tmp_path, keep=2, backend="process") as mgr:
        mgr.save_async(11, tree, meta={"lr": 0.5})
        mgr.wait()
        assert mgr.steps() == [11]
        manifest = json.loads(
            (tmp_path / "step_00000011" / "manifest.json").read_text()
        )
        assert set(manifest["leaves"]) == {"w", "opt.m", "opt.step"}
        restored, meta = mgr.restore(tree)
        assert meta == {"lr": 0.5, "step": 11}
        np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
        np.testing.assert_array_equal(np.asarray(restored["opt"]["m"]), tree["opt"]["m"])


def test_manager_rejects_pool_plus_backend(tmp_path):
    from repro.core import ThreadPool

    with ThreadPool(1) as tp:
        with pytest.raises(ValueError, match="not both"):
            CheckpointManager(tmp_path, pool=tp, backend="process")
