"""Multi-device correctness tests.

These run in SUBPROCESSES with ``--xla_force_host_platform_device_count=8``
so the main pytest process keeps its single-device jax (the dry-run is the
only place that touches 512 devices, per the assignment).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def run_devices(code: str, n: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-4000:]}"
    return out.stdout


def test_sharded_train_step_matches_single_device():
    """A (2 data x 4 model) sharded train step must match the single-device
    step numerically (same loss, same updated params)."""
    run_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
    from repro.parallel.steps import build_train_step

    cfg = get_reduced("tinyllama-1.1b").replace(dtype="float32", remat="none")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    ocfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(ocfg, params)
    lr_fn = cosine_schedule(1e-3, 10, 100)

    # single-device reference
    def ref_step(params, opt, batch, step):
        (loss, m), g = jax.value_and_grad(lambda p: model.loss(p, batch), has_aux=True)(params)
        p2, o2, _ = adamw_update(ocfg, lr_fn(step), params, g, opt)
        return p2, o2, loss
    rp, ro, rloss = jax.jit(ref_step)(params, opt, batch, jnp.asarray(0))

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    spec = {"seq_len": S, "global_batch": B, "kind": "train"}
    step, shardings, abstract = build_train_step(
        model, mesh, ocfg, lr_fn, model.input_specs("train_4k", spec), donate=False)
    sp, so, metrics = step(params, opt, batch, jnp.asarray(0))
    np.testing.assert_allclose(float(metrics["loss"]), float(rloss), rtol=2e-5)
    flat_r = jax.tree.leaves(rp)
    flat_s = jax.tree.leaves(sp)
    for a, b in zip(flat_r, flat_s):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5, rtol=2e-4)
    print("TRAIN-STEP-MATCH-OK")
    """)


def test_moe_ep_matches_dense_oracle():
    """Expert-parallel shard_map MoE == dense-oracle MoE (fwd AND grads)."""
    run_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.models.common import Alloc
    from repro.models.moe import moe_params, moe_dense, moe_ep
    from repro.parallel.ctx import ParallelCtx

    cfg = ModelConfig(name="m", family="moe", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=0, vocab_size=64, num_experts=8,
                      experts_per_token=2, moe_d_ff=16, num_shared_experts=1,
                      capacity_factor=8.0,  # no drops -> exact equality
                      dtype="float32")
    a = Alloc("init", jax.random.PRNGKey(0), dtype=jnp.float32)
    p = moe_params(cfg, a)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelCtx(mesh, batch_axes=("data",))
    B, S, d = 4, 8, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32)

    def f_dense(p, x):
        y, aux = moe_dense(cfg, p, x)
        return jnp.sum(y * y) + aux
    def f_ep(p, x):
        y, aux = moe_ep(cfg, p, x, ctx)
        return jnp.sum(y * y) + aux

    yd, gd = jax.value_and_grad(f_dense)(p, x)
    ye, ge = jax.value_and_grad(f_ep)(p, x)
    np.testing.assert_allclose(float(yd), float(ye), rtol=1e-5)
    for ad, ae in zip(jax.tree.leaves(gd), jax.tree.leaves(ge)):
        np.testing.assert_allclose(np.asarray(ad), np.asarray(ae), atol=1e-4, rtol=1e-3)
    print("MOE-EP-MATCH-OK")
    """)


def test_elastic_checkpoint_restore_across_meshes():
    """Save params sharded on a (4,2) mesh, restore onto (2,4) and (8,1)."""
    run_devices("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,), jnp.bfloat16)}
    with tempfile.TemporaryDirectory() as d:
        mesh1 = jax.make_mesh((4, 2), ("data", "model"))
        t1 = jax.device_put(tree, {"w": NamedSharding(mesh1, P("data", "model")),
                                   "b": NamedSharding(mesh1, P("data"))})
        with CheckpointManager(d, keep=2) as cm:
            cm.save_async(5, t1, meta={"step": 5})
            cm.wait()
            mesh2 = jax.make_mesh((2, 4), ("data", "model"))
            shard2 = {"w": NamedSharding(mesh2, P("model", "data")), "b": None}
            restored, meta = cm.restore(tree, shardings=shard2)
            assert meta["step"] == 5
            np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
            np.testing.assert_array_equal(
                np.asarray(restored["b"], np.float32), np.ones(8, np.float32))
    print("ELASTIC-OK")
    """)


def test_pipeline_parallel_matches_serial():
    """Task-graph-scheduled pipeline (4 stages over 'pod') == serial model."""
    run_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import build_pipelined_loss, forward_tick_table

    S, M, W = 4, 8, 16  # stages, microbatches, width
    mesh = jax.make_mesh((4,), ("pod",))
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, W, W)) * 0.3,
              "b": jnp.zeros((S, W))}

    def stage_fn(p, x):  # residual MLP stage
        return x + jnp.tanh(x @ p["w"] + p["b"])

    def loss_fn(x, y):
        return jnp.mean((x - y) ** 2)

    x_mb = jax.random.normal(jax.random.PRNGKey(1), (M, 4, W))
    y_mb = jax.random.normal(jax.random.PRNGKey(2), (M, 4, W))

    # serial reference
    def serial_loss(params, x_mb, y_mb):
        def apply_all(x):
            for s in range(S):
                x = stage_fn(jax.tree.map(lambda l: l[s], params), x)
            return x
        losses = jax.vmap(lambda x, y: loss_fn(apply_all(x), y))(x_mb, y_mb)
        return jnp.mean(losses)

    ref, ref_grad = jax.value_and_grad(serial_loss)(params, x_mb, y_mb)

    pipe_loss, table = build_pipelined_loss(
        stage_fn, loss_fn, mesh, axis="pod", num_microbatches=M)
    got, got_grad = jax.value_and_grad(pipe_loss)(params, x_mb, y_mb)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(ref_grad), jax.tree.leaves(got_grad)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)
    # schedule sanity: the table came from the paper's scheduler
    assert table.shape[1] == S and (table >= -1).all()
    print("PIPELINE-OK")
    """)


def test_decode_step_sharded_matches_single_device():
    run_devices("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_reduced
    from repro.models import build_model
    from repro.models.lm import extend_caches
    from repro.parallel.steps import build_decode_step

    cfg = get_reduced("granite-moe-1b-a400m").replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits0, caches = jax.jit(model.prefill)(params, {"tokens": tokens})
    caches = extend_caches(caches, 4)
    tok = jnp.zeros((B, 1), jnp.int32)
    ref_logits, _ = jax.jit(model.decode_step)(params, tok, caches, jnp.asarray(S))

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    abstract = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
                "caches": jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches),
                "index": jax.ShapeDtypeStruct((), jnp.int32)}
    step, shardings = build_decode_step(model, mesh, abstract)
    got_logits, _ = step(params, tok, caches, jnp.asarray(S))
    np.testing.assert_allclose(
        np.asarray(got_logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=2e-4, rtol=2e-3)
    print("DECODE-MATCH-OK")
    """)
