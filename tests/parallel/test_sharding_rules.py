"""Unit tests for the logical-axis sharding rules (fast, single device)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model
from repro.parallel.sharding import (
    estimate_padding_waste,
    param_specs,
    rules_for,
    spec_for,
    zero_spec,
)


class FakeMesh:
    """Just enough of a Mesh for spec_for (shape lookup)."""

    def __init__(self, **shape):
        self.shape = shape


MESH = FakeMesh(data=16, model=16)


def test_divisible_dims_shard_on_preferred_axis():
    rules = rules_for(get_config("tinyllama-1.1b"))
    # d_ff 5632/16 ok
    assert spec_for(("embed", "mlp"), (2048, 5632), rules, MESH) == P(None, "model")
    # vocab 32000/16 ok
    assert spec_for(("vocab", "embed"), (32000, 2048), rules, MESH) == P("model", None)


def test_awkward_dims_fall_back_to_row_parallel():
    rules = rules_for(get_config("tinyllama-1.1b"))
    # 56 heads not divisible -> model lands on the embed dim instead
    spec = spec_for(("embed", "heads", None), (7168, 56, 128), rules, MESH)
    assert spec == P("model", None, None)
    # layers dim is never sharded, even as fallback (head_dim 128 is picked)
    spec = spec_for(("layers", "heads", None), (62, 56, 128), rules, MESH)
    assert tuple(spec)[0] is None and tuple(spec) == (None, None, "model")


def test_zero_spec_adds_data_axis_once():
    z = zero_spec(P(None, "model"), (4096, 5632), MESH, ("data",))
    assert z == P("data", "model")
    # never duplicates an axis already used
    z2 = zero_spec(P("model", None, "data"), (160, 5120, 1536), MESH, ("data",))
    assert tuple(z2).count("data") == 1


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_every_param_spec_is_divisible(arch):
    """No spec may demand an indivisible shard (jit would reject it)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    mesh = FakeMesh(data=16, model=16)
    specs = param_specs(model.abstract_params(), model.logical_axes(), rules_for(cfg), mesh)
    flat_p = jax.tree.leaves(model.abstract_params())
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    sizes = {"data": 16, "model": 16}
    for leaf, spec in zip(flat_p, flat_s):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            n = sizes[ax] if isinstance(ax, str) else 1
            assert dim % n == 0, f"{arch}: {leaf.shape} vs {spec}"


@pytest.mark.parametrize("arch", ["deepseek-coder-33b", "qwen1.5-4b", "mamba2-1.3b"])
def test_model_axis_actually_used(arch):
    """TP must engage: a healthy fraction of parameter bytes shard on model."""
    cfg = get_config(arch)
    model = build_model(cfg)
    specs = param_specs(
        model.abstract_params(), model.logical_axes(), rules_for(cfg), FakeMesh(data=16, model=16)
    )
    flat_p = jax.tree.leaves(model.abstract_params())
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    total = sharded = 0
    for leaf, spec in zip(flat_p, flat_s):
        import numpy as np

        b = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        total += b
        if "model" in tuple(spec):
            sharded += b
    assert sharded / total > 0.9, f"{arch}: only {sharded / total:.0%} TP-sharded"


def test_padding_waste_estimator():
    import numpy as np

    class Leaf:
        shape = (56, 128)
        dtype = np.dtype("float32")

    waste = estimate_padding_waste(
        {"w": Leaf()}, {"w": P("model", None)}, FakeMesh(data=16, model=16)
    )
    # 56 -> padded 64: 14.3% waste
    assert waste["waste_frac"] == pytest.approx(8 / 56)
