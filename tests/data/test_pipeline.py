"""Data pipeline tests: determinism, ordering, resume, overlap."""
import time

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fallback shim; requirements-dev.txt pins the real one
    from repro.testing import given, settings, st

from repro.core import ThreadPool
from repro.data import MemmapTokens, Prefetcher, SyntheticTokens


def test_synthetic_deterministic_per_step():
    src = SyntheticTokens(101, 16, 4, seed=3)
    a, b = src.batch(7), src.batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = src.batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # targets are next-token shifted
    np.testing.assert_array_equal(a["targets"][:, :-1], a["tokens"][:, 1:])


def test_synthetic_learnable_structure():
    """Consecutive tokens are deterministically related (low entropy given
    previous token) — the smoke-training signal."""
    src = SyntheticTokens(101, 64, 8, seed=0)
    t = src.batch(0)["tokens"]
    # same previous token -> mostly same next token (7 noise values)
    from collections import defaultdict

    nxt = defaultdict(set)
    for row in t:
        for a, b in zip(row[:-1], row[1:]):
            nxt[int(a)].add(int(b))
    sizes = [len(v) for v in nxt.values() if len(v) > 0]
    assert np.mean(sizes) <= 7.5


def test_host_sharding_disjoint():
    a = SyntheticTokens(101, 8, 8, seed=1, host_id=0, num_hosts=2)
    b = SyntheticTokens(101, 8, 8, seed=1, host_id=1, num_hosts=2)
    assert a.local_batch == 4
    assert not np.array_equal(a.batch(0)["tokens"], b.batch(0)["tokens"])


def test_prefetcher_orders_and_resumes():
    src = SyntheticTokens(101, 8, 4, seed=2)
    with Prefetcher(src, depth=3) as pf:
        b0 = pf.get()
        b1 = pf.get()
        cursor = pf.cursor
    assert cursor == 2
    # resuming from the cursor reproduces the stream
    with Prefetcher(src, depth=2, start_step=cursor) as pf2:
        b2 = pf2.get()
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), src.batch(2)["tokens"])
    np.testing.assert_array_equal(np.asarray(b0["tokens"]), src.batch(0)["tokens"])
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), src.batch(1)["tokens"])


def test_prefetcher_overlaps_slow_source():
    class SlowSource:
        def batch(self, step):
            time.sleep(0.02)
            return {"x": np.full((2,), step)}

    with ThreadPool(4) as pool:
        with Prefetcher(SlowSource(), pool=pool, depth=4) as pf:
            pf.get()  # warm
            t0 = time.perf_counter()
            for _ in range(8):
                pf.get()
            elapsed = time.perf_counter() - t0
    # serial would be >= 8*0.02 = 0.16s; overlapped should be well under
    assert elapsed < 0.15, elapsed


def test_memmap_tokens(tmp_path):
    from repro.data.synthetic import write_token_file

    data = np.arange(1000, dtype=np.int32) % 50
    path = tmp_path / "toks.bin"
    write_token_file(path, data)
    src = MemmapTokens(path, seq_len=16, global_batch=4)
    b = src.batch(0)
    assert b["tokens"].shape == (4, 16)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
    # deterministic
    np.testing.assert_array_equal(src.batch(3)["tokens"], src.batch(3)["tokens"])


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 8))
def test_synthetic_tokens_in_range(step, batch):
    src = SyntheticTokens(97, 8, batch, seed=5)
    t = src.batch(step)["tokens"]
    assert t.min() >= 0 and t.max() < 97


def test_prefetcher_close_cancels_and_drains():
    """Regression: close() must not abandon in-flight futures — unstarted
    produce tasks are cancelled (never touching the source), running ones
    are drained, and a shared pool comes back clean and reusable."""
    import threading

    calls = []
    release = threading.Event()

    class SlowSource:
        def batch(self, step):
            calls.append(step)
            release.wait(5)
            return {"x": np.full((2,), step)}

    with ThreadPool(1) as pool:
        pf = Prefetcher(SlowSource(), pool=pool, depth=4)
        # one produce task is running (holding the worker); 3 are queued
        for _ in range(100):
            if calls:
                break
            time.sleep(0.005)
        assert calls == [0]
        # close() while step 0 is mid-body: the cancel pass stops steps 1-3
        # before the worker frees up; the drain pass waits for step 0
        threading.Timer(0.1, release.set).start()
        pf.close()
        # queued steps were cancelled before their bodies ran
        assert calls == [0]
        assert not pf._inflight
        assert pool.wait_idle(timeout=10)  # nothing leaked into the shared pool
        ok = []
        pool.run(lambda: ok.append(1))  # pool still usable
        assert ok == [1]


def test_prefetcher_close_waits_for_running_task():
    """A produce task that already started is drained, not abandoned."""
    done = []

    class Source:
        def batch(self, step):
            time.sleep(0.05)
            done.append(step)
            return {"x": np.full((2,), step)}

    pf = Prefetcher(Source(), depth=2)
    time.sleep(0.01)  # let at least one produce start
    pf.close()
    assert done, "running produce was abandoned instead of drained"


def _pid_stamp(batch):
    """Module-level transform so the process backend can ship it."""
    import os

    return {**batch, "transform_pid": np.asarray(os.getpid())}


def test_prefetcher_process_backend_transforms_cross_processes():
    """backend="process": the same lane graphs run with transform bodies
    in worker processes (DESIGN.md §11) — batches arrive in order and the
    transform demonstrably executed in another pid."""
    import os

    src = SyntheticTokens(101, 8, 4, seed=4)
    with Prefetcher(src, depth=2, backend="process", put_fn=_pid_stamp) as pf:
        batches = [pf.get() for _ in range(3)]
    for step, b in enumerate(batches):
        np.testing.assert_array_equal(
            np.asarray(b["tokens"]), src.batch(step)["tokens"]
        )
        assert int(b["transform_pid"]) != os.getpid()


def test_prefetcher_backend_serial_floor():
    """backend="serial": same pipeline, zero threads — the deterministic
    debugging configuration."""
    src = SyntheticTokens(101, 8, 4, seed=5)
    with Prefetcher(src, depth=2, backend="serial", put_fn=lambda b: b) as pf:
        b0 = pf.get()
        b1 = pf.get()
    np.testing.assert_array_equal(b0["tokens"], src.batch(0)["tokens"])
    np.testing.assert_array_equal(b1["tokens"], src.batch(1)["tokens"])


def test_prefetcher_process_backend_requires_explicit_put_fn():
    """The default transform is device_put-shaped; on the process backend
    that is both wrong-device and jax-in-fork, so it fails loudly."""
    src = SyntheticTokens(101, 8, 4, seed=6)
    with pytest.raises(ValueError, match="put_fn"):
        Prefetcher(src, backend="process")


def test_prefetcher_guard_applies_to_adopted_process_pool():
    """The put_fn guard checks the *resolved* backend: handing in a
    ProcessPool via pool= must not bypass it (review fix)."""
    from repro.dist import ProcessPool

    src = SyntheticTokens(101, 8, 4, seed=7)
    with ProcessPool(1) as pp:
        with pytest.raises(ValueError, match="put_fn"):
            Prefetcher(src, pool=pp)


def test_produce_pinned_local_by_contract():
    """produce must be pinned in-parent explicitly (affinity), not by the
    accident of its bound method failing to pickle (review fix)."""
    src = SyntheticTokens(101, 8, 4, seed=8)
    with Prefetcher(src, depth=1, backend="process", put_fn=lambda b: b) as pf:
        lane = pf._lanes[0]
        assert lane.produce.affinity == "local"
        assert lane.deliver.affinity == "local"
        pf.get()


def test_prefetcher_rejects_pool_plus_backend():
    """backend= with an adopted pool would be silently ignored — rejected
    up front, matching Executor's contract (review fix)."""
    src = SyntheticTokens(101, 8, 4, seed=10)
    with ThreadPool(1) as tp:
        with pytest.raises(ValueError, match="not both"):
            Prefetcher(src, pool=tp, backend="process", put_fn=lambda b: b)
