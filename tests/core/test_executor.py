"""Executor facade + §10 control flow, parametrized over every backend.

The ``ex`` fixture runs each test on the **serial**, **thread** and
**process** backends (DESIGN.md §11): one suite, three executors, same
semantics. Tests here follow the process-safe idioms the process backend
demands — loop/convergence state lives in condition bodies (which always
run scheduler-side) or flows along dataflow edges, and assertions read
parent-side task state (``result`` / ``started`` / ``done``), never
closure cells a remote body would have mutated in its own address space.

Backend-specific behavior (cancellation timing, pool adoption, priority
bands, wait_idle timeouts) uses the thread-only ``tex`` fixture below.
"""
import asyncio
import threading
import time

import pytest

from repro.core import (
    CancelledError,
    CycleError,
    Executor,
    Future,
    SerialExecutor,
    Task,
    TaskGraph,
    ThreadPool,
)

BACKENDS = ("serial", "thread", "process")


@pytest.fixture(params=BACKENDS)
def ex(request):
    """One Executor per backend — the whole suite runs on all three."""
    n = 2 if request.param == "process" else 4
    with Executor(n, backend=request.param) as e:
        yield e


@pytest.fixture()
def tex():
    """Thread-backend executor for backend-specific tests."""
    with Executor(4, backend="thread") as e:
        yield e


# ---------------------------------------------------------------------------
# facade basics (all backends)
# ---------------------------------------------------------------------------


def test_run_callable_returns_future(ex):
    assert ex.run(lambda: 6 * 7).result(10) == 42


def test_run_single_task_resolves_to_result(ex):
    t = Task(lambda: "payload")
    t.propagate_errors = False
    assert ex.run(t).result(10) == "payload"


def test_run_graph_and_iterable(ex):
    g = TaskGraph()
    a = g.add(lambda: 3)
    b = g.then(a, lambda x: x * x)
    assert ex.run(g).result(10) is None
    assert b.result == 9
    # an anonymous iterable of tasks is wrapped in a graph; the dataflow
    # edge proves t2 ran after t1 on any backend
    t1 = Task(lambda: 20)
    t2 = Task(lambda x: x + 1, takes_inputs=True)
    t2.succeed(t1)
    assert ex.run([t1, t2]).result(10) is None
    assert t2.result == 21


def test_submit_alias(ex):
    assert ex.submit(lambda: "ok").result(10) == "ok"


def test_run_failure_delivered_through_future(ex):
    with pytest.raises(ValueError, match="boom"):
        ex.run(lambda: (_ for _ in ()).throw(ValueError("boom"))).result(10)
    # the backend stays healthy afterwards
    assert ex.run(lambda: "still alive").result(10) == "still alive"


def test_failure_propagates_along_dataflow_edges(ex):
    g = TaskGraph()
    bad = g.add(lambda: (_ for _ in ()).throw(RuntimeError("upstream died")))
    down = g.then(bad, lambda x: x)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(RuntimeError, match="upstream died"):
        ex.run(g).result(10)
    assert isinstance(down.exception, RuntimeError)  # adopted, body skipped


def test_run_graph_priority_overrides_non_explicit_bands(ex):
    """run(graph, priority=) follows the ThreadPool.submit contract: every
    task without an explicit band is promoted, explicit bands win.
    (Serial ignores bands at runtime but records them identically.)"""
    g = TaskGraph()
    a = g.add(lambda: None)
    b = a.then(lambda _x: None)
    c = g.add(lambda: None, priority=-2.0)
    ex.run(g, priority=3.0).result(10)
    assert a.priority == b.priority == 3.0
    assert c.priority == -2.0


def test_wait_idle_after_work(ex):
    ex.run(lambda: 1).result(10)
    assert ex.wait_idle(10) is True


def test_context_manager_closes_own_pool_only():
    with Executor(2) as e:
        owned = e.pool
        e.run(lambda: None).result(10)
    assert owned._stop  # owned pool closed on exit
    shared = ThreadPool(2)
    try:
        with Executor(pool=shared) as e2:
            assert e2.backend == "thread"
            e2.run(lambda: None).result(10)
        assert not shared._stop  # adopted pool left running
        shared.run(lambda: None)  # and still usable
    finally:
        shared.close()


def test_backend_pool_mutually_exclusive():
    pool = ThreadPool(1)
    try:
        with pytest.raises(ValueError, match="not both"):
            Executor(backend="thread", pool=pool)
    finally:
        pool.close()
    with pytest.raises(ValueError, match="unknown backend"):
        Executor(backend="gpu")


def test_wait_idle_reports_timeout_as_bool(tex):
    tex.submit(lambda: time.sleep(0.4))
    assert tex.wait_idle(0.01) is False
    assert tex.wait_idle(10) is True


# ---------------------------------------------------------------------------
# condition tasks: branching (all backends)
# ---------------------------------------------------------------------------


def test_condition_selects_single_branch(ex):
    g = TaskGraph("branch")
    src = g.add(lambda: None, name="src")
    pick = g.add(lambda: 1, kind="condition", name="pick")
    pick.after(src)
    left = g.add(lambda: "L", name="left")
    right = g.add(lambda: "R", name="right")
    pick.precede(left, right)  # branch order = wiring order
    assert ex.run(g).result(10) is None
    # every member of a condition graph re-arms after running (clearing
    # `started` for the next pass), so assert on results — rearm keeps them
    assert right.result == "R"
    assert left.result is None  # branch not taken


def test_branch_not_taken_resets_cleanly_across_runs(ex):
    """Un-run branches leave no residue: across run_count > 1 each run
    releases exactly the branch its condition names."""
    sel = {"v": 0}
    g = TaskGraph()
    pick = g.add(lambda: sel["v"], kind="condition")  # conditions run in-parent
    a = g.add(lambda: "a")
    b = g.add(lambda: "b")
    pick.precede(a, b)
    taken = []
    for v in (0, 1, 0):
        sel["v"] = v
        if taken:
            g.reset()
        assert ex.run(g).result(10) is None
        assert (a.result is None) != (b.result is None)  # exactly one branch ran
        taken.append(a.result or b.result)
    assert taken == ["a", "b", "a"]
    assert g.run_count == 3


def test_condition_out_of_range_ends_run(ex):
    """A non-int / out-of-range return selects nothing — the loop's exit."""
    g = TaskGraph()
    c = g.add(lambda: 99, kind="condition")
    dead = g.add(lambda: "never")
    c.precede(dead)
    assert ex.run(g).result(10) is None
    assert dead.result is None  # branch never released


def test_condition_plus_runtime_rejected():
    """A condition task cannot spawn subflows — the splice would silently
    swallow every branch, so the combination is rejected at construction."""
    with pytest.raises(ValueError, match="runtime handle"):
        Task(lambda: 0, kind="condition", takes_runtime=True)
    with pytest.raises(ValueError, match="runtime handle"):
        TaskGraph().add(lambda: 0, kind="condition", takes_runtime=True)


def test_weak_edges_skip_countdown_and_slots():
    g = TaskGraph()
    c = g.add(lambda: 0, kind="condition")
    t = g.add(lambda x: x, takes_inputs=True)
    val = g.add(lambda: 5)
    t.succeed(val)  # strong: one slot
    t.succeed(c)  # weak: no countdown token, no slot
    assert t.num_predecessors == 1
    assert t.num_weak_predecessors == 1
    assert t.inputs == [val]


# ---------------------------------------------------------------------------
# condition tasks: weak-edge cycles (all backends)
# ---------------------------------------------------------------------------


def _build_loop(iters):
    """entry -> body -> more? with a weak back-edge to body.

    Loop state lives in the *condition* body — conditions always execute
    scheduler-side, so the counter is authoritative on every backend.
    """
    g = TaskGraph("loop")
    state = {"i": 0, "runs": 0}
    entry = g.add(lambda: state.update(i=0), name="entry", affinity="local")
    body = g.add(lambda: None, name="body")  # remote-eligible each pass
    body.after(entry)

    def more():
        state["i"] += 1
        state["runs"] += 1
        return 0 if state["i"] < iters else 1

    cond = g.add(more, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)
    return g, state


def test_condition_loop_bounded_iteration(ex):
    g, state = _build_loop(7)
    assert ex.run(g).result(10) is None
    assert state["runs"] == 7


def test_condition_loop_rerunnable(ex):
    g, state = _build_loop(4)
    for expect in (4, 8, 12):
        ex.run(g).result(10)
        assert state["runs"] == expect
        g.reset()
    assert g.run_count == 3


def test_condition_loop_via_plain_pool_run():
    """Deprecation shim: the old ThreadPool.run path drives condition
    graphs too (completion via quiescence instead of the counted future)."""
    g, state = _build_loop(5)
    with ThreadPool(2) as pool:
        pool.run(g)
    assert state["runs"] == 5


def test_condition_loop_serial_executor():
    g, state = _build_loop(6)
    SerialExecutor().run(g)
    assert state["runs"] == 6


def test_validate_permits_condition_closed_cycle():
    g, _state = _build_loop(3)
    g.validate()  # weak back-edge: legal
    bad = TaskGraph()
    a = bad.add(lambda: None)
    b = bad.add(lambda: None)
    a.succeed(b)
    b.succeed(a)
    with pytest.raises(CycleError):
        bad.validate()  # strong cycle: still illegal


def test_condition_loop_failure_resolves_future(ex):
    boom = {"at": 3, "i": 0}
    g = TaskGraph()
    entry = g.add(lambda: boom.update(i=0), name="entry", affinity="local")

    # pass counting and the triggered failure stay scheduler-side
    # (affinity="local"): the loop machinery under test is identical on
    # every backend, and the counter must be authoritative
    def body():
        boom["i"] += 1
        if boom["i"] == boom["at"]:
            raise ValueError("pass 3 failed")

    bt = g.add(body, name="body", affinity="local")
    bt.after(entry)
    # the condition consumes the body's value edge, so a body failure
    # propagates into it (skip + adopt) and the loop stops that pass
    cond = g.add(
        lambda _x: 0 if boom["i"] < 10 else 1, kind="condition", takes_inputs=True
    )
    cond.succeed(bt)
    cond.precede(bt)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(ValueError, match="pass 3"):
        ex.run(g).result(10)
    assert boom["i"] == 3  # the loop stopped at the failing pass


def test_condition_loop_cancellation(tex):
    """Cancelling the run future stops a spinning loop cooperatively."""
    g = TaskGraph()
    hits = []
    entry = g.add(lambda: None)
    body = g.add(lambda: (hits.append(1), time.sleep(0.005)))
    body.after(entry)
    cond = g.add(lambda: 0, kind="condition")  # would loop forever
    cond.after(body)
    cond.precede(body)
    fut = tex.run(g)
    while not hits:
        time.sleep(0.001)
    assert fut.cancel() is True
    with pytest.raises(CancelledError):
        fut.result(10)
    n = len(hits)
    time.sleep(0.05)
    assert len(hits) == n  # the loop genuinely stopped
    tex.wait_idle(10)


# ---------------------------------------------------------------------------
# dynamic subflows (all backends)
# ---------------------------------------------------------------------------


def test_subflow_join_before_successor(ex):
    """Every runtime-spawned task completes before the spawner's successor
    runs, and the gather's result is visible through the spawner."""
    g = TaskGraph()

    def spawn(rt):
        ws = [rt.add(lambda i=i: i * i, name=f"w{i}") for i in range(8)]
        return rt.gather(ws)

    sp = g.add(spawn, takes_runtime=True, name="spawn")
    # the spawner's dataflow value is the gather's result (join unwraps it)
    done = g.then(sp, lambda vals: sorted(vals))
    assert ex.run(g).result(10) is None
    assert done.result == [i * i for i in range(8)]
    assert all(w.done for w in sp._spawned)  # joined before the successor


def test_subflow_sized_by_runtime_data(ex):
    """The fan-out width comes from data the task sees at execution time."""
    g = TaskGraph()
    width = g.add(lambda: 5, name="width")

    def spawn(rt, n):
        return rt.gather([rt.add(lambda i=i: i, name=f"s{i}") for i in range(n)])

    sp = g.add(spawn, takes_inputs=True, takes_runtime=True, name="spawn")
    sp.succeed(width)
    total = g.then(sp, sum)
    assert ex.run(g).result(10) is None
    assert total.result == sum(range(5))
    assert len(sp._spawned) == 6  # 5 workers + gather


def test_subflow_failure_propagates_to_future(ex):
    g = TaskGraph()

    def spawn(rt):
        rt.add(lambda: None)
        rt.add(lambda: (_ for _ in ()).throw(RuntimeError("shard died")))

    sp = g.add(spawn, takes_runtime=True)
    g.then(sp, lambda _gt: None)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(RuntimeError, match="shard died"):
        ex.run(g).result(10)
    assert isinstance(sp.exception, RuntimeError)  # adopted by the spawner
    ex.wait_idle(10)  # pool not poisoned


def test_nested_subflow_spawner(ex):
    """A spawned task may itself be a takes_runtime spawner; the outer
    successor still waits for the innermost join."""
    g = TaskGraph()

    def outer_spawn(rt):
        def inner_spawn(rt2):
            return rt2.gather([rt2.add(lambda i=i: ("inner", i)) for i in range(3)])

        return rt.add(inner_spawn, takes_runtime=True, name="inner")

    sp = g.add(outer_spawn, takes_runtime=True, name="outer")
    after = g.then(sp, lambda inner_vals: sorted(inner_vals))
    assert ex.run(g).result(10) is None
    assert after.result == [("inner", i) for i in range(3)]


def test_subflow_serial_executor():
    order = []
    g = TaskGraph()

    def spawn(rt):
        for i in range(3):
            rt.add(lambda i=i: order.append(i))

    sp = g.add(spawn, takes_runtime=True)
    g.add(lambda: order.append("after")).after(sp)
    SerialExecutor().run(g)
    assert order[-1] == "after" and sorted(order[:-1]) == [0, 1, 2]


def test_subflow_priority_inherited_from_spawner(ex):
    g = TaskGraph()
    captured = []

    def spawn(rt):  # spawner bodies always run scheduler-side
        captured.append(rt.add(lambda: None).priority)
        captured.append(rt.add(lambda: None, priority=-1.0).priority)

    g.add(spawn, takes_runtime=True, priority=2.5)
    ex.run(g).result(10)
    assert captured == [2.5, -1.0]


def test_subflow_cancellation_in_flight():
    """Cancelling mid-subflow skips not-yet-started spawned tasks and the
    future reports cancellation."""
    pool = ThreadPool(1)
    try:
        ex1 = Executor(pool=pool)
        gate = threading.Event()
        started = threading.Event()
        ran = []
        g = TaskGraph()

        def spawn(rt):
            def first():
                started.set()
                gate.wait(10)
                ran.append("first")

            f = rt.add(first)
            for i in range(4):
                rt.add(lambda i=i: ran.append(i)).after(f)

        sp = g.add(spawn, takes_runtime=True)
        g.then(sp, lambda _gt: ran.append("after"))
        for t in g.tasks:
            t.propagate_errors = False
        fut = ex1.run(g)
        assert started.wait(10)
        assert fut.cancel() is True  # spawned followers had not started
        gate.set()
        with pytest.raises(CancelledError):
            fut.result(10)
        pool.wait_idle(10)
        assert ran == ["first"]  # running body drained; the rest skipped
    finally:
        pool.close()


def test_subflow_cancellation_mid_spawner_body():
    """Cancelling while the spawner's body is still running reaches the
    already-spawned tasks (the live subflow list is published before the
    body runs), so no writer body executes after a successful cancel."""
    pool = ThreadPool(2)
    try:
        ex1 = Executor(pool=pool)
        in_body = threading.Event()
        release = threading.Event()
        ran = []
        g = TaskGraph()

        def spawn(rt):
            for i in range(6):
                rt.add(lambda i=i: ran.append(i))
            in_body.set()
            release.wait(10)  # cancel happens here, mid-body

        sp = g.add(spawn, takes_runtime=True)
        g.then(sp, lambda _gt: ran.append("after"))
        for t in g.tasks:
            t.propagate_errors = False
        fut = ex1.run(g)
        assert in_body.wait(10)
        assert fut.cancel() is True
        release.set()
        with pytest.raises(CancelledError):
            fut.result(10)
        pool.wait_idle(10)
        assert ran == []  # every spawned body was skipped
    finally:
        pool.close()


def test_run_same_task_repeatedly_does_not_chain_callbacks(tex):
    """Re-running one Task through the facade must not stack resolver
    wrappers (leak) — each round resolves its own future exactly once."""
    runs = []
    base_hits = []
    t = Task(lambda: runs.append(1) or len(runs))
    t.propagate_errors = False
    t.on_done = lambda _t: base_hits.append(1)
    for expect in (1, 2, 3):
        t.reset()
        assert tex.run(t).result(10) == expect
    assert t.on_done._wrapped.__name__ == "<lambda>"  # base cb, not a wrapper
    assert len(base_hits) == 3  # fired once per round, not 1+2+3 times


def test_run_iterable_rerun_waits_for_completion(tex):
    """Regression: re-running the same task iterable must return a future
    that resolves only after the bodies ran (a stale hidden completion
    task from the previous wrapper graph must not hide the sinks)."""
    runs = []
    t = Task(lambda: (time.sleep(0.05), runs.append(1)))
    t.propagate_errors = False
    assert tex.run([t]).result(10) is None
    t.reset()
    fut = tex.run([t])
    fut.result(10)
    assert len(runs) == 2  # second run actually executed before resolving
    with pytest.raises(TimeoutError):
        # and a third run's future is live, not pre-resolved
        t.reset()
        tex.run([t]).result(0.001)
    tex.wait_idle(10)


# ---------------------------------------------------------------------------
# run_until + asyncio bridge (all backends)
# ---------------------------------------------------------------------------


def test_run_until_reruns_to_convergence(ex):
    # convergence state is carried by the task's own result: the predicate
    # reads parent-side task state, valid on every backend
    state = {"x": 100.0}
    g = TaskGraph()

    def halve():
        state["x"] /= 2
        return state["x"]

    t = g.add(halve, affinity="local")  # caller-side loop, caller-side state
    rounds = ex.run_until(g, lambda: t.result < 1.0)
    assert rounds == 7  # 100 / 2^7 < 1
    assert g.run_count == 7


def test_run_until_max_rounds(ex):
    g = TaskGraph()
    g.add(lambda: None)
    with pytest.raises(RuntimeError, match="still false"):
        ex.run_until(g, lambda: False, max_rounds=3)
    assert g.run_count == 3


def test_await_future_from_asyncio(ex):
    async def main():
        return await ex.run(lambda: 6 * 7)

    assert asyncio.run(main()) == 42


def test_await_future_already_resolved(ex):
    fut = ex.run(lambda: "early")
    fut.result(10)

    async def main():
        return await fut

    assert asyncio.run(main()) == "early"


def test_await_future_delivers_exception(ex):
    async def main():
        await ex.run(lambda: (_ for _ in ()).throw(ValueError("async boom")))

    with pytest.raises(ValueError, match="async boom"):
        asyncio.run(main())


def test_co_run_graph_with_condition_loop(ex):
    g, state = _build_loop(5)

    async def main():
        await ex.co_run(g)
        return state["runs"]

    assert asyncio.run(main()) == 5


def test_co_run_concurrent_awaits(ex):
    """Several co_run awaitables progress concurrently on one loop."""

    async def main():
        futs = [ex.co_run(lambda i=i: i * 10) for i in range(5)]
        return await asyncio.gather(*futs)

    assert asyncio.run(main()) == [0, 10, 20, 30, 40]


def test_future_add_done_callback_fires_once():
    hits = []
    fut = Future()
    fut.add_done_callback(lambda f: hits.append("cb"))
    fut.set_result(1)
    fut.set_result(2)  # first-write-wins: no second fire
    fut.add_done_callback(lambda f: hits.append("late"))  # immediate
    assert hits == ["cb", "late"]


# ---------------------------------------------------------------------------
# to_dot rendering (satellite)
# ---------------------------------------------------------------------------


def test_to_dot_condition_edges_dashed_and_subflow_cluster(tex):
    g = TaskGraph("render")
    pick = g.add(lambda: 0, kind="condition", name="pick")
    a = g.add(lambda: None, name="branch-a")
    pick.precede(a)

    def spawn(rt):
        rt.add(lambda: None, name="spawned0")

    sp = g.add(spawn, takes_runtime=True, name="spawner")
    sp.after(a)
    dot = g.to_dot()
    assert "shape=diamond" in dot  # condition node
    assert "style=dashed" in dot and 'label="0"' in dot  # weak branch edge
    assert "cluster" not in dot  # subflow only exists after a run
    tex.run(g).result(10)
    dot = g.to_dot()
    assert 'subgraph "cluster_' in dot and "spawned0" in dot
    assert "style=dotted" in dot  # spawner -> subflow link


def test_single_prewired_task_runs_on_every_backend(ex):
    """Submitting one pre-wired (non-source) Task runs exactly that task,
    as ThreadPool._schedule does — the serial backend must not reject it
    as a sourceless graph (review fix)."""
    t1 = Task(lambda: "unrun")
    t2 = Task(lambda x: (x, "ran"), takes_inputs=True)
    t2.succeed(t1)
    t2.propagate_errors = False
    assert ex.run(t2).result(10) == (None, "ran")  # t1 never ran: slot is None
