"""Executor facade: thread-backend-specific behavior and plumbing.

The backend-*portable* executor matrix (lifecycle, priorities, conditions
and weak cycles, subflows, counted completion, replay parity,
retry/timeout/at-most-once, observer accounting) lives in
``tests/dist/conformance.py``, where every test runs identically on the
serial, thread, process and socket backends (DESIGN.md §11, §16). This
file keeps what cannot be backend-parametrized: pool adoption and
ownership, constructor validation, sub-millisecond cancellation timing
(needs in-process closure cells), serial/plain-pool compatibility shims,
``Future`` plumbing and ``to_dot`` rendering.
"""
import threading
import time

import pytest

from repro.core import (
    CancelledError,
    CycleError,
    Executor,
    Future,
    SerialExecutor,
    Task,
    TaskGraph,
    ThreadPool,
)


@pytest.fixture()
def tex():
    """Thread-backend executor for backend-specific tests."""
    with Executor(4, backend="thread") as e:
        yield e


# ---------------------------------------------------------------------------
# facade plumbing: ownership + validation
# ---------------------------------------------------------------------------


def test_context_manager_closes_own_pool_only():
    with Executor(2) as e:
        owned = e.pool
        e.run(lambda: None).result(10)
    assert owned._stop  # owned pool closed on exit
    shared = ThreadPool(2)
    try:
        with Executor(pool=shared) as e2:
            assert e2.backend == "thread"
            e2.run(lambda: None).result(10)
        assert not shared._stop  # adopted pool left running
        shared.run(lambda: None)  # and still usable
    finally:
        shared.close()


def test_backend_pool_mutually_exclusive():
    pool = ThreadPool(1)
    try:
        with pytest.raises(ValueError, match="not both"):
            Executor(backend="thread", pool=pool)
    finally:
        pool.close()
    with pytest.raises(ValueError, match="unknown backend"):
        Executor(backend="gpu")


def test_wait_idle_reports_timeout_as_bool(tex):
    tex.submit(lambda: time.sleep(0.4))
    assert tex.wait_idle(0.01) is False
    assert tex.wait_idle(10) is True


# ---------------------------------------------------------------------------
# condition construction rules + shims
# ---------------------------------------------------------------------------


def test_condition_plus_runtime_rejected():
    """A condition task cannot spawn subflows — the splice would silently
    swallow every branch, so the combination is rejected at construction."""
    with pytest.raises(ValueError, match="runtime handle"):
        Task(lambda: 0, kind="condition", takes_runtime=True)
    with pytest.raises(ValueError, match="runtime handle"):
        TaskGraph().add(lambda: 0, kind="condition", takes_runtime=True)


def test_weak_edges_skip_countdown_and_slots():
    g = TaskGraph()
    c = g.add(lambda: 0, kind="condition")
    t = g.add(lambda x: x, takes_inputs=True)
    val = g.add(lambda: 5)
    t.succeed(val)  # strong: one slot
    t.succeed(c)  # weak: no countdown token, no slot
    assert t.num_predecessors == 1
    assert t.num_weak_predecessors == 1
    assert t.inputs == [val]


def _build_loop(iters):
    """entry -> body -> more? with a weak back-edge to body.

    (Thread/serial-shim copy; the four-backend version lives in the
    conformance suite.)
    """
    g = TaskGraph("loop")
    state = {"i": 0, "runs": 0}
    entry = g.add(lambda: state.update(i=0), name="entry", affinity="local")
    body = g.add(lambda: None, name="body")
    body.after(entry)

    def more():
        state["i"] += 1
        state["runs"] += 1
        return 0 if state["i"] < iters else 1

    cond = g.add(more, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)
    return g, state


def test_condition_loop_via_plain_pool_run():
    """Deprecation shim: the old ThreadPool.run path drives condition
    graphs too (completion via quiescence instead of the counted future)."""
    g, state = _build_loop(5)
    with ThreadPool(2) as pool:
        pool.run(g)
    assert state["runs"] == 5


def test_condition_loop_serial_executor():
    g, state = _build_loop(6)
    SerialExecutor().run(g)
    assert state["runs"] == 6


def test_validate_permits_condition_closed_cycle():
    g, _state = _build_loop(3)
    g.validate()  # weak back-edge: legal
    bad = TaskGraph()
    a = bad.add(lambda: None)
    b = bad.add(lambda: None)
    a.succeed(b)
    b.succeed(a)
    with pytest.raises(CycleError):
        bad.validate()  # strong cycle: still illegal


# ---------------------------------------------------------------------------
# cancellation timing (thread-only: needs in-process events + closure cells)
# ---------------------------------------------------------------------------


def test_condition_loop_cancellation(tex):
    """Cancelling the run future stops a spinning loop cooperatively."""
    g = TaskGraph()
    hits = []
    entry = g.add(lambda: None)
    body = g.add(lambda: (hits.append(1), time.sleep(0.005)))
    body.after(entry)
    cond = g.add(lambda: 0, kind="condition")  # would loop forever
    cond.after(body)
    cond.precede(body)
    fut = tex.run(g)
    while not hits:
        time.sleep(0.001)
    assert fut.cancel() is True
    with pytest.raises(CancelledError):
        fut.result(10)
    n = len(hits)
    time.sleep(0.05)
    assert len(hits) == n  # the loop genuinely stopped
    tex.wait_idle(10)


def test_subflow_serial_executor():
    order = []
    g = TaskGraph()

    def spawn(rt):
        for i in range(3):
            rt.add(lambda i=i: order.append(i))

    sp = g.add(spawn, takes_runtime=True)
    g.add(lambda: order.append("after")).after(sp)
    SerialExecutor().run(g)
    assert order[-1] == "after" and sorted(order[:-1]) == [0, 1, 2]


def test_subflow_cancellation_in_flight():
    """Cancelling mid-subflow skips not-yet-started spawned tasks and the
    future reports cancellation."""
    pool = ThreadPool(1)
    try:
        ex1 = Executor(pool=pool)
        gate = threading.Event()
        started = threading.Event()
        ran = []
        g = TaskGraph()

        def spawn(rt):
            def first():
                started.set()
                gate.wait(10)
                ran.append("first")

            f = rt.add(first)
            for i in range(4):
                rt.add(lambda i=i: ran.append(i)).after(f)

        sp = g.add(spawn, takes_runtime=True)
        g.then(sp, lambda _gt: ran.append("after"))
        for t in g.tasks:
            t.propagate_errors = False
        fut = ex1.run(g)
        assert started.wait(10)
        assert fut.cancel() is True  # spawned followers had not started
        gate.set()
        with pytest.raises(CancelledError):
            fut.result(10)
        pool.wait_idle(10)
        assert ran == ["first"]  # running body drained; the rest skipped
    finally:
        pool.close()


def test_subflow_cancellation_mid_spawner_body():
    """Cancelling while the spawner's body is still running reaches the
    already-spawned tasks (the live subflow list is published before the
    body runs), so no writer body executes after a successful cancel."""
    pool = ThreadPool(2)
    try:
        ex1 = Executor(pool=pool)
        in_body = threading.Event()
        release = threading.Event()
        ran = []
        g = TaskGraph()

        def spawn(rt):
            for i in range(6):
                rt.add(lambda i=i: ran.append(i))
            in_body.set()
            release.wait(10)  # cancel happens here, mid-body

        sp = g.add(spawn, takes_runtime=True)
        g.then(sp, lambda _gt: ran.append("after"))
        for t in g.tasks:
            t.propagate_errors = False
        fut = ex1.run(g)
        assert in_body.wait(10)
        assert fut.cancel() is True
        release.set()
        with pytest.raises(CancelledError):
            fut.result(10)
        pool.wait_idle(10)
        assert ran == []  # every spawned body was skipped
    finally:
        pool.close()


# ---------------------------------------------------------------------------
# facade re-run + Future plumbing
# ---------------------------------------------------------------------------


def test_run_same_task_repeatedly_does_not_chain_callbacks(tex):
    """Re-running one Task through the facade must not stack resolver
    wrappers (leak) — each round resolves its own future exactly once."""
    runs = []
    base_hits = []
    t = Task(lambda: runs.append(1) or len(runs))
    t.propagate_errors = False
    t.on_done = lambda _t: base_hits.append(1)
    for expect in (1, 2, 3):
        t.reset()
        assert tex.run(t).result(10) == expect
    assert t.on_done._wrapped.__name__ == "<lambda>"  # base cb, not a wrapper
    assert len(base_hits) == 3  # fired once per round, not 1+2+3 times


def test_run_iterable_rerun_waits_for_completion(tex):
    """Regression: re-running the same task iterable must return a future
    that resolves only after the bodies ran (a stale hidden completion
    task from the previous wrapper graph must not hide the sinks)."""
    runs = []
    t = Task(lambda: (time.sleep(0.05), runs.append(1)))
    t.propagate_errors = False
    assert tex.run([t]).result(10) is None
    t.reset()
    fut = tex.run([t])
    fut.result(10)
    assert len(runs) == 2  # second run actually executed before resolving
    with pytest.raises(TimeoutError):
        # and a third run's future is live, not pre-resolved
        t.reset()
        tex.run([t]).result(0.001)
    tex.wait_idle(10)


def test_future_add_done_callback_fires_once():
    hits = []
    fut = Future()
    fut.add_done_callback(lambda f: hits.append("cb"))
    fut.set_result(1)
    fut.set_result(2)  # first-write-wins: no second fire
    fut.add_done_callback(lambda f: hits.append("late"))  # immediate
    assert hits == ["cb", "late"]


# ---------------------------------------------------------------------------
# to_dot rendering (satellite)
# ---------------------------------------------------------------------------


def test_to_dot_condition_edges_dashed_and_subflow_cluster(tex):
    g = TaskGraph("render")
    pick = g.add(lambda: 0, kind="condition", name="pick")
    a = g.add(lambda: None, name="branch-a")
    pick.precede(a)

    def spawn(rt):
        rt.add(lambda: None, name="spawned0")

    sp = g.add(spawn, takes_runtime=True, name="spawner")
    sp.after(a)
    dot = g.to_dot()
    assert "shape=diamond" in dot  # condition node
    assert "style=dashed" in dot and 'label="0"' in dot  # weak branch edge
    assert "cluster" not in dot  # subflow only exists after a run
    tex.run(g).result(10)
    dot = g.to_dot()
    assert 'subgraph "cluster_' in dot and "spawned0" in dot
    assert "style=dotted" in dot  # spawner -> subflow link
