"""§14 fault tolerance: retry/timeout policies, the hung-task watchdog and
the seeded chaos harness, parametrized over every backend.

Process-safe idioms apply (see tests/core/test_executor.py): bodies whose
*attempt counters* drive the test are pinned ``affinity="local"`` so the
counter lives in the parent on the process backend too; purely-failing or
purely-sleeping bodies are module-level functions so they ship by pickle
reference. Chaos injection happens at the parent-side dispatch seam, so it
is backend-uniform by construction.
"""
import os
import threading
import time

import pytest

from repro.core import (
    ChaosError,
    Executor,
    FaultInjector,
    RetryPolicy,
    Task,
    TaskGraph,
    TaskTimeoutError,
    checkpoint,
)
from repro.dist.process_pool import WorkerDiedError

BACKENDS = ("serial", "thread", "process", "socket")


@pytest.fixture(params=BACKENDS)
def ex(request):
    """One Executor per backend — the whole suite runs on all four."""
    n = 2 if request.param in ("process", "socket") else 4
    with Executor(n, backend=request.param) as e:
        yield e


@pytest.fixture()
def tex():
    """Thread-backend executor for backend-specific tests."""
    with Executor(4, backend="thread") as e:
        yield e


@pytest.fixture()
def pex():
    """Process-backend executor for worker-kill tests."""
    with Executor(2, backend="process") as e:
        yield e


def _always_fail():
    raise ValueError("permanent failure")


def _sleep_long():
    time.sleep(30.0)


def _exit_now():
    os._exit(1)


# ---------------------------------------------------------------------------
# RetryPolicy surface
# ---------------------------------------------------------------------------


def test_retry_policy_validation_and_backoff():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=2, backoff=-1.0)
    with pytest.raises(ValueError):
        Task(lambda: None, timeout=0.0)
    pol = RetryPolicy(max_attempts=5, backoff=0.1, factor=2.0, max_backoff=0.3)
    assert [pol.delay(a) for a in (1, 2, 3, 4)] == [0.1, 0.2, 0.3, 0.3]
    assert pol.matches(ValueError("x"))
    from repro.core import CancelledError

    assert not pol.matches(CancelledError("never retried"))
    narrow = RetryPolicy(max_attempts=2, retry_on=OSError)
    assert narrow.matches(OSError()) and not narrow.matches(ValueError())


# ---------------------------------------------------------------------------
# retry semantics (all backends)
# ---------------------------------------------------------------------------


def test_retry_to_success(ex):
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ValueError(f"boom {len(calls)}")
        return 42

    t = Task(flaky, name="flaky", affinity="local",
             retry=RetryPolicy(max_attempts=5, backoff=0.001))
    t.propagate_errors = False
    assert ex.run(t).result(30) == 42
    assert t.exception is None
    assert ex.stats()["retries"] == 2


def test_exhausted_retries_surface_the_chain(ex):
    t = Task(_always_fail, name="doomed",
             retry=RetryPolicy(max_attempts=3, backoff=0))
    t.propagate_errors = False
    with pytest.raises(ValueError, match="permanent failure"):
        ex.run(t).result(30)
    # the surfaced exception chains every failed attempt (§14)
    depth, exc = 0, t.exception
    while exc is not None:
        depth += 1
        exc = exc.__context__
    assert depth == 3
    assert ex.stats()["retries"] == 2


def test_retry_composes_with_dataflow(ex):
    calls = []

    def flaky_mid(x):
        calls.append(1)
        if len(calls) < 2:
            raise RuntimeError("transient")
        return x * 10

    g = TaskGraph()
    a = g.add(lambda: 4, name="a")
    b = g.then(a, flaky_mid, name="b")
    b.affinity = "local"
    b.retry_policy = RetryPolicy(max_attempts=3, backoff=0)
    c = g.then(b, lambda v: v + 2, name="c")
    assert ex.run(g).result(30) is None
    assert c.result == 42


def test_deferred_backoff_does_not_block_workers(tex):
    """A backing-off retry must not occupy a worker: other tasks keep
    flowing while the failed task waits out its delay on the pool timer."""
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 2:
            raise ValueError("wait for it")
        return "done"

    t = Task(flaky, name="slow-retry", affinity="local",
             retry=RetryPolicy(max_attempts=3, backoff=0.3))
    t.propagate_errors = False
    fut = tex.run(t)
    t0 = time.monotonic()
    # the pool is fully available during the backoff window
    assert tex.run(lambda: "quick").result(5) == "quick"
    assert time.monotonic() - t0 < 0.25
    assert fut.result(30) == "done"


def test_cancelled_tasks_never_retry(tex):
    from repro.core import CancelledError

    g = TaskGraph()
    bad = g.add(_always_fail, name="bad")
    skipped = g.then(bad, lambda _x: "unreachable", name="skipped")
    skipped.retry_policy = RetryPolicy(max_attempts=5, backoff=0)
    with pytest.raises(ValueError):
        tex.run(g).result(30)
    assert isinstance(skipped.exception, CancelledError)
    assert tex.stats()["retries"] == 0


# ---------------------------------------------------------------------------
# timeouts: cooperative checkpoint + process watchdog
# ---------------------------------------------------------------------------


def test_cooperative_timeout_checkpoint(ex):
    def body():
        for _ in range(200):
            time.sleep(0.005)
            checkpoint()

    t = Task(body, name="deadline", affinity="local", timeout=0.05)
    t.propagate_errors = False
    with pytest.raises(TaskTimeoutError, match="deadline"):
        ex.run(t).result(30)
    assert ex.stats()["timeouts"] == 1


def test_timeout_then_retry_to_success(tex):
    calls = []

    def flaky_slow():
        calls.append(1)
        if len(calls) < 2:
            while True:
                time.sleep(0.005)
                checkpoint()
        return "recovered"

    t = Task(flaky_slow, name="slow-once", affinity="local", timeout=0.05,
             retry=RetryPolicy(max_attempts=2, backoff=0, retry_on=TaskTimeoutError))
    t.propagate_errors = False
    assert tex.run(t).result(30) == "recovered"
    st = tex.stats()
    assert st["timeouts"] == 1 and st["retries"] == 1


def test_checkpoint_is_noop_outside_a_task():
    checkpoint()  # no current task: must not raise


def test_watchdog_kills_stuck_worker(pex):
    """A remote body that never returns is killed at its deadline: the
    task fails with TaskTimeoutError, the pool respawns the worker and
    keeps serving."""
    t = Task(_sleep_long, name="wedge", timeout=0.5, affinity="remote")
    t.propagate_errors = False
    with pytest.raises(TaskTimeoutError, match="wedge"):
        pex.run(t).result(30)
    st = pex.stats()
    assert st["worker_kills"] == 1 and st["timeouts"] == 1
    assert st["worker_restarts"] >= 1
    assert pex.run(lambda: 7).result(30) == 7  # capacity restored


# ---------------------------------------------------------------------------
# §10 contract under a permanently wedged body (regression)
# ---------------------------------------------------------------------------


def test_wedged_body_result_and_wait_idle_timeouts(tex):
    """A stuck task must never hang the contract surface: Future.result
    raises TimeoutError at its deadline and wait_idle reports False."""
    gate = threading.Event()
    fut = tex.submit(gate.wait)
    with pytest.raises(TimeoutError):
        fut.result(0.2)
    assert tex.wait_idle(0.2) is False
    gate.set()
    assert fut.result(30) is True
    assert tex.wait_idle(30) is True


# ---------------------------------------------------------------------------
# ProcessPool fault model: transport loss, at-most-once
# ---------------------------------------------------------------------------


def test_transport_loss_is_retried_implicitly(pex):
    """A worker that died while idle fails the *send*; the implicit
    transport-loss policy resubmits without any per-task RetryPolicy."""
    pool = pex.pool
    pool._procs[0].kill()
    pool._procs[0].join()
    futs = [pex.submit(lambda i=i: i * i) for i in range(8)]
    assert [f.result(30) for f in futs] == [i * i for i in range(8)]
    st = pex.stats()
    assert st["retries"] >= 1 and st["worker_restarts"] >= 1


def test_started_bodies_are_at_most_once_unless_idempotent(pex):
    t = Task(_exit_now, name="suicide", affinity="remote")
    t.propagate_errors = False
    with pytest.raises(WorkerDiedError) as ei:
        pex.run(t).result(30)
    assert ei.value.started is True
    base = pex.stats()["retries"]  # non-idempotent: never retried
    t2 = Task(_exit_now, name="suicide2", affinity="remote", idempotent=True)
    t2.propagate_errors = False
    with pytest.raises(WorkerDiedError):
        pex.run(t2).result(30)
    assert pex.stats()["retries"] == base + 1  # one implicit retry, then surfaced


# ---------------------------------------------------------------------------
# seeded chaos: deterministic schedules, surviving results intact
# ---------------------------------------------------------------------------

_CHAOS = dict(fail_rate=0.25, delay_rate=0.1, kill_rate=0.08, delay_s=0.001)


def _chaos_graph():
    g = TaskGraph("chaos")
    tasks = [
        g.add(
            lambda i=i: i + 1,
            name=f"c:{i}",
            retry=RetryPolicy(
                max_attempts=10, backoff=0, retry_on=(ChaosError, WorkerDiedError)
            ),
        )
        for i in range(30)
    ]
    sink = g.gather(tasks, name="collect")
    return g, sink


def test_chaos_same_seed_same_schedule(ex):
    runs = []
    for _ in range(2):
        inj = FaultInjector(seed=7, match=lambda t: (t.name or "").startswith("c:"),
                            **_CHAOS)
        g, sink = _chaos_graph()
        with inj.on(ex.pool):
            ex.run(g).result(60)
        runs.append((inj.schedule(), list(sink.result)))
    assert runs[0] == runs[1]
    sched, values = runs[0]
    counts = {"fail": 0, "delay": 0, "kill": 0}
    for _name, _occ, kind in sched:
        counts[kind] += 1
    # the ISSUE floor: >=10% injected body failures, delays, >=2 kills
    assert counts["fail"] >= 3 and counts["delay"] >= 1 and counts["kill"] >= 2
    assert values == [i + 1 for i in range(30)]  # surviving results intact


def test_chaos_schedule_identical_across_backends():
    outcomes = {}
    for backend in BACKENDS:
        n = 2 if backend in ("process", "socket") else 4
        with Executor(n, backend=backend) as e:
            inj = FaultInjector(seed=123, match=lambda t: (t.name or "").startswith("c:"),
                                **_CHAOS)
            g, sink = _chaos_graph()
            with inj.on(e.pool):
                e.run(g).result(60)
            outcomes[backend] = (inj.schedule(), list(sink.result))
    assert len(set(map(repr, outcomes.values()))) == 1, outcomes
    assert outcomes["serial"][1] == [i + 1 for i in range(30)]


def test_chaos_counts_provoked_recoveries(tex):
    inj = FaultInjector(seed=11, fail_rate=0.5)
    tasks = [Task(lambda i=i: i, name=f"f:{i}",
                  retry=RetryPolicy(max_attempts=20, backoff=0, retry_on=ChaosError))
             for i in range(20)]
    for t in tasks:
        t.propagate_errors = False
    with inj.on(tex.pool):
        for t in tasks:
            tex.pool.submit(t)
        tex.wait_idle(60)
    assert inj.counts()["fail"] == len(inj.schedule()) >= 5
    assert inj.retries == len(inj.schedule())  # every injected fail was retried
    assert all(t.result == i for i, t in enumerate(tasks))


def test_chaos_uninstall_restores_the_seam(tex):
    inj = FaultInjector(seed=1, fail_rate=1.0)
    with inj.on(tex.pool):
        assert tex.pool._offload == inj._offload
    assert tex.pool._offload is None
    assert tex.run(lambda: "clean").result(10) == "clean"
    with pytest.raises(RuntimeError):
        inj.install(tex.pool)
        inj.install(tex.pool)  # double-install is an error
    inj.uninstall()


# ---------------------------------------------------------------------------
# §14 x §12: retries inside replayed segments
# ---------------------------------------------------------------------------


def test_retry_inside_replayed_segment_keeps_plan(tex):
    calls = []

    def flaky(x):
        calls.append(1)
        if len(calls) == 3:  # fail once, on the replayed (second) pass
            raise RuntimeError("mid-replay hiccup")
        return x + 1

    g = TaskGraph("chain")
    a = g.add(lambda: 0, name="a")
    b = g.then(a, flaky, name="b")
    b.affinity = "local"
    b.retry_policy = RetryPolicy(max_attempts=3, backoff=0)
    c = g.then(b, lambda v: v * 10, name="c")
    tex.run(g).result(30)  # pass 1: live, records the plan
    tex.run(g).result(30)  # pass 2: compiles + replays
    tex.run(g).result(30)  # pass 3: replay with a retried member
    assert c.result == 10
    plan = g.replay_plan
    assert plan is not None and not plan.diverged  # retried-to-success: plan survives
    assert tex.stats()["retries"] == 1


def test_exhausted_retry_in_replay_diverges_then_recovers(tex):
    state = {"fail": False}

    def maybe_fail(x):
        if state["fail"]:
            raise RuntimeError("hard failure")
        return x + 1

    g = TaskGraph("chain2")
    a = g.add(lambda: 1, name="a")
    b = g.then(a, maybe_fail, name="b")
    b.affinity = "local"
    b.retry_policy = RetryPolicy(max_attempts=2, backoff=0)
    tex.run(g).result(30)
    tex.run(g).result(30)
    state["fail"] = True  # replayed pass exhausts retries and fails
    with pytest.raises(RuntimeError, match="hard failure"):
        tex.run(g).result(30)
    assert g.replay_plan.diverged
    state["fail"] = False
    with pytest.raises(RuntimeError, match="hard failure"):
        tex.wait_idle(10)  # collect the poisoned-pool error (§8 contract)
    tex.run(g).result(30)  # falls back live, completes
    assert b.result == 2
