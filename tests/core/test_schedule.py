"""Property tests for the trace-time schedule simulator (DESIGN.md §2).

The simulator re-executes the paper's scheduling policy deterministically;
these tests check the two things that make it usable as a schedule compiler:
(1) generated schedules respect every dependency edge, and (2) applied to
pipeline parallelism the policy reproduces canonical 1F1B (makespan and the
S-s activation-memory bound).
"""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fallback shim; requirements-dev.txt pins the real one
    from repro.testing import given, settings, st

from repro.core import (
    SimTask,
    gpipe_schedule,
    peak_activation_buffers,
    pipeline_schedule,
    pipeline_task_graph,
    schedule_to_table,
    simulate,
)


def _check_valid(tasks, res):
    for tid, t in enumerate(tasks):
        for succ in t.successors:
            assert res.start[succ] >= res.end[tid] - 1e-9, (
                f"{tasks[succ].name} started before {t.name} finished"
            )


@st.composite
def random_dags(draw):
    n = draw(st.integers(min_value=1, max_value=40))
    num_workers = draw(st.integers(min_value=1, max_value=6))
    tasks = []
    for i in range(n):
        pinned = draw(st.booleans())
        tasks.append(
            SimTask(
                name=f"t{i}",
                cost=float(draw(st.integers(min_value=1, max_value=5))),
                worker=(
                    draw(st.integers(min_value=0, max_value=num_workers - 1)) if pinned else None
                ),
                priority=float(draw(st.integers(min_value=0, max_value=3))),
            )
        )
    # edges only i -> j with i < j: guaranteed acyclic
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()) and draw(st.integers(0, 3)) == 0:
                tasks[i].successors.append(j)
                tasks[j].num_predecessors += 1
    return tasks, num_workers


@settings(max_examples=60, deadline=None)
@given(random_dags())
def test_simulated_schedules_respect_dependencies(dag):
    tasks, num_workers = dag
    res = simulate(tasks, num_workers)
    _check_valid(tasks, res)
    # every task scheduled exactly once
    scheduled = [tid for tl in res.timelines for (tid, _s, _e) in tl]
    assert sorted(scheduled) == list(range(len(tasks)))
    # pinned tasks ran on their pinned worker
    for w, tl in enumerate(res.timelines):
        for tid, _s, _e in tl:
            if tasks[tid].worker is not None:
                assert tasks[tid].worker == w
    # no worker overlaps itself
    for tl in res.timelines:
        spans = sorted((s, e) for _t, s, e in tl)
        for (s0, e0), (s1, _e1) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-9
    # makespan is at least the critical path and at most the serial time
    assert res.makespan <= sum(t.cost for t in tasks) + 1e-9


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=8),
    st.integers(min_value=1, max_value=24),
)
def test_pipeline_schedule_is_canonical_1f1b(S, M):
    tasks = pipeline_task_graph(S, M)
    res = pipeline_schedule(S, M)
    _check_valid(tasks, res)
    # canonical 1F1B makespan with unit costs
    assert res.makespan == pytest.approx(2 * (S - 1) + 2 * M)
    # 1F1B memory property: stage s buffers at most S - s activations
    peaks = peak_activation_buffers(tasks, res, S)
    for s, p in enumerate(peaks):
        assert p <= S - s
    # work conservation: every stage runs one F and one B per microbatch
    table = schedule_to_table(tasks, res, S)
    for s in range(S):
        ops = [row[s] for row in table if row[s] is not None]
        assert len(ops) == 2 * M
        assert sorted((o.kind, o.microbatch) for o in ops) == sorted(
            [("F", m) for m in range(M)] + [("B", m) for m in range(M)]
        )


@settings(max_examples=20, deadline=None)
@given(
    st.integers(min_value=2, max_value=8),
    st.integers(min_value=2, max_value=16),
)
def test_gpipe_buffers_all_microbatches_1f1b_does_not(S, M):
    onef1b_tasks = pipeline_task_graph(S, M)
    onef1b = pipeline_schedule(S, M)
    g_tasks = pipeline_task_graph(S, M, memory_limited=False)
    gpipe = gpipe_schedule(S, M)
    _check_valid(g_tasks, gpipe)
    g_peaks = peak_activation_buffers(g_tasks, gpipe, S)
    o_peaks = peak_activation_buffers(onef1b_tasks, onef1b, S)
    assert max(g_peaks) == M  # GPipe buffers every microbatch
    assert max(o_peaks) == min(S, M)  # 1F1B caps at pipeline depth
    # and the anti-dependency edges cost no throughput with unit costs
    assert onef1b.makespan <= gpipe.makespan + 1e-9


def test_work_stealing_balances_unpinned_tasks():
    """Independent unpinned tasks spread across workers via stealing."""
    tasks = [SimTask(name=f"t{i}", cost=1.0) for i in range(16)]
    res = simulate(tasks, 4)
    sizes = [len(tl) for tl in res.timelines]
    assert sum(sizes) == 16
    assert res.makespan == pytest.approx(4.0)  # perfect balance


def test_deadlock_detection():
    a = SimTask(name="a")
    b = SimTask(name="b")
    a.successors.append(1)
    b.num_predecessors = 1
    b.successors.append(0)
    a.num_predecessors = 1  # cycle
    with pytest.raises(RuntimeError, match="deadlock"):
        simulate([a, b], 2)
