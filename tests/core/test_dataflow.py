"""Dataflow runtime tests (DESIGN.md §8): value-passing edges, re-runnable
graphs, composition, combinators, and the as_future sink-membership fix."""
import threading

import pytest

from repro.core import CancelledError, Task, TaskGraph, ThreadPool


# ---------------------------------------------------------------------------
# value-passing
# ---------------------------------------------------------------------------


def test_diamond_value_passing_rerun_identical():
    """Acceptance: a diamond run twice via as_future produces identical,
    correctly-ordered results with no manual state reset beyond
    TaskGraph.reset()."""
    g = TaskGraph("diamond")
    a = g.add(lambda: 2, name="a")
    b = g.then(a, lambda x: x + 1, name="b")
    c = g.then(a, lambda x: x * 10, name="c")
    d = g.gather([b, c], lambda bx, cx: (bx, cx), name="d")
    with ThreadPool(4) as pool:
        assert g.as_future(pool).result(10) is None
        first = d.result
        g.reset()
        assert g.as_future(pool).result(10) is None
        second = d.result
    # argument order is the succeed order (b then c), both runs identical
    assert first == second == (3, 20)
    assert g.run_count == 2


def test_value_passing_argument_order_is_wiring_order():
    g = TaskGraph()
    srcs = [g.add(lambda i=i: i, name=f"s{i}") for i in range(6)]
    out = g.gather(srcs, name="collect")
    with ThreadPool(4) as pool:
        g.as_future(pool).result(10)
    assert out.result == [0, 1, 2, 3, 4, 5]


def test_then_chain_on_task():
    g = TaskGraph()
    last = g.add(lambda: 5).then(lambda x: x * x).then(lambda x: x + 1)
    with ThreadPool(2) as pool:
        g.as_future(pool).result(10)
    assert last.result == 26


def test_then_requires_graph_membership():
    t = Task(lambda: 1)
    with pytest.raises(ValueError, match="TaskGraph.add"):
        t.then(lambda x: x)


def test_after_is_ordering_only():
    """after() wires a dependency without recording an argument slot."""
    g = TaskGraph()
    order = []
    gate = g.add(lambda: order.append("gate"), name="gate")
    val = g.add(lambda: 7, name="val")
    consumer = g.add(lambda x: (order.append("consumer"), x * 2)[1], takes_inputs=True)
    consumer.succeed(val)  # one argument slot
    consumer.after(gate)  # ordering only — no slot
    with ThreadPool(2) as pool:
        g.as_future(pool).result(10)
    assert consumer.result == 14
    assert order == ["gate", "consumer"]


def test_dataflow_failure_propagates_along_edges():
    """A failed input skips downstream bodies and delivers the original
    exception through the edges (propagate_errors=False: pool stays clean)."""
    g = TaskGraph()
    boom = g.add(lambda: (_ for _ in ()).throw(ValueError("boom")), name="boom")
    mid = g.then(boom, lambda x: x + 1, name="mid")
    ran = []
    out = g.then(mid, lambda x: ran.append(x), name="out")
    for t in g.tasks:
        t.propagate_errors = False
    with ThreadPool(2) as pool:
        with pytest.raises(ValueError, match="boom"):
            g.as_future(pool).result(10)
        assert ran == []
        assert isinstance(out.exception, ValueError)
        pool.wait_idle(10)  # not poisoned
        ok = []
        pool.run(lambda: ok.append(1))
        assert ok == [1]


def test_reset_clears_per_run_results():
    g = TaskGraph()
    t = g.add(lambda: 42)
    with ThreadPool(2) as pool:
        g.as_future(pool).result(10)
    assert t.result == 42
    g.reset()
    assert t.result is None and t.exception is None


# ---------------------------------------------------------------------------
# re-run lifecycle
# ---------------------------------------------------------------------------


def test_build_once_run_many_as_future():
    g = TaskGraph("loop")
    acc = []
    counter = g.add(lambda: acc.append(len(acc)) or len(acc), name="count")
    sq = g.then(counter, lambda n: n * n, name="sq")
    with ThreadPool(2) as pool:
        results = []
        for _ in range(5):
            g.as_future(pool).result(10)
            results.append(sq.result)
    assert results == [1, 4, 9, 16, 25]
    assert g.run_count == 5


def test_run_count_tracks_plain_submission():
    g = TaskGraph()
    g.add(lambda: None)
    with ThreadPool(2) as pool:
        pool.run(g)
        pool.run(g)
    assert g.run_count == 2


def test_cancel_then_resubmit():
    """A cancelled round leaves no residue: reset() + as_future runs clean."""
    pool = ThreadPool(1)
    gate = threading.Event()
    pool.submit(lambda: gate.wait(10))
    import time

    time.sleep(0.05)  # worker parked on the gate; graph tasks queue behind it
    ran = []
    g = TaskGraph()
    a = g.add(lambda: ran.append("a"))
    g.then(a, lambda _x: ran.append("b"))
    fut = g.as_future(pool)
    assert fut.cancel() is True
    gate.set()
    pool.wait_idle(10)
    with pytest.raises(CancelledError):
        fut.result(5)
    assert ran == []
    # resubmit after an explicit reset: the graph runs normally
    g.reset()
    assert g.as_future(pool).result(10) is None
    assert ran == ["a", "b"]
    assert g.run_count == 2
    pool.close()


# ---------------------------------------------------------------------------
# as_future sink membership (satellite fix)
# ---------------------------------------------------------------------------


def _fin_preds(g):
    """Tasks currently wired into the hidden completion task."""
    fin = g._fin
    return {t.name for t in g.tasks if fin in t.successors}


def test_sink_rewiring_tracks_membership():
    """A task that gains a real successor after being wired as a sink is
    unwired from the completion task on the next round."""
    g = TaskGraph("grow")
    order = []
    a = g.add(lambda: order.append("a"), name="a")
    with ThreadPool(2) as pool:
        g.as_future(pool).result(10)
        assert _fin_preds(g) == {"a"}
        # a gains a real successor between rounds
        b = g.add(lambda: order.append("b"), name="b")
        b.after(a)
        g.as_future(pool).result(10)
        # a is no longer a sink: only b holds the graph open
        assert _fin_preds(g) == {"b"}
        assert g._fin.num_predecessors == 1
        assert order == ["a", "a", "b"]


def test_sink_rewiring_no_accumulation_over_rounds():
    g = TaskGraph()
    t = g.add(lambda: None, name="only")
    with ThreadPool(2) as pool:
        for _ in range(4):
            g.as_future(pool).result(10)
    assert g._fin.num_predecessors == 1
    assert t.successors.count(g._fin) == 1


# ---------------------------------------------------------------------------
# composition
# ---------------------------------------------------------------------------


def test_compose_gathers_subgraph_results():
    outer = TaskGraph("outer")
    sub = TaskGraph("sub")
    sub.add(lambda: 1, name="one")
    sub.add(lambda: 2, name="two")
    prep = outer.add(lambda: None, name="prep")
    m = outer.compose(sub)
    m.source.after(prep)
    total = outer.then(m.sink, lambda vals: sum(vals), name="total")
    with ThreadPool(4) as pool:
        outer.as_future(pool).result(10)
    assert total.result == 3
    # adopted tasks belong to the outer graph now
    assert all(t.graph is outer for t in sub.tasks)


def test_compose_respects_boundary_ordering():
    outer = TaskGraph()
    events = []
    sub = TaskGraph("sub")
    sub.chain([lambda: events.append("s0"), lambda: events.append("s1")])
    before = outer.add(lambda: events.append("before"))
    m = outer.compose(sub)
    m.source.after(before)
    outer.then(m.sink, lambda _vals: events.append("after"))
    with ThreadPool(4) as pool:
        outer.as_future(pool).result(10)
    assert events == ["before", "s0", "s1", "after"]


def test_composed_graph_is_rerunnable():
    outer = TaskGraph()
    sub = TaskGraph("sub")
    sub.add(lambda: 10, name="x")
    m = outer.compose(sub)
    out = outer.then(m.sink, lambda vals: vals[0] + 1)
    with ThreadPool(2) as pool:
        results = []
        for _ in range(3):
            outer.as_future(pool).result(10)
            results.append(out.result)
    assert results == [11, 11, 11]


# ---------------------------------------------------------------------------
# validate (satellite fix: no mid-iteration mutation)
# ---------------------------------------------------------------------------


def test_validate_adopts_externals_after_walk():
    g = TaskGraph()
    a = g.add(lambda: None, name="a")
    outside1 = Task(lambda: None, name="out1")
    outside2 = Task(lambda: None, name="out2")
    outside1.succeed(a)
    outside2.succeed(outside1)  # two levels deep
    g.validate()
    assert {t.name for t in g.tasks} == {"a", "out1", "out2"}
    # adopted exactly once; a second validate is a no-op
    g.validate()
    assert len(g.tasks) == 3


def test_validate_ignores_hidden_completion_task():
    g = TaskGraph()
    g.add(lambda: None)
    with ThreadPool(2) as pool:
        g.as_future(pool).result(10)
    g.validate()  # the hidden ::done task must not be adopted
    assert len(g.tasks) == 1


def test_validate_cycle_still_detected():
    from repro.core import CycleError

    g = TaskGraph("cyclic")
    a = g.add(lambda: None)
    b = g.add(lambda: None)
    a.succeed(b)
    b.succeed(a)
    with pytest.raises(CycleError):
        g.validate()


def test_as_future_on_poisoned_pool_reports_cancellation():
    """Regression: a graph whose bodies were skipped because the shared pool
    was poisoned by an unrelated failure must not resolve successfully."""
    with ThreadPool(1) as pool:
        gate = threading.Event()

        def boom():
            gate.wait(10)
            raise RuntimeError("unrelated failure")

        pool.submit(boom)  # poisons the pool once it runs
        g = TaskGraph()
        ran = []
        g.add(lambda: ran.append(1))
        fut = g.as_future(pool)  # queued behind the gate task
        gate.set()
        with pytest.raises((CancelledError, RuntimeError)):
            fut.result(10)
        assert ran == []  # the body never executed — and the future said so
        with pytest.raises(RuntimeError):
            pool.wait_idle(10)  # drain the poison marker


def test_compose_empty_subgraph_preserves_ordering():
    """Regression: an empty composed module's sink must still run after the
    module's upstream ordering edges (checkpoint of an empty pytree)."""
    outer = TaskGraph()
    events = []
    prep = outer.add(lambda: events.append("prepare"))
    m = outer.compose(TaskGraph("empty"))
    m.source.after(prep)
    outer.then(m.sink, lambda vals: events.append(("commit", vals)))
    with ThreadPool(4) as pool:
        outer.as_future(pool).result(10)
    assert events == ["prepare", ("commit", [])]
