"""Observer-layer tests (DESIGN.md §8): lifecycle hooks, aggregate stats,
and Chrome-trace export validity."""
import json
import threading

from repro.core import (
    ChromeTraceObserver,
    PoolObserver,
    StatsObserver,
    TaskGraph,
    ThreadPool,
)


class Recorder(PoolObserver):
    def __init__(self):
        self.events = []
        self._lock = threading.Lock()

    def on_submit(self, task):
        with self._lock:
            self.events.append(("submit", task.name))

    def on_start(self, task, worker):
        with self._lock:
            self.events.append(("start", task.name))

    def on_finish(self, task, worker):
        with self._lock:
            self.events.append(("finish", task.name))

    def on_steal(self, task, thief, victim):
        with self._lock:
            self.events.append(("steal", task.name))


def test_observer_sees_lifecycle_events():
    rec = Recorder()
    with ThreadPool(2, observers=[rec]) as pool:
        g = TaskGraph()
        a = g.add(lambda: None, name="a")
        g.add(lambda: None, name="b").succeed(a)
        pool.run(g)
    kinds = [k for k, _ in rec.events]
    assert kinds.count("start") == 2 and kinds.count("finish") == 2
    # the root is submitted; the continuation (b) runs inline, no re-queue
    assert ("submit", "a") in rec.events
    starts = [n for k, n in rec.events if k == "start"]
    assert starts == ["a", "b"]


def test_add_remove_observer():
    rec = Recorder()
    with ThreadPool(1) as pool:
        pool.run(lambda: None)
        pool.add_observer(rec)
        pool.run(lambda: None)
        pool.remove_observer(rec)
        pool.remove_observer(rec)  # absent: no-op
        pool.run(lambda: None)
    assert [k for k, _ in rec.events].count("finish") == 1


def test_observer_exceptions_are_swallowed():
    class Broken(PoolObserver):
        def on_start(self, task, worker):
            raise RuntimeError("observer bug")

    with ThreadPool(1, observers=[Broken()]) as pool:
        hits = []
        pool.run(lambda: hits.append(1))
        assert hits == [1]


def test_stats_observer_counts_and_timing():
    obs = StatsObserver()
    with ThreadPool(2, observers=[obs]) as pool:
        g = TaskGraph()
        for i in range(8):
            g.add(lambda: sum(range(200)), name=f"work:{i}")
        pool.run(g)
    s = obs.summary()
    assert s["started"] == s["finished"] == 8
    assert s["errors"] == 0
    assert s["by_name"]["work"]["count"] == 8
    assert s["by_name"]["work"]["total_s"] >= 0.0


def test_stats_observer_sees_steals():
    """One worker parks holding a gate after pushing tasks to its own deque;
    the other worker can only get them by stealing."""
    obs = StatsObserver()
    with ThreadPool(2, observers=[obs]) as pool:
        gate = threading.Event()
        done = threading.Event()
        remaining = [6]
        lock = threading.Lock()

        def child():
            with lock:
                remaining[0] -= 1
                if remaining[0] == 0:
                    done.set()

        def parent():
            for _ in range(6):
                pool.submit(child)  # lands in this worker's own deque
            done.wait(10)  # hold this worker until the children finish
            gate.set()

        pool.submit(parent)
        assert gate.wait(10)
        assert pool.wait_idle(10)
    assert obs.stolen >= 1
    assert pool.stats()["steals"] >= 1


def test_chrome_trace_is_valid_trace_event_json():
    """Acceptance: the exporter output loads as trace-event JSON — a dict
    with a traceEvents list of complete events carrying name/ph/ts/dur and
    integer pid/tid, exactly what chrome://tracing ingests."""
    tracer = ChromeTraceObserver()
    with ThreadPool(2, observers=[tracer]) as pool:
        g = TaskGraph("traced")
        a = g.add(lambda: sum(range(100)), name="root")
        g.then(a, lambda x: x + 1, name="child")
        pool.run(g)
        payload = tracer.to_json(num_workers=pool.num_threads)
    trace = json.loads(payload)  # round-trips as strict JSON
    assert isinstance(trace, dict)
    events = trace["traceEvents"]
    assert isinstance(events, list) and events
    complete = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in complete} >= {"root", "child"}
    for e in complete:
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
    # worker-name metadata present for every lane
    meta = [e for e in events if e.get("ph") == "M"]
    assert {m["tid"] for m in meta} == {0, 1}


def test_chrome_trace_save_roundtrip(tmp_path):
    tracer = ChromeTraceObserver()
    with ThreadPool(1, observers=[tracer]) as pool:
        pool.run(lambda: None)
    path = tmp_path / "trace.json"
    tracer.save(path)
    trace = json.loads(path.read_text())
    assert any(e["ph"] == "X" for e in trace["traceEvents"])


def test_chrome_trace_marks_errors_and_cancellations():
    tracer = ChromeTraceObserver()
    with ThreadPool(1, observers=[tracer]) as pool:
        f = pool.submit_future(lambda: 1 / 0)
        try:
            f.result(10)
        except ZeroDivisionError:
            pass
        assert pool.wait_idle(10)
    events = json.loads(tracer.to_json())["traceEvents"]
    assert any("error" in e.get("args", {}) for e in events)


def test_stats_observer_counts_retries_and_timeouts():
    """§14 observability: StatsObserver's summary carries the retried /
    timed_out counters alongside the lifecycle counts."""
    from repro.core import RetryPolicy, checkpoint

    stats = StatsObserver()
    attempts = [0]

    def flaky():
        attempts[0] += 1
        if attempts[0] < 3:
            raise ValueError("transient")
        return attempts[0]

    def wedged():
        import time

        while True:
            time.sleep(0.005)
            checkpoint()

    with ThreadPool(2, observers=[stats]) as pool:
        g = TaskGraph("faulty")
        g.add(flaky, name="flaky", retry=RetryPolicy(max_attempts=3, backoff=0.0))
        w = g.add(wedged, name="wedged", timeout=0.05)
        w.propagate_errors = False
        pool.run(g)
    s = stats.summary()
    assert s["retried"] == 2
    assert s["timed_out"] == 1
    assert s["finished"] >= 2  # both tasks still complete their lifecycle


def test_chrome_trace_marks_retries_and_timeouts():
    """§14 observability: retries show up as "retry:<name>" complete events
    (cat "fault", args.attempt) and timeouts as "timeout:<name>" instants."""
    from repro.core import RetryPolicy, checkpoint

    tracer = ChromeTraceObserver()
    attempts = [0]

    def flaky():
        attempts[0] += 1
        if attempts[0] < 2:
            raise ValueError("transient")

    def wedged():
        import time

        while True:
            time.sleep(0.005)
            checkpoint()

    with ThreadPool(2, observers=[tracer]) as pool:
        g = TaskGraph("faulty")
        g.add(flaky, name="flaky", retry=RetryPolicy(max_attempts=2, backoff=0.0))
        w = g.add(wedged, name="wedged", timeout=0.05)
        w.propagate_errors = False
        pool.run(g)
    events = json.loads(tracer.to_json())["traceEvents"]
    retries = [e for e in events if e["name"] == "retry:flaky"]
    assert len(retries) == 1
    assert retries[0]["ph"] == "X" and retries[0]["cat"] == "fault"
    assert retries[0]["args"]["attempt"] == 1
    timeouts = [e for e in events if e["name"] == "timeout:wedged"]
    assert len(timeouts) == 1
    assert timeouts[0]["ph"] == "i" and timeouts[0]["cat"] == "fault"
