"""Unit + property tests for the work-stealing deques (paper §2.1)."""
import threading

import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # fallback shim; requirements-dev.txt pins the real one
    from repro.testing import given, settings, st

from repro.core import EMPTY, ChaseLevDeque, FastDeque

DEQUES = [FastDeque, ChaseLevDeque]


@pytest.mark.parametrize("cls", DEQUES)
def test_lifo_owner_fifo_thief(cls):
    dq = cls()
    for i in range(10):
        dq.push(i)
    assert dq.pop() == 9  # owner: LIFO bottom
    assert dq.steal() == 0  # thief: FIFO top
    assert dq.steal() == 1
    assert dq.pop() == 8
    assert len(dq) == 6


@pytest.mark.parametrize("cls", DEQUES)
def test_empty_sentinel(cls):
    dq = cls()
    assert dq.pop() is EMPTY
    assert dq.steal() is EMPTY
    dq.push(None)  # None is a valid payload
    assert dq.pop() is None
    assert dq.pop() is EMPTY


def test_chase_lev_growth():
    dq = ChaseLevDeque(capacity=4)
    for i in range(1000):
        dq.push(i)
    assert len(dq) == 1000
    got = [dq.steal() for _ in range(500)] + [dq.pop() for _ in range(500)]
    assert set(got) == set(range(1000))
    assert dq.pop() is EMPTY


def test_chase_lev_capacity_validation():
    with pytest.raises(ValueError):
        ChaseLevDeque(capacity=3)


@pytest.mark.parametrize("cls", DEQUES)
@settings(max_examples=50, deadline=None)
@given(ops=st.lists(st.sampled_from(["push", "pop", "steal"]), max_size=200))
def test_sequential_model_equivalence(cls, ops):
    """Single-threaded: deque behaves as a double-ended queue (model-based)."""
    dq = cls()
    model: list[int] = []
    counter = 0
    for op in ops:
        if op == "push":
            dq.push(counter)
            model.append(counter)
            counter += 1
        elif op == "pop":
            got = dq.pop()
            want = model.pop() if model else EMPTY
            assert got == want or (got is EMPTY and want is EMPTY)
        else:
            got = dq.steal()
            want = model.pop(0) if model else EMPTY
            assert got == want or (got is EMPTY and want is EMPTY)
    assert len(dq) == len(model)


def test_chase_lev_grow_under_concurrent_steals():
    """Satellite: force ring resizes while thieves hammer the steal lock —
    no task may be lost or duplicated across _grow's buffer copy."""
    dq = ChaseLevDeque(capacity=4)
    N = 30_000
    n_thieves = 3
    taken: list[list[int]] = [[] for _ in range(n_thieves + 1)]
    stop = threading.Event()
    start = threading.Barrier(n_thieves + 1)

    def thief(slot):
        start.wait()
        while not stop.is_set() or len(dq):
            item = dq.steal()
            if item is not EMPTY:
                taken[slot].append(item)

    threads = [threading.Thread(target=thief, args=(i,)) for i in range(n_thieves)]
    for t in threads:
        t.start()
    start.wait()
    # push in bursts with no owner pops, so the ring repeatedly fills and
    # grows while the thieves contend on the lock mid-copy
    for i in range(N):
        dq.push(i)
    while True:
        got = dq.pop()
        if got is EMPTY:
            break
        taken[n_thieves].append(got)
    stop.set()
    for t in threads:
        t.join()
    assert dq._mask + 1 > 4, "ring never grew — the stress did not trigger _grow"
    everything = [x for sub in taken for x in sub]
    assert len(everything) == N, f"lost/duplicated: {len(everything)} != {N}"
    assert set(everything) == set(range(N))


@pytest.mark.parametrize("cls", DEQUES)
def test_concurrent_owner_and_thieves_no_loss_no_dup(cls):
    """One owner pushes/pops while thieves steal: every item taken exactly once.

    This is the Chase-Lev correctness contract (single producer at the
    bottom, concurrent consumers at the top).
    """
    dq = cls()
    N = 20_000
    n_thieves = 3
    taken: list[list[int]] = [[] for _ in range(n_thieves + 1)]
    stop = threading.Event()

    def thief(slot):
        while not stop.is_set() or len(dq):
            item = dq.steal()
            if item is not EMPTY:
                taken[slot].append(item)

    threads = [threading.Thread(target=thief, args=(i,)) for i in range(n_thieves)]
    for t in threads:
        t.start()
    # owner: interleave pushes with occasional pops
    for i in range(N):
        dq.push(i)
        if i % 3 == 0:
            got = dq.pop()
            if got is not EMPTY:
                taken[n_thieves].append(got)
    while True:
        got = dq.pop()
        if got is EMPTY:
            break
        taken[n_thieves].append(got)
    stop.set()
    for t in threads:
        t.join()
    everything = [x for sub in taken for x in sub]
    assert len(everything) == N, f"lost/duplicated: {len(everything)} != {N}"
    assert set(everything) == set(range(N))


# ---------------------------------------------------------------------------
# PriorityDeque single-band fast path (DESIGN.md §9)
# ---------------------------------------------------------------------------


class _Item:
    __slots__ = ("tag", "priority")

    def __init__(self, tag, priority=0.0):
        self.tag, self.priority = tag, priority


def test_priority_deque_starts_on_fast_path():
    from repro.core import PriorityDeque

    dq = PriorityDeque()
    assert not dq.banded
    for i in range(4):
        dq.push(_Item(i))
    assert not dq.banded  # priority 0.0 never promotes
    assert len(dq) == 4
    assert dq.pop().tag == 3  # owner LIFO
    assert dq.steal().tag == 0  # thief FIFO
    assert len(dq) == 2


def test_priority_deque_promotes_on_first_nonzero_priority():
    from repro.core import EMPTY, PriorityDeque

    dq = PriorityDeque()
    dq.push(_Item("plain"))
    assert not dq.banded
    dq.push(_Item("hi", 2.0))
    assert dq.banded  # one-way promotion
    dq.push(_Item("plain2"))  # 0.0 items keep landing in the same band
    assert len(dq) == 3
    assert dq.pop().tag == "hi"  # highest band first
    assert dq.pop().tag == "plain2"
    assert dq.steal().tag == "plain"
    assert dq.pop() is EMPTY
    assert dq.banded  # promotion never reverts


def test_priority_deque_fast_path_items_visible_after_promotion():
    """Items pushed on the fast path are band 0.0 — promotion must not
    strand them (the fast deque IS the 0.0 band)."""
    from repro.core import EMPTY, PriorityDeque

    dq = PriorityDeque()
    for i in range(8):
        dq.push(_Item(i))
    dq.push(_Item("lo", -1.0))
    got = []
    while True:
        item = dq.steal()
        if item is EMPTY:
            break
        got.append(item.tag)
    assert got == list(range(8)) + ["lo"]  # higher band drains first, FIFO


def test_priority_deque_depths_snapshot():
    """§13 monitoring: per-band depth, highest band first, empty bands kept."""
    from repro.core import PriorityDeque

    dq = PriorityDeque()
    assert dq.depths() == {0.0: 0}  # fast path reports band 0.0
    dq.push(_Item("a"))
    dq.push(_Item("b", 1.0))
    dq.push(_Item("c", 1.0))
    dq.push(_Item("d", -0.5))
    assert dq.depths() == {1.0: 2, 0.0: 1, -0.5: 1}
    assert list(dq.depths()) == [1.0, 0.0, -0.5]  # descending priority
    dq.pop()
    dq.pop()
    assert dq.depths() == {1.0: 0, 0.0: 1, -0.5: 1}  # drained band persists
