"""Tests for the TaskGraph container."""
import pytest

from repro.core import CycleError, TaskGraph, ThreadPool


def test_cycle_detection():
    g = TaskGraph("cyclic")
    a = g.add(lambda: None)
    b = g.add(lambda: None)
    a.succeed(b)
    b.succeed(a)
    with pytest.raises(CycleError):
        g.validate()


def test_cycle_error_names_tasks_and_prints_path():
    """Satellite regression: the CycleError message must name the offending
    tasks and print the witness cycle path (not just a count)."""
    g = TaskGraph("pipeline")
    a = g.add(lambda: None, name="load")
    b = g.add(lambda: None, name="transform")
    c = g.add(lambda: None, name="store")
    b.succeed(a)
    c.succeed(b)
    a.succeed(c)  # closes the strong cycle
    with pytest.raises(CycleError) as exc:
        g.validate()
    msg = str(exc.value)
    assert msg == (
        "task graph 'pipeline': 3 task(s) unreachable from roots — "
        "strong dependency cycle: load -> transform -> store -> load"
    )


def test_find_strong_cycle_ignores_weak_back_edges():
    g = TaskGraph("loop")
    entry = g.add(None, name="entry")
    body = g.add(lambda: None, name="body")
    body.after(entry)
    cond = g.add(lambda: 0, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)  # weak back-edge: a legal §10 loop
    assert g.find_strong_cycle() is None
    g.validate()  # weak cycles stay legal


def test_edges_reports_strength_per_task_kind():
    g = TaskGraph("edges")
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    b.succeed(a)
    c = g.add(lambda: 0, kind="condition", name="c")
    c.after(b)
    c.precede(a)
    edges = {(u.name, v.name): strong for u, v, strong in g.edges()}
    assert edges == {("a", "b"): True, ("b", "c"): True, ("c", "a"): False}


def test_roots_and_validate_ok():
    g = TaskGraph()
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    c = g.add(lambda: None, name="c")
    c.succeed(a, b)
    g.validate()
    assert set(t.name for t in g.roots()) == {"a", "b"}


def test_critical_path():
    g = TaskGraph()
    chain = g.chain([lambda: None] * 5)
    assert len(chain) == 5
    extra = g.add(lambda: None)
    extra.succeed(chain[0])
    assert g.critical_path() == pytest.approx(5.0)


def test_map_reduce_runs():
    acc = []
    g = TaskGraph()
    g.map_reduce([lambda i=i: acc.append(i) for i in range(8)], lambda: acc.append("done"))
    with ThreadPool(4) as pool:
        pool.run(g)
    assert acc[-1] == "done"
    assert sorted(acc[:-1]) == list(range(8))


def test_to_dot():
    g = TaskGraph("viz")
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    b.succeed(a)
    dot = g.to_dot()
    assert "digraph" in dot and "->" in dot


def test_validate_pulls_in_external_successors():
    g = TaskGraph()
    a = g.add(lambda: None)
    from repro.core import Task

    outside = Task(lambda: None, name="outside")
    outside.succeed(a)
    g.validate()  # must notice `outside` through the successor edge
    assert any(t.name == "outside" for t in g.tasks)
