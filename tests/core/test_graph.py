"""Tests for the TaskGraph container."""
import pytest

from repro.core import CycleError, TaskGraph, ThreadPool


def test_cycle_detection():
    g = TaskGraph("cyclic")
    a = g.add(lambda: None)
    b = g.add(lambda: None)
    a.succeed(b)
    b.succeed(a)
    with pytest.raises(CycleError):
        g.validate()


def test_roots_and_validate_ok():
    g = TaskGraph()
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    c = g.add(lambda: None, name="c")
    c.succeed(a, b)
    g.validate()
    assert set(t.name for t in g.roots()) == {"a", "b"}


def test_critical_path():
    g = TaskGraph()
    chain = g.chain([lambda: None] * 5)
    assert len(chain) == 5
    extra = g.add(lambda: None)
    extra.succeed(chain[0])
    assert g.critical_path() == pytest.approx(5.0)


def test_map_reduce_runs():
    acc = []
    g = TaskGraph()
    g.map_reduce([lambda i=i: acc.append(i) for i in range(8)], lambda: acc.append("done"))
    with ThreadPool(4) as pool:
        pool.run(g)
    assert acc[-1] == "done"
    assert sorted(acc[:-1]) == list(range(8))


def test_to_dot():
    g = TaskGraph("viz")
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    b.succeed(a)
    dot = g.to_dot()
    assert "digraph" in dot and "->" in dot


def test_validate_pulls_in_external_successors():
    g = TaskGraph()
    a = g.add(lambda: None)
    from repro.core import Task

    outside = Task(lambda: None, name="outside")
    outside.succeed(a)
    g.validate()  # must notice `outside` through the successor edge
    assert any(t.name == "outside" for t in g.tasks)
