"""Behaviour tests for the work-stealing thread pool (paper §2, §4)."""
import threading
import time

import pytest

from repro.core import (
    CancelledError,
    ChaseLevDeque,
    NaiveThreadPool,
    TaskGraph,
    ThreadPool,
)

POOLS = [
    lambda n: ThreadPool(n),
    lambda n: ThreadPool(n, deque_cls=ChaseLevDeque),
    lambda n: NaiveThreadPool(n),
]
POOL_IDS = ["ws-fast", "ws-chaselev", "naive-baseline"]


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_submit_callable(make):
    with make(4) as pool:
        hits = []
        pool.run(lambda: hits.append(1))
        assert hits == [1]


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_paper_arithmetic_example(make):
    """The (a+b)*(c+d) task graph from paper §4.2."""
    with make(4) as pool:
        vals = {}
        g = TaskGraph("arith")
        get_a = g.emplace_back(lambda: vals.__setitem__("a", 1))
        get_b = g.emplace_back(lambda: vals.__setitem__("b", 2))
        get_c = g.emplace_back(lambda: vals.__setitem__("c", 3))
        get_d = g.emplace_back(lambda: vals.__setitem__("d", 4))
        get_sum_ab = g.emplace_back(lambda: vals.__setitem__("ab", vals["a"] + vals["b"]))
        get_sum_cd = g.emplace_back(lambda: vals.__setitem__("cd", vals["c"] + vals["d"]))
        get_product = g.emplace_back(lambda: vals.__setitem__("p", vals["ab"] * vals["cd"]))
        get_sum_ab.Succeed(get_a, get_b)
        get_sum_cd.Succeed(get_c, get_d)
        get_product.Succeed(get_sum_ab, get_sum_cd)
        pool.run(g)
        assert vals["p"] == (1 + 2) * (3 + 4)


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_graph_resubmission(make):
    """Counters re-arm on submit: the same graph object runs repeatedly."""
    with make(2) as pool:
        order = []
        g = TaskGraph()
        first = g.add(lambda: order.append("first"))
        second = g.add(lambda: order.append("second"))
        second.succeed(first)
        for _ in range(5):
            pool.run(g)
        assert order == ["first", "second"] * 5


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_dependency_ordering_diamond_stress(make):
    """Many diamonds: successors must never observe unfinished predecessors."""
    with make(4) as pool:
        violations = []
        g = TaskGraph()
        done = [False] * 400
        for base in range(0, 400, 4):
            def mk_leaf(i=base):
                def fn():
                    done[i] = True
                return fn

            def mk_mid(i=base):
                def fn():
                    if not done[i]:
                        violations.append(i)
                    done[i + 1] = True
                    done[i + 2] = True
                return fn

            def mk_join(i=base):
                def fn():
                    if not (done[i + 1] and done[i + 2]):
                        violations.append(i)
                    done[i + 3] = True
                return fn

            leaf = g.add(mk_leaf())
            m1 = g.add(mk_mid()).succeed(leaf)
            m2 = g.add(mk_mid()).succeed(leaf)
            g.add(mk_join()).succeed(m1, m2)
        pool.run(g)
        assert not violations
        assert all(done)


def test_submit_from_worker_uses_own_deque():
    """The paper's thread-local fast path: tasks spawned inside a worker are
    pushed to that worker's own deque and (with one worker) run before the
    parent returns to stealing."""
    with ThreadPool(1) as pool:
        order = []

        def parent():
            order.append("parent")
            pool.submit(lambda: order.append("child"))

        pool.run(parent)
        assert order == ["parent", "child"]


def test_continuation_runs_on_same_thread():
    """Exactly one newly-ready successor continues on the finishing worker."""
    with ThreadPool(2) as pool:
        tids = {}
        g = TaskGraph()
        a = g.add(lambda: tids.__setitem__("a", threading.get_ident()))
        b = g.add(lambda: tids.__setitem__("b", threading.get_ident()))
        b.succeed(a)
        pool.run(g)
        assert tids["a"] == tids["b"]


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_exception_propagates_on_wait(make):
    with make(2) as pool:
        def boom():
            raise ValueError("boom")

        pool.submit(boom)
        with pytest.raises(ValueError, match="boom"):
            pool.wait_idle(timeout=10)
        # pool stays usable afterwards
        ok = []
        pool.run(lambda: ok.append(1))
        assert ok == [1]


def test_failed_predecessor_cancels_successors_but_drains():
    with ThreadPool(2) as pool:
        ran = []
        g = TaskGraph()
        a = g.add(lambda: (_ for _ in ()).throw(RuntimeError("fail")))
        b = g.add(lambda: ran.append("b"))
        b.succeed(a)
        with pytest.raises(RuntimeError):
            pool.run(g)
        # b was cancelled, not executed, and the pool drained (no hang)
        assert ran == [] and isinstance(b.exception, (CancelledError, type(None)))


def test_future_result_and_exception():
    with ThreadPool(2) as pool:
        assert pool.submit_future(lambda: 7 * 6).result(5) == 42
        f = pool.submit_future(lambda: 1 / 0)
        with pytest.raises(ZeroDivisionError):
            f.result(5)
        pool.wait_idle()  # future errors do not poison the pool


def test_wait_idle_timeout_returns_false():
    """§10 satellite: timeout is reported as False, never conflated with a
    task failure (which raises); the eventual successful wait returns True."""
    with ThreadPool(1) as pool:
        pool.submit(lambda: time.sleep(0.5))
        assert pool.wait_idle(timeout=0.01) is False
        assert pool.wait_idle(timeout=10) is True


def test_wait_idle_timeout_preserves_error_for_next_wait():
    """A timed-out wait must not swallow the first-error marker."""
    with ThreadPool(1) as pool:
        release = threading.Event()

        def boom():
            release.wait(5)
            raise ValueError("late boom")

        pool.submit(boom)
        assert pool.wait_idle(timeout=0.01) is False
        release.set()
        with pytest.raises(ValueError, match="late boom"):
            pool.wait_idle(timeout=10)


def build_fib_graph(g: TaskGraph, n: int, results: dict, key: str):
    """The paper's benchmark workload: the full recursion DAG of fib(n)
    without memoization (one task per call site)."""
    if n < 2:
        return g.add(lambda k=key, v=n: results.__setitem__(k, v))
    left = build_fib_graph(g, n - 1, results, key + "l")
    right = build_fib_graph(g, n - 2, results, key + "r")
    join = g.add(lambda k=key: results.__setitem__(k, results[k + "l"] + results[k + "r"]))
    return join.succeed(left, right)


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_recursive_fibonacci_task_graph(make):
    with make(4) as pool:
        results = {}
        g = TaskGraph("fib")
        build_fib_graph(g, 12, results, "r")
        assert len(g) == 465  # 2*fib(13)-1 call sites
        pool.run(g)
        assert results["r"] == 144


@pytest.mark.parametrize("make", POOLS, ids=POOL_IDS)
def test_many_independent_tasks_stress(make):
    with make(4) as pool:
        counter = [0]
        lock = threading.Lock()

        def bump():
            with lock:
                counter[0] += 1

        for _ in range(2000):
            pool.submit(bump)
        pool.wait_idle(timeout=60)
        assert counter[0] == 2000


def test_default_thread_count_is_hardware_concurrency():
    import os

    with ThreadPool() as pool:
        assert pool.num_threads == (os.cpu_count() or 1)


def test_stats_and_close_idempotent():
    pool = ThreadPool(2)
    pool.run(lambda: None)
    s = pool.stats()
    assert s["executed"] >= 1
    pool.close()
    pool.close()  # idempotent


def test_stats_exact_after_quiesce():
    """Per-worker counters: the summed count is exact once idle."""
    with ThreadPool(4) as pool:
        for _ in range(500):
            pool.submit(lambda: None)
        pool.wait_idle(timeout=60)
        assert pool.stats()["executed"] == 500


def test_stats_expose_parked_and_wakeups():
    """DESIGN.md §9: park events and targeted wakeups are counted through
    the same per-worker-cell discipline as executed/steals."""
    pool = ThreadPool(2)
    try:
        s = pool.stats()
        assert set(s) >= {"executed", "steals", "parked", "wakeups"}
        counters = ("executed", "steals", "parked", "wakeups")
        assert all(isinstance(s[k], int) for k in counters)
        # §13: queue depth per priority band (idle pool -> all empty)
        assert all(n == 0 for n in s["band_depths"].values())
        # idle workers park (spin-then-park, no poll ticks)
        deadline = time.monotonic() + 5.0
        while pool.stats()["parked"] < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert pool.stats()["parked"] >= 2
        # an external submission issues a targeted wakeup to a sleeper.
        # `parked` is cumulative, so a single submission could race the
        # brief backstop re-park window — submit until a wakeup lands.
        executed = 0
        deadline = time.monotonic() + 5.0
        while pool.stats()["wakeups"] < 1 and time.monotonic() < deadline:
            pool.run(lambda: None)
            executed += 1
            time.sleep(0.01)
        assert pool.stats()["wakeups"] >= 1
        assert pool.stats()["executed"] == executed
    finally:
        pool.close()


def test_close_returns_promptly_from_parked_workers():
    """Satellite regression: close() wakes every parked worker through its
    event — shutdown must not wait out park-timeout ticks."""
    pool = ThreadPool(4)
    deadline = time.monotonic() + 5.0
    while pool.stats()["parked"] < 4 and time.monotonic() < deadline:
        time.sleep(0.01)  # let all workers reach the parked state
    t0 = time.monotonic()
    pool.close()
    elapsed = time.monotonic() - t0
    assert elapsed < 0.25, f"close() took {elapsed:.3f}s with parked workers"


def test_wait_idle_concurrent_waiters():
    """The event-based quiescence protocol wakes every registered waiter."""
    with ThreadPool(2) as pool:
        release = threading.Event()
        pool.submit(lambda: release.wait(10))
        results = []

        def waiter():
            pool.wait_idle(timeout=10)
            results.append("idle")

        threads = [threading.Thread(target=waiter) for _ in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.05)  # let the waiters block on a busy pool
        release.set()
        for t in threads:
            t.join(10)
        assert results == ["idle"] * 3


def test_wait_idle_immediate_when_already_quiet():
    """A waiter on an already-idle pool returns without parking."""
    with ThreadPool(2) as pool:
        pool.run(lambda: None)
        t0 = time.monotonic()
        pool.wait_idle(timeout=5)
        assert time.monotonic() - t0 < 0.1


# ---------------------------------------------------------------------------
# priorities (DESIGN.md §3: same ready-key as the schedule simulator)
# ---------------------------------------------------------------------------


def _gated_pool(n=1):
    """Pool whose single worker is parked on a gate, so submissions queue."""
    pool = ThreadPool(n)
    gate = threading.Event()
    pool.submit(lambda: gate.wait(10))
    time.sleep(0.05)  # let the worker claim the gate task
    return pool, gate


def test_priority_orders_inbox():
    """Higher-priority external submissions run first; FIFO within a band."""
    pool, gate = _gated_pool()
    order = []
    pool.submit(lambda: order.append("low-a"), priority=-1.0)
    pool.submit(lambda: order.append("mid"), priority=0.0)
    pool.submit(lambda: order.append("low-b"), priority=-1.0)
    pool.submit(lambda: order.append("high"), priority=5.0)
    gate.set()
    pool.wait_idle(10)
    pool.close()
    assert order == ["high", "mid", "low-a", "low-b"]


def test_priority_inline_continuation_prefers_high():
    """Among newly-ready successors, the highest-priority one continues on
    the finishing worker (the B-before-F rule)."""
    with ThreadPool(1) as pool:
        order = []
        g = TaskGraph()
        root = g.add(lambda: order.append("root"))
        g.add(lambda: order.append("lo"), priority=-1.0).succeed(root)
        g.add(lambda: order.append("hi"), priority=1.0).succeed(root)
        pool.run(g)
        assert order == ["root", "hi", "lo"]


def test_then_continuation_inherits_priority():
    """Satellite fix: then()-created continuations no longer silently fall
    back to band 0.0 — they inherit the parent's band unless overridden."""
    g = TaskGraph()
    a = g.add(lambda: 1, priority=3.0)
    b = a.then(lambda x: x + 1)
    c = b.then(lambda x: x + 1, priority=-1.0)
    d = g.then(c, lambda x: x)
    assert b.priority == 3.0
    assert c.priority == -1.0
    assert d.priority == -1.0  # TaskGraph.then inherits too
    assert g.gather([a, c]).priority == 3.0  # joins take the highest band


def test_submit_priority_propagates_to_continuations():
    """ThreadPool.submit(task, priority=) reaches then()-created successors
    that never chose an explicit band."""
    pool, gate = _gated_pool()
    order = []
    g = TaskGraph()
    root = g.add(lambda: order.append("chain-root"))
    root.then(lambda _x: order.append("chain-cont"))
    pool.submit(lambda: order.append("filler"), priority=1.0)
    pool.submit(root, priority=5.0)  # whole chain should outrank the filler
    gate.set()
    pool.wait_idle(10)
    pool.close()
    assert order == ["chain-root", "chain-cont", "filler"]


def test_priority_deque_unit():
    from repro.core import EMPTY, PriorityDeque

    class Item:
        def __init__(self, tag, priority):
            self.tag, self.priority = tag, priority

    dq = PriorityDeque()
    for tag, pr in [("a0", 0.0), ("b0", 0.0), ("hi", 2.0), ("lo", -2.0)]:
        dq.push(Item(tag, pr))
    assert len(dq) == 4
    assert dq.pop().tag == "hi"  # highest band first
    assert dq.pop().tag == "b0"  # LIFO within the band (owner side)
    assert dq.steal().tag == "a0"  # FIFO within the band (thief side)
    assert dq.steal().tag == "lo"
    assert dq.pop() is EMPTY and dq.steal() is EMPTY


# ---------------------------------------------------------------------------
# cooperative cancellation + graph futures
# ---------------------------------------------------------------------------


def test_future_cancel_before_start():
    pool, gate = _gated_pool()
    fut = pool.submit_future(lambda: 42)
    assert fut.cancel() is True
    assert fut.cancelled()
    gate.set()
    pool.wait_idle(10)
    with pytest.raises(CancelledError):
        fut.result(5)
    pool.close()


def test_future_cancel_is_idempotent_before_run():
    """Repeat cancels of a not-yet-run task keep reporting success — the
    canceller's verdict stays authoritative across calls."""
    pool, gate = _gated_pool()
    fut = pool.submit_future(lambda: 42)
    assert fut.cancel() is True
    assert fut.cancel() is True  # second call: same verdict, not False
    assert fut.cancelled()
    gate.set()
    pool.wait_idle(10)
    pool.close()


def test_future_cancel_after_completion_fails():
    with ThreadPool(2) as pool:
        fut = pool.submit_future(lambda: 7)
        assert fut.result(10) == 7
        assert fut.cancel() is False


def test_future_cancel_while_running_fails():
    with ThreadPool(2) as pool:
        started = threading.Event()
        release = threading.Event()

        def body():
            started.set()
            release.wait(10)
            return "done"

        fut = pool.submit_future(body)
        assert started.wait(10)
        assert fut.cancel() is False  # running bodies are never interrupted
        release.set()
        assert fut.result(10) == "done"


def test_cancelled_task_releases_successors():
    """A cancelled task completes (CancelledError) and its successors run."""
    pool, gate = _gated_pool()
    ran = []
    g = TaskGraph()
    a = g.add(lambda: ran.append("a"))
    b = g.add(lambda: ran.append("b")).succeed(a)
    pool.submit(g)
    assert a.cancel() is True
    gate.set()
    pool.wait_idle(10)
    pool.close()
    assert ran == ["b"]  # dependency drained despite the skipped body
    assert isinstance(a.exception, CancelledError)


def test_graph_as_future_result_and_resubmission():
    with ThreadPool(2) as pool:
        order = []
        g = TaskGraph("g")
        first = g.add(lambda: order.append("first"))
        g.add(lambda: order.append("second")).succeed(first)
        assert g.as_future(pool).result(10) is None
        assert g.as_future(pool).result(10) is None  # graph is reusable
        assert order == ["first", "second"] * 2


def test_graph_as_future_delivers_exception():
    with ThreadPool(2) as pool:
        g = TaskGraph()
        g.add(lambda: (_ for _ in ()).throw(ValueError("boom")))
        fut = g.as_future(pool)
        with pytest.raises(ValueError, match="boom"):
            fut.result(10)
        with pytest.raises(ValueError):
            pool.wait_idle(10)  # pool error state drains as before


def test_graph_as_future_cancel():
    pool, gate = _gated_pool()
    ran = []
    g = TaskGraph()
    g.add(lambda: ran.append(1))
    fut = g.as_future(pool)
    assert fut.cancel() is True
    gate.set()
    pool.wait_idle(10)
    pool.close()
    assert ran == []  # body never ran
    with pytest.raises(CancelledError):
        fut.result(5)
