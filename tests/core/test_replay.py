"""Graph capture & replay (DESIGN.md §12).

The replay contract under test:

* an unchanged graph re-run through the facade dispatches from its
  captured :class:`ReplayPlan` from the second pass on — same results,
  bit-identical dataflow values, same observer event stream;
* every divergence source (structural mutation, a condition branching
  off the recorded path is *allowed*, runtime-sized subflows resizing is
  *allowed*, cancellation, task failure) either replays correctly or
  falls back to live dispatch transparently — never a wrong answer;
* the serial backend never compiles a plan (there is nothing to save),
  the process backend replays with full §11 placement parity.
"""
import threading

import pytest

from repro.core import (
    CancelledError,
    Executor,
    Runtime,
    StatsObserver,
    TaskGraph,
)

BACKENDS = ("serial", "thread", "process")


@pytest.fixture(params=BACKENDS)
def ex(request):
    """One Executor per backend — replay must be invisible on all three."""
    n = 2 if request.param == "process" else 4
    with Executor(n, backend=request.param) as e:
        yield e


@pytest.fixture()
def tex():
    """Thread-backend executor for replay-internal assertions."""
    with Executor(4, backend="thread") as e:
        yield e


def _plan_expected(ex):
    return ex.backend in ("thread", "process")


# ---------------------------------------------------------------------------
# parity: unchanged graphs replay with identical results (all backends)
# ---------------------------------------------------------------------------


def test_replay_parity_across_backends(ex):
    """Three passes of a diamond-with-tails graph: pass 1 runs live and
    records, later passes replay (thread/process) or stay live (serial) —
    results identical either way."""
    g = TaskGraph("diamond")
    a = g.add(lambda: 2, name="a")
    b = g.then(a, lambda x: x + 1, name="b")
    c = g.then(a, lambda x: x * 10, name="c")
    d = g.add(lambda: "done", name="d")
    d.after(b, c)
    for i in range(3):
        assert ex.run(g).result(30) is None
        assert (b.result, c.result, d.result) == (3, 20, "done")
        has_plan = g.replay_plan is not None
        assert has_plan == (_plan_expected(ex) and i >= 1)


def test_replay_chain_dataflow_bit_identical(ex):
    """A pure dataflow chain produces the same value every pass — the
    fused segment forwards argument slots exactly like live fan-out."""
    g = TaskGraph("chain")
    t = g.add(lambda: 1.0, name="head")
    for i in range(12):
        t = g.then(t, lambda x, k=i: x * 3.0 + k, name=f"n{i}")
    results = []
    for _ in range(4):
        ex.run(g).result(30)
        results.append(t.result)
    assert all(r == results[0] for r in results[1:])


def test_replay_runtime_sized_subflow_changes_size(ex):
    """A spawner sized by runtime state replays through the same plan —
    subflows are spawned fresh each pass, never captured."""
    g = TaskGraph("sub")
    width = {"n": 2}
    acc = []

    def spawn(rt: Runtime):
        # affinity="local": side effects on ``acc`` must stay in-parent
        # so the assertion sees them on the process backend too
        for i in range(width["n"]):
            rt.sub.add(lambda i=i: acc.append(i), affinity="local")

    sp = g.add(spawn, takes_runtime=True, name="spawn")
    g.add(
        lambda _: acc.append(-1), name="tail", takes_inputs=True, affinity="local"
    ).succeed(sp)
    for n in (2, 5, 1, 4):
        width["n"] = n
        acc.clear()
        ex.run(g).result(30)
        assert sorted(acc) == [-1, *range(n)]


def test_replay_condition_loop_trip_count_varies(ex):
    """A condition loop whose trip count differs between passes keeps its
    plan: branch tables are part of the capture, outcomes are not."""
    g = TaskGraph("loop")
    state = {"i": 0, "limit": 3, "runs": 0}
    # loop state lives in the condition body (always runs in-parent), so
    # the counters are authoritative on every backend; entry pins local
    entry = g.add(lambda: state.update(i=0), name="entry", affinity="local")
    body = g.add(lambda: None, name="body")
    body.after(entry)

    def more():
        state["i"] += 1
        state["runs"] += 1
        return 0 if state["i"] < state["limit"] else 1

    cond = g.add(more, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)
    total = 0
    for limit in (3, 7, 1, 5):
        state["limit"] = limit
        ex.run(g).result(30)
        total += limit
        assert state["runs"] == total
        assert (g.replay_plan is not None) == (_plan_expected(ex) and total > 3)


# ---------------------------------------------------------------------------
# invalidation matrix (thread backend: asserts on the plan itself)
# ---------------------------------------------------------------------------


def test_mutation_via_add_drops_plan(tex):
    g = TaskGraph("mut-add")
    seen = []
    g.add(lambda: seen.append("a"), name="a")
    tex.run(g).result(10)
    tex.run(g).result(10)
    plan = g.replay_plan
    assert plan is not None
    g.add(lambda: seen.append("b"), name="b")
    tex.run(g).result(10)  # structural epoch moved: falls back live
    assert seen.count("a") == 3 and seen.count("b") == 1  # a, b run in parallel
    assert g.replay_plan is not plan  # old plan dropped (recompile or None)
    tex.run(g).result(10)  # settled again: recompiles
    assert g.replay_plan is not None and g.replay_plan is not plan


def test_mutation_via_then_drops_plan(tex):
    g = TaskGraph("mut-then")
    a = g.add(lambda: 5, name="a")
    tex.run(g).result(10)
    tex.run(g).result(10)
    assert g.replay_plan is not None
    b = g.then(a, lambda x: x * x, name="b")
    tex.run(g).result(10)
    assert b.result == 25
    tex.run(g).result(10)
    assert b.result == 25 and g.replay_plan is not None


def test_cancellation_mid_replay_falls_back_live(tex):
    """Cancelling a replayed run marks the plan diverged; the next pass
    runs live (full reset) and produces the correct result."""
    g = TaskGraph("cancel")
    gate = threading.Event()
    release = threading.Event()
    hits = []

    def slow():
        gate.set()
        release.wait(10)
        hits.append(1)

    head = g.add(slow, name="head")
    g.then(head, lambda _: hits.append(2), name="tail")
    tex.run(g).result(10)
    release.set()  # pass 1 may still be parked on the gate
    gate.clear()
    release.clear()
    fut = tex.run(g)  # replayed pass
    plan = g.replay_plan
    assert plan is not None
    assert gate.wait(10)  # head is running inside the replay
    fut.cancel()
    release.set()
    with pytest.raises(CancelledError):
        fut.result(10)
    assert plan.diverged
    tex.wait_idle(10)
    hits.clear()
    tex.run(g).result(10)  # live fallback
    assert hits == [1, 2]


def test_failure_mid_replay_then_live_clears_stale_exceptions(tex):
    """Regression (§12 satellite): after a replayed pass fails, the live
    fallback pass must clear every stale member exception — success must
    not be poisoned by the previous pass's corpse."""
    g = TaskGraph("fail")
    mode = {"boom": False}

    def maybe():
        if mode["boom"]:
            raise ValueError("boom")
        return 7

    x = g.add(maybe, name="x")
    y = g.then(x, lambda v: v + 1, name="y")
    tex.run(g).result(10)
    tex.run(g).result(10)
    assert g.replay_plan is not None
    mode["boom"] = True
    with pytest.raises(ValueError, match="boom"):
        tex.run(g).result(10)
    assert g.replay_plan is None or g.replay_plan.diverged
    with pytest.raises(ValueError, match="boom"):
        tex.wait_idle(10)  # drains + clears the pool poison (§10 contract)
    mode["boom"] = False
    tex.run(g).result(10)  # live fallback: stale x/y exceptions must clear
    assert x.exception is None and y.exception is None and y.result == 8


def test_invalidate_plan_escape_hatch(tex):
    g = TaskGraph("hatch")
    a = g.add(lambda: 1, name="a")
    tex.run(g).result(10)
    tex.run(g).result(10)
    assert g.replay_plan is not None
    g.invalidate_plan()
    assert g.replay_plan is None
    tex.run(g).result(10)  # live again, then recompiles
    tex.run(g).result(10)
    assert g.replay_plan is not None and a.result == 1


def test_replay_false_forces_live(tex):
    g = TaskGraph("optout")
    g.add(lambda: 1, name="a")
    for _ in range(3):
        tex.run(g, replay=False).result(10)
    assert g.replay_plan is None


# ---------------------------------------------------------------------------
# submit-path replay + observer parity (thread backend)
# ---------------------------------------------------------------------------


def test_pool_submit_reuses_plan(tex):
    """ThreadPool.submit/run of a graph whose plan was captured by the
    facade dispatches from the plan too (the §12 submit fast path)."""
    g = TaskGraph("submit")
    a = g.add(lambda: 3, name="a")
    b = g.then(a, lambda x: x + 4, name="b")
    tex.run(g).result(10)
    tex.run(g).result(10)
    plan = g.replay_plan
    assert plan is not None
    before = plan.replays
    tex.pool.run(g)  # plain pool path, no future
    assert b.result == 7
    assert g.replay_plan is plan and plan.replays == before + 1


def test_observer_counts_identical_live_vs_replayed(tex):
    """StatsObserver must not be able to tell a replayed pass from a live
    one: per-pass submitted/started/finished deltas are identical, and
    started/finished cover every member of every fused segment."""
    obs = StatsObserver()
    tex.add_observer(obs)
    try:
        g = TaskGraph("obs")
        a = g.add(lambda: 1, name="a")
        b = g.then(a, lambda x: x + 1, name="b")
        c = g.then(b, lambda x: x + 1, name="c")
        d = g.add(lambda: 0, name="d")
        d.after(a)
        def counts():
            return {
                "submitted": obs.submitted,
                "started": obs.started,
                "finished": obs.finished,
            }

        deltas = []
        prev = counts()
        for i in range(3):
            tex.run(g).result(10)
            tex.wait_idle(10)
            cur = counts()
            deltas.append({k: cur[k] - prev[k] for k in prev})
            prev = cur
        assert deltas[1] == deltas[0] == deltas[2]
        # every member ran visibly each pass: a, b, c, d + the hidden fin
        assert deltas[1]["started"] == deltas[1]["finished"] == 5
        assert c.result == 3
    finally:
        tex.remove_observer(obs)


def test_replay_plan_introspection(tex):
    """The plan reports its shape: a pure chain contracts to one segment."""
    g = TaskGraph("intro")
    t = g.add(lambda: 0, name="n0")
    for i in range(1, 6):
        t = g.then(t, lambda x: x + 1, name=f"n{i}")
    tex.run(g).result(10)
    tex.run(g).result(10)
    plan = g.replay_plan
    assert plan is not None
    # the 6 user tasks contract to one segment; the hidden fin keeps its
    # own (its propagate_errors differs — it must run even on failure)
    assert plan.segments == 2 and plan.fused == 5
    assert plan.replays == 1 and not plan.diverged
    assert t.result == 5
