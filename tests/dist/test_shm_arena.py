"""Shared-memory arena: pooled recycling, ephemeral receipt, lifetimes."""
import numpy as np

from repro.dist.shm_arena import ArrayRef, ShmArena, _bucket


def test_round_trip_pooled():
    arena = ShmArena(threshold=0)
    try:
        a = np.arange(24, dtype=np.int64).reshape(4, 6)
        ref = arena.put(a)
        assert not ref.ephemeral
        out = arena.get(ref)
        np.testing.assert_array_equal(out, a)
        arena.recycle(ref)
    finally:
        arena.close()


def test_pooled_segments_are_recycled():
    arena = ShmArena(threshold=0)
    try:
        a = np.zeros(1000, dtype=np.float64)
        r1 = arena.put(a)
        arena.recycle(r1)
        r2 = arena.put(a + 1)  # same bucket: must reuse the freed segment
        assert r2.name == r1.name
        assert arena.get(r2)[0] == 1.0
        arena.recycle(r2)
        assert len(arena._owned) == 1  # one segment served both jobs
    finally:
        arena.close()


def test_distinct_buckets_get_distinct_segments():
    arena = ShmArena(threshold=0)
    try:
        r_small = arena.put(np.zeros(10, dtype=np.int8))
        r_big = arena.put(np.zeros(1 << 20, dtype=np.int8))
        assert r_small.name != r_big.name
        arena.recycle(r_small)
        arena.recycle(r_big)
    finally:
        arena.close()


def test_ephemeral_result_copied_and_unlinked():
    producer = ShmArena(threshold=0, attach_only=True)
    consumer = ShmArena(threshold=0)
    try:
        a = np.arange(128, dtype=np.float32)
        ref = producer.put(a)
        assert ref.ephemeral
        out = consumer.get(ref)
        np.testing.assert_array_equal(out, a)
        out[0] = 99.0  # the copy is owned: segment already gone
        # re-attach must fail: receipt unlinked the segment
        import pytest
        from multiprocessing import shared_memory

        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=ref.name)
    finally:
        producer.close()
        consumer.close()


def test_noncontiguous_and_zero_dim_arrays():
    arena = ShmArena(threshold=0)
    try:
        a = np.arange(64).reshape(8, 8)[:, ::2]  # non-contiguous view
        ref = arena.put(a)
        np.testing.assert_array_equal(arena.get(ref), a)
        arena.recycle(ref)
        scalar = np.float64(3.5).reshape(())
        ref2 = arena.put(scalar)
        assert arena.get(ref2).item() == 3.5
        arena.recycle(ref2)
    finally:
        arena.close()


def test_bucket_rounding():
    assert _bucket(1) == 4096
    assert _bucket(4096) == 4096
    assert _bucket(4097) == 8192
    assert _bucket(3 << 20) == 4 << 20


def test_array_ref_pickles():
    import pickle

    ref = ArrayRef("seg", (2, 3), "float32", 24, True)
    out = pickle.loads(pickle.dumps(ref))
    assert (out.name, out.shape, out.dtype, out.nbytes, out.ephemeral) == (
        "seg",
        (2, 3),
        "float32",
        24,
        True,
    )


def test_close_unlinks_owned_segments():
    arena = ShmArena(threshold=0)
    ref = arena.put(np.zeros(16))
    name = ref.name
    arena.close()
    import pytest
    from multiprocessing import shared_memory

    with pytest.raises(FileNotFoundError):
        shared_memory.SharedMemory(name=name)


def test_max_pooled_cap_falls_back_to_ephemeral_without_blocking():
    """§11 satellite: at the ``max_pooled`` cap, ``put`` degrades to an
    ephemeral segment instead of blocking or growing — values still
    deliver intact, and the overflow is visible in stats()."""
    arena = ShmArena(threshold=0, max_pooled=2)
    try:
        arrays = [np.full(1000, float(i)) for i in range(5)]
        refs = [arena.put(a) for a in arrays]
        assert sum(not r.ephemeral for r in refs) == 2  # the cap
        assert sum(r.ephemeral for r in refs) == 3  # the overflow
        for ref, a in zip(refs, arrays):
            np.testing.assert_array_equal(arena.get(ref), a)
        s = arena.stats()
        assert s["pooled_segments"] == 2  # never grew past the cap
        assert s["ephemeral_created"] == 3
        assert s["ephemeral_unlinked"] == 3  # get() released each one
        for ref in refs:
            arena.recycle(ref)
        # recycled pooled segments serve the next round (no new creation)
        r = arena.put(np.ones(1000))
        assert not r.ephemeral
        assert arena.stats()["pooled_created"] == 2
        arena.recycle(r)
    finally:
        arena.close()


def test_exhaustion_under_concurrent_jobs_stays_deadlock_free():
    """A capped arena under a real ProcessPool: more concurrent large-array
    jobs than pooled segments. Overflow rides ephemeral segments, every
    job completes (no checkout ever blocks), and the recycle counters
    surface through ``pool.stats()['arena']``."""
    from repro.core import Executor, TaskGraph
    from repro.dist import ProcessPool

    with ProcessPool(2, arena_threshold=1024, arena_max_pooled=1,
                     name="capped-arena") as pool:
        g = TaskGraph()
        heads = [
            g.add(lambda i=i: np.full(2000, float(i)), name=f"mk{i}",
                  affinity="local")
            for i in range(6)
        ]
        sums = [g.then(h, lambda a: float(a.sum())) for h in heads]
        Executor(pool=pool).run(g).result(60)
        assert [t.result for t in sums] == [2000.0 * i for i in range(6)]
        arena = pool.stats()["arena"]
        assert arena["pooled_segments"] <= 1  # the cap held
        assert arena["ephemeral_created"] >= 1  # overflow took the fallback
        assert arena["pooled_recycled"] >= 1  # and pooled traffic recycled


def test_stats_counters_round_trip():
    arena = ShmArena(threshold=0)
    try:
        ref = arena.put(np.zeros(100))
        arena.recycle(ref)
        ref2 = arena.put(np.zeros(100))
        arena.recycle(ref2)
        s = arena.stats()
        assert s["pooled_created"] == 1
        assert s["pooled_reused"] == 1
        assert s["pooled_recycled"] == 2
        assert s["free_segments"] == 1
    finally:
        arena.close()


def test_freelist_keyed_by_requested_bucket_not_os_size():
    """recycle must file segments under the checkout bucket: the OS may
    page-round seg.size (macOS: 16 KiB), which would make every lookup
    miss and grow the pool unboundedly (review fix)."""
    arena = ShmArena(threshold=0)
    try:
        ref = arena.put(np.zeros(100, dtype=np.int8))  # bucket 4096
        arena.recycle(ref)
        assert list(arena._free) == [4096]  # keyed by bucket, whatever fstat says
        ref2 = arena.put(np.zeros(200, dtype=np.int8))
        assert ref2.name == ref.name  # reused even if seg.size were rounded
    finally:
        arena.close()
