"""Socket chaos battery (DESIGN.md §16 acceptance): real connection
kills, half-open sockets, heartbeat lapses and hard-timeout worker kills
against a live :class:`SocketPool` — plus the seeded-determinism gate: the
same chaos seed yields a **byte-identical** injected schedule across two
consecutive runs on fresh pools.

Determinism here is load-bearing, not cosmetic. The injector's decisions
are keyed hashes of ``(seed, task, occurrence)``, so the schedule can only
diverge if the *pool* makes occurrence counts interleaving-dependent —
e.g. a kill silently swallowed because the idle monitor respawned the
worker before the dispatcher noticed (exactly the race the
``_transport_fault`` handoff closes). These tests are the canary for that
class of bug.

Process-safe idioms as everywhere: module-level bodies for anything that
must ship by pickle reference, ``idempotent=True`` on bodies a chaos kill
may interrupt mid-flight (§14 at-most-once), assertions on parent-side
task state.
"""
import hashlib
import json
import os
import signal
import time

import pytest

from repro.core import (
    ChaosError,
    Executor,
    FaultInjector,
    RetryPolicy,
    Task,
    TaskGraph,
    TaskTimeoutError,
)
from repro.dist import SocketPool, WorkerDiedError

_POLICY = RetryPolicy(
    max_attempts=10, backoff=0.0, retry_on=(ChaosError, WorkerDiedError)
)
_CHAOS = dict(fail_rate=0.2, delay_rate=0.08, kill_rate=0.1, delay_s=0.001)


def _battery_graph(n=24):
    g = TaskGraph("sock-chaos")
    tasks = [
        g.add(lambda i=i: i * i, name=f"k:{i}", retry=_POLICY, idempotent=True)
        for i in range(n)
    ]
    sink = g.gather(tasks, name="collect")
    return g, sink


def _run_battery(seed):
    """One full battery run on a fresh pool; returns (schedule, values,
    stats) — everything the determinism gate compares or bounds."""
    with SocketPool(2, name="chaos-sock") as pool:
        inj = FaultInjector(
            seed=seed, match=lambda t: (t.name or "").startswith("k:"), **_CHAOS
        )
        g, sink = _battery_graph()
        with inj.on(pool):
            Executor(pool=pool).run(g).result(120)
        return inj.schedule(), list(sink.result), pool.stats()


def fingerprint(schedule):
    """Canonical digest of an injected-fault schedule (the CI artifact)."""
    blob = json.dumps(schedule, separators=(",", ":")).encode()
    return hashlib.blake2b(blob, digest_size=16).hexdigest()


def _sleepy(i):
    time.sleep(0.02)
    return i * 3


def _wedge():
    time.sleep(30.0)


def _exit_now():
    os._exit(1)


# ---------------------------------------------------------------------------
# the seeded battery: byte-identical schedules across consecutive runs
# ---------------------------------------------------------------------------


def test_seeded_battery_byte_identical_across_two_runs():
    runs = [_run_battery(seed=2026) for _ in range(2)]
    (sched_a, vals_a, stats_a), (sched_b, vals_b, _) = runs
    # byte-identical: compare the serialized schedules, not just equality
    blob_a = json.dumps(sched_a, separators=(",", ":")).encode()
    blob_b = json.dumps(sched_b, separators=(",", ":")).encode()
    assert blob_a == blob_b
    assert fingerprint(sched_a) == fingerprint(sched_b)
    # the battery actually exercised every fault kind, incl. real kills
    counts = {"fail": 0, "delay": 0, "kill": 0}
    for _name, _occ, kind in sched_a:
        counts[kind] += 1
    assert counts["fail"] >= 2 and counts["kill"] >= 1
    # chaos changes the schedule, never the answer
    assert vals_a == vals_b == [i * i for i in range(24)]
    assert stats_a["worker_restarts"] >= 1  # the kills were real


def test_different_seeds_differ():
    """Sanity for the gate above: the fingerprint is sensitive — two seeds
    with these rates virtually never produce the same schedule."""
    sched_a, _va, _sa = _run_battery(seed=11)
    sched_b, _vb, _sb = _run_battery(seed=12)
    assert fingerprint(sched_a) != fingerprint(sched_b)


# ---------------------------------------------------------------------------
# real transport faults, one at a time
# ---------------------------------------------------------------------------


def test_half_open_connection_recovers():
    """Shutting down a live connection under traffic (the half-open case:
    the parent's endpoint dies, the worker process is still running) fails
    in-flight bodies with ``WorkerDiedError``; retries land on replacement
    capacity and the graph completes intact."""
    with SocketPool(2, name="halfopen-sock") as pool:
        g = TaskGraph()
        tasks = [
            g.add(lambda i=i: _sleepy(i), name=f"s:{i}", retry=_POLICY,
                  idempotent=True)
            for i in range(16)
        ]
        sink = g.gather(tasks, name="collect")
        fut = Executor(pool=pool).run(g)
        time.sleep(0.05)  # let jobs reach the wire
        conn = pool._conns[0]
        if hasattr(conn, "kill"):
            conn.kill()  # RDWR shutdown: both directions die mid-stream
        assert fut.result(60) is None
        assert list(sink.result) == [i * 3 for i in range(16)]
        assert pool.stats()["worker_restarts"] >= 1


def test_heartbeat_lapse_detected_and_recovered():
    """A SIGSTOPped worker stops pulsing; the liveness window declares it
    dead (heartbeat_lapses counter), the slot respawns, and idempotent
    bodies retry to completion."""
    with SocketPool(2, heartbeat_s=0.05, liveness_s=0.4,
                    name="lapse-sock") as pool:
        g = TaskGraph()
        tasks = [
            g.add(lambda i=i: _sleepy(i), name=f"h:{i}", retry=_POLICY,
                  idempotent=True)
            for i in range(8)
        ]
        sink = g.gather(tasks, name="collect")
        fut = Executor(pool=pool).run(g)
        time.sleep(0.06)  # a body is in flight on some worker
        victim = next(p for p in pool._procs if p is not None)
        os.kill(victim.pid, signal.SIGSTOP)  # silent, not dead: no EOF
        assert fut.result(60) is None
        assert list(sink.result) == [i * 3 for i in range(8)]
        s = pool.stats()
        assert s["heartbeat_lapses"] >= 1
        assert s["worker_restarts"] >= 1


def test_hard_timeout_kills_remote_worker_and_restores_capacity():
    with SocketPool(2, name="watchdog-sock") as pool:
        t = Task(_wedge, name="wedged", affinity="remote", timeout=0.2)
        t.propagate_errors = False
        with pytest.raises(TaskTimeoutError, match="wedged"):
            Executor(pool=pool).run(t).result(30)
        s = pool.stats()
        assert s["worker_kills"] >= 1 and s["timeouts"] >= 1
        # the replacement worker serves the next job
        assert pool.submit_future(lambda: "alive").result(20) == "alive"


def test_started_loss_is_at_most_once_without_idempotent():
    """A body that genuinely dies mid-execution (os._exit) surfaces as
    ``WorkerDiedError(started=True)`` and is NOT retried without
    ``idempotent=True`` — even under a matching policy (§14)."""
    with SocketPool(2, name="amo-sock") as pool:
        t = Task(
            _exit_now, name="amo", affinity="remote",
            retry=RetryPolicy(max_attempts=5, backoff=0.0,
                              retry_on=WorkerDiedError),
        )
        t.propagate_errors = False
        with pytest.raises(WorkerDiedError) as ei:
            Executor(pool=pool).run(t).result(30)
        assert ei.value.started is True
        assert pool.stats()["worker_restarts"] >= 1
        assert pool.wait_idle(20) is True  # not poisoned
