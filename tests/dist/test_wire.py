"""Wire-format unit tests: function shipping (pickle + code-object
fallback), value/argument packs, exception transport."""
import functools
import threading

import numpy as np
import pytest

from repro.dist.shm_arena import ShmArena
from repro.dist.wire import (
    UnpicklableTaskError,
    dumps_args,
    dumps_exception,
    dumps_fn,
    dumps_value,
    loads_args,
    loads_exception,
    loads_fn,
    loads_value,
    shm_refs,
)


def module_level(x):
    return x * 3


MODULE_CONST = 17


def test_plain_function_round_trips_by_reference():
    fn = loads_fn(dumps_fn(module_level))
    assert fn(4) == 12


def test_lambda_round_trips_via_code_wire():
    fn = loads_fn(dumps_fn(lambda x: x + 1))
    assert fn(41) == 42


def test_closure_cells_are_captured_by_value():
    def make(k):
        return lambda x: x * k

    fn = loads_fn(dumps_fn(make(5)))
    assert fn(6) == 30


def test_defaults_and_nested_lambdas_ship():
    base = 100

    def make():
        inner = lambda v: v + base  # noqa: E731 - nested closure on purpose
        return lambda x, off=7: inner(x) + off

    fn = loads_fn(dumps_fn(make()))
    assert fn(1) == 108


def test_referenced_globals_ship_by_value():
    # the loaded function must see the submission-time global, not rely on
    # the destination module state (fork-time snapshots go stale)
    fn = loads_fn(dumps_fn(lambda: MODULE_CONST * 2))
    g = fn.__globals__
    assert g["MODULE_CONST"] == 17
    assert fn() == 34


def test_partial_round_trips():
    fn = loads_fn(dumps_fn(functools.partial(module_level, 9)))
    assert fn() == 27


def test_recursive_lambda_global_does_not_recurse_forever():
    # fact references itself through its module globals; the dump guard
    # must break the cycle instead of recursing to a stack overflow
    import sys

    mod = sys.modules[__name__]
    mod.fact = eval("lambda n: 1 if n <= 1 else n * fact(n - 1)", mod.__dict__)
    try:
        wire = dumps_fn(mod.fact)
        assert wire is not None
    finally:
        del mod.fact


def test_closure_over_module_ships_by_name():
    def make():
        import numpy as np_local

        return lambda: np_local.arange(3).sum()

    fn = loads_fn(dumps_fn(make()))
    assert fn() == 3


def test_unpicklable_closure_raises_clear_error():
    lock = threading.Lock()
    with pytest.raises(UnpicklableTaskError, match="does not pickle"):
        dumps_fn(lambda: lock.acquire())


def test_bound_method_of_stateful_object_raises():
    class Holder:
        def __init__(self):
            self.lock = threading.Lock()

        def body(self):
            return 1

    with pytest.raises(UnpicklableTaskError, match="not a plain function"):
        dumps_fn(Holder().body)


def test_value_pack_small_arrays_pickle_large_use_arena():
    arena = ShmArena(threshold=1024)
    try:
        small = np.arange(4)
        large = np.arange(1024, dtype=np.float64)  # 8 KiB >= threshold
        pack = dumps_args((small, large, "tag"), arena)
        refs = shm_refs(pack)
        assert len(refs) == 1 and refs[0].nbytes == large.nbytes
        s, l, t = loads_args(pack, arena)
        np.testing.assert_array_equal(s, small)
        np.testing.assert_array_equal(l, large)
        assert t == "tag"
        for ref in refs:
            arena.recycle(ref)
    finally:
        arena.close()


def test_callable_value_falls_back_to_fn_wire():
    k = 5
    wire = dumps_value(lambda v: v * k)
    assert loads_value(wire)(3) == 15


def test_exception_transport_preserves_type():
    exc = loads_exception(dumps_exception(ValueError("worker boom")))
    assert isinstance(exc, ValueError) and "worker boom" in str(exc)


def test_unpicklable_exception_degrades_to_runtime_error():
    class Weird(Exception):
        def __init__(self):
            super().__init__("weird")
            self.lock = threading.Lock()

    exc = loads_exception(dumps_exception(Weird()))
    assert isinstance(exc, RuntimeError) and "weird" in str(exc)


def test_failed_pack_recycles_partial_arena_blocks():
    """A pack that fails mid-serialization must return already-allocated
    pooled segments to the freelist (review fix: no leak-until-close)."""
    arena = ShmArena(threshold=1024)
    try:
        big = np.zeros(4096, dtype=np.float64)
        with pytest.raises(Exception):
            dumps_args((big, threading.Lock()), arena)
        # the segment created for `big` is back in the freelist
        assert sum(len(v) for v in arena._free.values()) == len(arena._owned) == 1
        with pytest.raises(Exception):
            dumps_args(({"a": big, "bad": threading.Lock()},), arena)
        assert sum(len(v) for v in arena._free.values()) == len(arena._owned) == 1
    finally:
        arena.close()


def test_failed_result_pack_unlinks_ephemeral_segments():
    """Worker-side cleanup contract: a result pack that fails mid-
    serialization unlinks the ephemeral segments already created (review
    fix: recycle handles ephemeral refs — nothing persists in /dev/shm)."""
    from multiprocessing import shared_memory

    arena = ShmArena(threshold=1024, attach_only=True)
    try:
        big = np.zeros(4096, dtype=np.float64)
        ref = arena.put(big)  # simulate the first element of a failing pack
        arena.recycle(ref)
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=ref.name)
        with pytest.raises(Exception):
            dumps_value({"a": big, "bad": threading.Lock()}, arena)
    finally:
        arena.close()


def test_main_module_functions_ship_by_value():
    """A __main__-level function must ride the code wire: its pickle
    reference dangles in any worker forked before the definition ran
    (review-drive fix — the adopted-pool Prefetcher scenario)."""
    k = []  # ensure no accidental closure

    def f(x):
        return x * 4

    f.__module__ = "__main__"  # simulate a script-level def
    wire = dumps_fn(f)
    assert wire[0] != 0  # not a bare pickle reference
    assert loads_fn(wire)(5) == 20 and not k


def test_recursive_inner_function_fails_fast_with_clear_error():
    """A self-referential closure cannot ship by value: the wire reports
    it immediately (no RecursionError stack burn) with an actionable
    message (review fix)."""

    def make():
        def fact(n):
            return 1 if n <= 1 else n * fact(n - 1)

        return fact

    with pytest.raises(UnpicklableTaskError, match="self-referential"):
        dumps_fn(make())


def test_cyclic_container_edge_value_falls_back_to_pickle():
    """A small self-referential container ships via pickle (which handles
    cycles) instead of recursing in the arena scan (review fix)."""
    arena = ShmArena(threshold=1024)
    try:
        cyc = []
        cyc.append(cyc)
        out = loads_value(dumps_value(cyc, arena), arena)
        assert out[0] is out
    finally:
        arena.close()
