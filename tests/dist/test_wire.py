"""Wire-format unit tests: function shipping (pickle + code-object
fallback), value/argument packs, exception transport."""
import functools
import threading

import numpy as np
import pytest

from repro.dist.shm_arena import ShmArena
from repro.dist.wire import (
    UnpicklableTaskError,
    dumps_args,
    dumps_exception,
    dumps_fn,
    dumps_value,
    loads_args,
    loads_exception,
    loads_fn,
    loads_value,
    shm_refs,
)


def module_level(x):
    return x * 3


MODULE_CONST = 17


def test_plain_function_round_trips_by_reference():
    fn = loads_fn(dumps_fn(module_level))
    assert fn(4) == 12


def test_lambda_round_trips_via_code_wire():
    fn = loads_fn(dumps_fn(lambda x: x + 1))
    assert fn(41) == 42


def test_closure_cells_are_captured_by_value():
    def make(k):
        return lambda x: x * k

    fn = loads_fn(dumps_fn(make(5)))
    assert fn(6) == 30


def test_defaults_and_nested_lambdas_ship():
    base = 100

    def make():
        inner = lambda v: v + base  # noqa: E731 - nested closure on purpose
        return lambda x, off=7: inner(x) + off

    fn = loads_fn(dumps_fn(make()))
    assert fn(1) == 108


def test_referenced_globals_ship_by_value():
    # the loaded function must see the submission-time global, not rely on
    # the destination module state (fork-time snapshots go stale)
    fn = loads_fn(dumps_fn(lambda: MODULE_CONST * 2))
    g = fn.__globals__
    assert g["MODULE_CONST"] == 17
    assert fn() == 34


def test_partial_round_trips():
    fn = loads_fn(dumps_fn(functools.partial(module_level, 9)))
    assert fn() == 27


def test_recursive_lambda_global_does_not_recurse_forever():
    # fact references itself through its module globals; the dump guard
    # must break the cycle instead of recursing to a stack overflow
    import sys

    mod = sys.modules[__name__]
    mod.fact = eval("lambda n: 1 if n <= 1 else n * fact(n - 1)", mod.__dict__)
    try:
        wire = dumps_fn(mod.fact)
        assert wire is not None
    finally:
        del mod.fact


def test_closure_over_module_ships_by_name():
    def make():
        import numpy as np_local

        return lambda: np_local.arange(3).sum()

    fn = loads_fn(dumps_fn(make()))
    assert fn() == 3


def test_unpicklable_closure_raises_clear_error():
    lock = threading.Lock()
    with pytest.raises(UnpicklableTaskError, match="does not pickle"):
        dumps_fn(lambda: lock.acquire())


def test_bound_method_of_stateful_object_raises():
    class Holder:
        def __init__(self):
            self.lock = threading.Lock()

        def body(self):
            return 1

    with pytest.raises(UnpicklableTaskError, match="not a plain function"):
        dumps_fn(Holder().body)


def test_value_pack_small_arrays_pickle_large_use_arena():
    arena = ShmArena(threshold=1024)
    try:
        small = np.arange(4)
        large = np.arange(1024, dtype=np.float64)  # 8 KiB >= threshold
        pack = dumps_args((small, large, "tag"), arena)
        refs = shm_refs(pack)
        assert len(refs) == 1 and refs[0].nbytes == large.nbytes
        s, l, t = loads_args(pack, arena)
        np.testing.assert_array_equal(s, small)
        np.testing.assert_array_equal(l, large)
        assert t == "tag"
        for ref in refs:
            arena.recycle(ref)
    finally:
        arena.close()


def test_callable_value_falls_back_to_fn_wire():
    k = 5
    wire = dumps_value(lambda v: v * k)
    assert loads_value(wire)(3) == 15


def test_exception_transport_preserves_type():
    exc = loads_exception(dumps_exception(ValueError("worker boom")))
    assert isinstance(exc, ValueError) and "worker boom" in str(exc)


def test_unpicklable_exception_degrades_to_runtime_error():
    class Weird(Exception):
        def __init__(self):
            super().__init__("weird")
            self.lock = threading.Lock()

    exc = loads_exception(dumps_exception(Weird()))
    assert isinstance(exc, RuntimeError) and "weird" in str(exc)


def test_failed_pack_recycles_partial_arena_blocks():
    """A pack that fails mid-serialization must return already-allocated
    pooled segments to the freelist (review fix: no leak-until-close)."""
    arena = ShmArena(threshold=1024)
    try:
        big = np.zeros(4096, dtype=np.float64)
        with pytest.raises(Exception):
            dumps_args((big, threading.Lock()), arena)
        # the segment created for `big` is back in the freelist
        assert sum(len(v) for v in arena._free.values()) == len(arena._owned) == 1
        with pytest.raises(Exception):
            dumps_args(({"a": big, "bad": threading.Lock()},), arena)
        assert sum(len(v) for v in arena._free.values()) == len(arena._owned) == 1
    finally:
        arena.close()


def test_failed_result_pack_unlinks_ephemeral_segments():
    """Worker-side cleanup contract: a result pack that fails mid-
    serialization unlinks the ephemeral segments already created (review
    fix: recycle handles ephemeral refs — nothing persists in /dev/shm)."""
    from multiprocessing import shared_memory

    arena = ShmArena(threshold=1024, attach_only=True)
    try:
        big = np.zeros(4096, dtype=np.float64)
        ref = arena.put(big)  # simulate the first element of a failing pack
        arena.recycle(ref)
        with pytest.raises(FileNotFoundError):
            shared_memory.SharedMemory(name=ref.name)
        with pytest.raises(Exception):
            dumps_value({"a": big, "bad": threading.Lock()}, arena)
    finally:
        arena.close()


def test_main_module_functions_ship_by_value():
    """A __main__-level function must ride the code wire: its pickle
    reference dangles in any worker forked before the definition ran
    (review-drive fix — the adopted-pool Prefetcher scenario)."""
    k = []  # ensure no accidental closure

    def f(x):
        return x * 4

    f.__module__ = "__main__"  # simulate a script-level def
    wire = dumps_fn(f)
    assert wire[0] != 0  # not a bare pickle reference
    assert loads_fn(wire)(5) == 20 and not k


def test_recursive_inner_function_fails_fast_with_clear_error():
    """A self-referential closure cannot ship by value: the wire reports
    it immediately (no RecursionError stack burn) with an actionable
    message (review fix)."""

    def make():
        def fact(n):
            return 1 if n <= 1 else n * fact(n - 1)

        return fact

    with pytest.raises(UnpicklableTaskError, match="self-referential"):
        dumps_fn(make())


def test_cyclic_container_edge_value_falls_back_to_pickle():
    """A small self-referential container ships via pickle (which handles
    cycles) instead of recursing in the arena scan (review fix)."""
    arena = ShmArena(threshold=1024)
    try:
        cyc = []
        cyc.append(cyc)
        out = loads_value(dumps_value(cyc, arena), arena)
        assert out[0] is out
    finally:
        arena.close()


# ---------------------------------------------------------------------------
# §16 satellite: the edge cases the socket transport leans on
# ---------------------------------------------------------------------------


def test_mutually_recursive_closures_fail_fast_with_clear_error():
    """Two inner functions referencing each other form a closure cycle the
    code wire cannot ship; the dump guard reports it immediately (no
    RecursionError stack burn) with the same actionable message as the
    direct self-reference case."""

    def make():
        def even(n):
            return True if n == 0 else odd(n - 1)

        def odd(n):
            return False if n == 0 else even(n - 1)

        return even

    with pytest.raises(UnpicklableTaskError, match="self-referential"):
        dumps_fn(make())


def test_lambda_capturing_module_object_in_cell_ships_by_name():
    """A module object held in a closure *cell* (not just referenced as a
    global) rides the wire by import name and rebinds on the far side."""
    import numpy as np_mod

    hold = np_mod  # closure cell holds the module object itself

    def make():
        return lambda: hold.arange(5).sum()

    fn = loads_fn(dumps_fn(make()))
    assert fn() == 10


class _Plain:
    """Module-level on purpose: instances pickle by class reference."""

    def __init__(self, k):
        self.k = k

    def mul(self, x):
        return self.k * x


def test_partial_over_bound_method_of_picklable_instance_round_trips():
    fn = loads_fn(dumps_fn(functools.partial(_Plain(3).mul, 7)))
    assert fn() == 21


def test_partial_over_bound_method_of_stateful_instance_raises():
    class Holder:
        def __init__(self):
            self.lock = threading.Lock()

        def body(self, x):  # pragma: no cover - never ships
            return x

    with pytest.raises(UnpicklableTaskError, match="not a plain function"):
        dumps_fn(functools.partial(Holder().body, 1))


# property test: the wire round-trips arbitrary nested arg packs — runs
# under real hypothesis when installed, the deterministic shim otherwise
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from repro.testing import given, settings, st


@st.composite
def _arg_packs(draw):
    scalars = st.sampled_from([0, -1, 3.5, "tag", None, True])
    small = st.lists(scalars, min_size=0, max_size=4)
    n = draw(st.integers(min_value=1, max_value=2048))
    dtype = draw(st.sampled_from(["float64", "int32"]))
    arr = np.arange(n, dtype=dtype)
    shape = draw(st.sampled_from(["flat", "tuple", "dict"]))
    if shape == "flat":
        return (arr, draw(small))
    if shape == "tuple":
        return ((draw(scalars), arr), [arr, draw(scalars)])
    return ({"a": arr, "b": draw(small)}, draw(scalars))


@settings(max_examples=25, deadline=None)
@given(pack=_arg_packs())
def test_args_round_trip_property(pack):
    """dumps_args/loads_args is lossless for nested scalars + arrays, both
    below and above the arena threshold (arrays >= 1 KiB cross the shm
    plane; equality must hold either way)."""
    arena = ShmArena(threshold=1024)
    try:
        out = loads_args(dumps_args(pack, arena), arena)
        _assert_tree_equal(out, pack)
        for ref in shm_refs(dumps_args(pack, arena)):
            arena.recycle(ref)
    finally:
        arena.close()


def _assert_tree_equal(a, b):
    assert type(a) is type(b), (type(a), type(b))
    if isinstance(a, np.ndarray):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(a, b)
    elif isinstance(a, dict):
        assert a.keys() == b.keys()
        for k in a:
            _assert_tree_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert len(a) == len(b)
        for x, y in zip(a, b):
            _assert_tree_equal(x, y)
    else:
        assert a == b
