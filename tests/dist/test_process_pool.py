"""ProcessPool behavior: placement, fault handling, arena edges, and the
backend edge cases the §11 satellite calls out — unpicklable bodies fail
at submit, worker death fails the task (and releases ``wait_idle``), and
the scheduler's §10 semantics survive the address-space boundary."""
import os
import threading

import numpy as np
import pytest

from repro.core import Executor, Task, TaskGraph
from repro.dist import ProcessPool, UnpicklableTaskError, WorkerDiedError


@pytest.fixture()
def pool():
    with ProcessPool(2, name="test-procpool") as p:
        yield p


def _locked_body():
    lock = threading.Lock()
    return lambda: lock.acquire()


# ---------------------------------------------------------------------------
# placement + wiring
# ---------------------------------------------------------------------------


def test_remote_execution_actually_happens(pool):
    """The body observes a different pid — proof it escaped the parent."""
    assert pool.submit_future(lambda: os.getpid()).result(10) != os.getpid()
    assert pool.stats()["remote_jobs"] >= 1


def test_affinity_local_pins_to_parent(pool):
    t = Task(lambda: os.getpid(), affinity="local")
    t.propagate_errors = False
    fut = Executor(pool=pool).run(t)
    assert fut.result(10) == os.getpid()


def test_condition_and_spawner_bodies_always_run_in_parent(pool):
    pids = {}
    g = TaskGraph()
    entry = g.add(lambda: None)
    cond = g.add(lambda: pids.setdefault("cond", os.getpid()) and 99, kind="condition")
    cond.after(entry)

    def spawn(rt):
        pids["spawn"] = os.getpid()
        return rt.add(lambda: os.getpid())

    sp = g.add(spawn, takes_runtime=True)
    sp.after(entry)
    worker_pid = g.then(sp, lambda p: p)
    Executor(pool=pool).run(g).result(10)
    assert pids["cond"] == os.getpid()  # control flow is scheduler-side
    assert pids["spawn"] == os.getpid()
    assert worker_pid.result != os.getpid()  # spawned body went remote


def test_unpicklable_body_raises_clear_error_at_submit(pool):
    t = Task(_locked_body(), name="locked", affinity="remote")
    with pytest.raises(UnpicklableTaskError, match="locked"):
        pool.submit(t)
    assert not t.started  # nothing was scheduled


def test_unpicklable_body_with_any_affinity_runs_locally(pool):
    t = Task(_locked_body(), affinity="any")
    t.propagate_errors = False
    assert Executor(pool=pool).run(t).result(10) is True  # acquired in-parent
    assert t.done


def test_unpicklable_spawned_remote_task_fails_its_task(pool):
    """A runtime-spawned affinity='remote' body that cannot ship fails
    when it runs (wiring happens inside the scheduler loop — deferred),
    and the failure adopts through the join like any subflow error."""
    g = TaskGraph()

    def spawn(rt):
        rt.add(_locked_body(), affinity="remote", name="bad-spawn")

    g.add(spawn, takes_runtime=True)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(UnpicklableTaskError, match="bad-spawn"):
        Executor(pool=pool).run(g).result(10)


# ---------------------------------------------------------------------------
# fault handling
# ---------------------------------------------------------------------------


def test_worker_death_fails_task_and_releases_wait_idle(pool):
    fut = pool.submit_future(lambda: os._exit(7))
    with pytest.raises(WorkerDiedError):
        fut.result(10)
    assert pool.wait_idle(10) is True  # no hang, no poisoned pool
    # capacity restored: the respawned worker serves the next job
    assert pool.submit_future(lambda: "alive").result(10) == "alive"
    assert pool.stats()["worker_restarts"] >= 1


def test_worker_death_poisons_propagating_graph(pool):
    g = TaskGraph()
    dead = g.add(lambda: os._exit(3), name="dies")
    g.then(dead, lambda _x: "unreachable")
    with pytest.raises(WorkerDiedError):
        Executor(pool=pool).run(g).result(10)


def test_remote_exception_type_survives(pool):
    with pytest.raises(ZeroDivisionError):
        pool.submit_future(lambda: 1 // 0).result(10)


@pytest.mark.skipif(not os.path.isdir("/proc/self/fd"), reason="needs procfs")
def test_respawn_cycles_do_not_leak_fds():
    """Regression (§14 satellite): every kill/respawn cycle must close the
    dead worker's pipe ends AND its Process object's sentinel/fifo
    descriptors — 20 cycles through one slot may not grow this process's
    open-FD count."""
    with ProcessPool(1, name="fd-pool") as pool:
        with pytest.raises(WorkerDiedError):
            pool.submit_future(lambda: os._exit(9)).result(20)  # warm the path
        pool.submit_future(lambda: None).result(20)  # slot respawned + live
        baseline = len(os.listdir("/proc/self/fd"))
        for _ in range(20):
            with pytest.raises(WorkerDiedError):
                pool.submit_future(lambda: os._exit(9)).result(20)
        pool.submit_future(lambda: None).result(20)  # steady state again
        after = len(os.listdir("/proc/self/fd"))
        # identical modulo transient slack (a respawn mid-count holds a
        # few descriptors for one cycle); 20 leaked cycles would show as
        # +40 or more (two pipe ends each)
        assert after - baseline <= 4, f"fd leak: {baseline} -> {after}"
        assert pool.stats()["worker_restarts"] >= 21


# ---------------------------------------------------------------------------
# shared-memory data plane
# ---------------------------------------------------------------------------


def test_large_array_edges_cross_the_arena(pool):
    n = 512  # 2 MB float64 — far above the arena threshold
    g = TaskGraph()
    src = g.add(lambda: np.ones((n, n)), name="make")
    total = g.then(src, lambda a: float(a.sum()), name="sum")
    Executor(pool=pool).run(g).result(30)
    assert total.result == float(n * n)


def test_large_array_result_returns_intact(pool):
    arr = pool.submit_future(lambda: np.arange(100_000, dtype=np.int64)).result(30)
    assert isinstance(arr, np.ndarray)
    assert arr.shape == (100_000,) and arr[-1] == 99_999


def test_arena_segments_recycle_across_jobs(pool):
    g = TaskGraph()
    heads = [g.add(lambda i=i: np.full(50_000, i, np.float64), name=f"h{i}") for i in range(4)]
    sums = [g.then(h, lambda a: float(a.sum())) for h in heads]
    Executor(pool=pool).run(g).result(30)
    assert [s.result for s in sums] == [0.0, 50_000.0, 100_000.0, 150_000.0]
    # pooled segments are bounded by concurrency, not by job count
    assert len(pool._arena._owned) <= 2 * pool.num_threads


def test_fanout_parallel_remote_bodies(pool):
    g = TaskGraph()
    root = g.add(lambda: None)
    layer = [g.add(lambda i=i: os.getpid() * 0 + i).after(root) for i in range(8)]
    tot = g.gather(layer, fn=lambda *vs: sum(vs))
    Executor(pool=pool).run(g).result(30)
    assert tot.result == sum(range(8))
    assert pool.stats()["remote_jobs"] >= 8


# ---------------------------------------------------------------------------
# snapshot semantics (the documented sharp edge)
# ---------------------------------------------------------------------------


def test_remote_closure_mutation_does_not_travel_back(pool):
    """Remote bodies see closure snapshots; the parent's cell is untouched.
    This is the documented §11 contract, pinned here so it fails loudly if
    the semantics ever drift."""
    hits = []
    t = Task(lambda: hits.append(1) or len(hits))
    t.propagate_errors = False
    assert Executor(pool=pool).run(t).result(10) == 1  # worker-side append
    assert hits == []  # parent cell untouched


def test_unpicklable_edge_value_falls_back_in_parent(pool):
    """An 'any' task whose dataflow input does not pickle runs in-parent
    (thread/serial parity) instead of failing with a raw pickle error;
    affinity='remote' keeps the clear contract error (review fix)."""
    g = TaskGraph()
    src = g.add(lambda: threading.Lock(), affinity="local", name="lockmaker")
    took = g.then(src, lambda lk: lk.acquire(), name="taker")
    Executor(pool=pool).run(g).result(10)
    assert took.result is True  # body ran in-parent on the real lock

    g2 = TaskGraph()
    src2 = g2.add(lambda: threading.Lock(), affinity="local")
    bad = g2.then(src2, lambda lk: lk, name="must-remote")
    bad.affinity = "remote"
    for t in g2.tasks:
        t.propagate_errors = False
    with pytest.raises(UnpicklableTaskError, match="dataflow input"):
        Executor(pool=pool).run(g2).result(10)
