"""SocketPool transport behavior (DESIGN.md §16): the framed job protocol,
handshake gating, the worker launcher, per-connection transfer caching and
the consumer surfaces — everything socket-*specific*. The backend-portable
scheduler semantics are certified by ``tests/dist/conformance.py``; the
fault battery (real kills, half-open sockets, heartbeat lapses) lives in
``test_socket_chaos.py``."""
import os
import pickle
import socket
import struct
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import Executor, Task, TaskGraph
from repro.dist import SocketPool, UnpicklableTaskError, WorkerDiedError
from repro.dist.remote_worker import (
    MAGIC,
    PROTOCOL_VERSION,
    FramedConn,
    spawn_workers,
    worker_caps,
)


@pytest.fixture()
def pool():
    with SocketPool(2, name="test-sockpool") as p:
        yield p


# ---------------------------------------------------------------------------
# placement + wiring (socket-specific: the body crosses a TCP frame)
# ---------------------------------------------------------------------------


def test_remote_execution_crosses_the_socket(pool):
    assert pool.submit_future(lambda: os.getpid()).result(20) != os.getpid()
    s = pool.stats()
    assert s["remote_jobs"] >= 1
    assert s["workers_connected"] == 2


def test_affinity_local_pins_to_parent(pool):
    t = Task(lambda: os.getpid(), affinity="local")
    t.propagate_errors = False
    assert Executor(pool=pool).run(t).result(20) == os.getpid()


def test_unpicklable_body_raises_clear_error_at_submit(pool):
    import threading

    lock = threading.Lock()
    t = Task(lambda: lock.acquire(), name="locked", affinity="remote")
    with pytest.raises(UnpicklableTaskError, match="locked"):
        pool.submit(t)
    assert not t.started


def test_remote_exception_type_survives(pool):
    with pytest.raises(ZeroDivisionError):
        pool.submit_future(lambda: 1 // 0).result(20)


def test_workers_alias_and_liveness_validation():
    with SocketPool(workers=1) as p:
        assert p.num_threads == 1
        assert p.submit_future(lambda: "hi").result(20) == "hi"
    with pytest.raises(ValueError, match="liveness"):
        SocketPool(1, heartbeat_s=0.5, liveness_s=0.5)


# ---------------------------------------------------------------------------
# handshake gating
# ---------------------------------------------------------------------------


def _raw_hello(address, hello, timeout=5.0):
    """Open a raw framed connection, send ``hello``, return the ack."""
    with socket.create_connection(address, timeout=timeout) as sk:
        payload = pickle.dumps(hello, protocol=pickle.HIGHEST_PROTOCOL)
        sk.sendall(struct.pack("!I", len(payload)) + payload)
        hdr = b""
        while len(hdr) < 4:
            chunk = sk.recv(4 - len(hdr))
            assert chunk, "listener hung up without an ack"
            hdr += chunk
        (n,) = struct.unpack("!I", hdr)
        body = b""
        while len(body) < n:
            body += sk.recv(n - len(body))
        return pickle.loads(body)


def test_handshake_rejects_version_mismatch(pool):
    ack = _raw_hello(
        pool.address, {"magic": MAGIC, "version": 999, "caps": worker_caps()}
    )
    assert ack["ok"] is False and "protocol" in ack["error"]
    assert ack["version"] == PROTOCOL_VERSION  # the rejection names ours
    assert pool.stats()["handshakes_rejected"] == 1
    # the pool keeps serving on its existing workers
    assert pool.submit_future(lambda: 21 * 2).result(20) == 42


def test_handshake_rejects_wrong_magic(pool):
    ack = _raw_hello(pool.address, {"magic": "not-repro", "version": 1, "caps": {}})
    assert ack["ok"] is False
    assert pool.submit_future(lambda: "fine").result(20) == "fine"


def test_handshake_rejects_when_slots_full(pool):
    """All slots occupied: a well-formed extra worker is turned away."""
    ack = _raw_hello(
        pool.address,
        {"magic": MAGIC, "version": PROTOCOL_VERSION, "caps": worker_caps()},
    )
    assert ack["ok"] is False and "slot" in ack["error"]
    assert pool.submit_future(lambda: "serving").result(20) == "serving"


# ---------------------------------------------------------------------------
# remote attach: the launcher CLI and spawn_workers
# ---------------------------------------------------------------------------


def test_cli_worker_attaches_and_serves():
    """``python -m repro.dist.remote_worker --connect host:port`` fills a
    slot: the pool records no local process for it, the handshake carries
    the CLI's pid, and an orderly close sends ``bye`` (worker exits 0)."""
    import repro.dist.remote_worker as rw

    src_dir = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(rw.__file__)))
    )
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_dir, env.get("PYTHONPATH")) if p
    )
    with SocketPool(1, spawn_local=False) as pool:
        host, port = pool.address
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.dist.remote_worker",
             "--connect", f"{host}:{port}"],
            env=env,
        )
        try:
            assert pool.submit_future(lambda: os.getpid()).result(30) == proc.pid
            assert pool._procs[0] is None  # remote slot: no local Process
            assert pool._caps[0]["pid"] == proc.pid
        finally:
            pool.close()
            assert proc.wait(10) == 0  # "bye" -> orderly exit
    # close() before the fixture-style exit above; the context exit is a no-op


def test_submit_parks_until_a_worker_attaches():
    """spawn_local=False: jobs wait for capacity, then flow. The §16
    dispatcher blocks on the slot-ready event, not on a dead endpoint."""
    with SocketPool(1, spawn_local=False, connect_timeout=30.0) as pool:
        fut = pool.submit_future(lambda: "late but served")
        time.sleep(0.2)  # genuinely parked: nothing to run it yet
        assert not fut.done()
        procs = spawn_workers(1, pool.address)
        try:
            assert fut.result(30) == "late but served"
        finally:
            pool.close()
            for p in procs:
                p.join(10)


def test_spawn_workers_returns_live_processes():
    with SocketPool(2, spawn_local=False) as pool:
        procs = spawn_workers(2, pool.address)
        try:
            fut = pool.submit_future(lambda: sum(range(100)))
            assert fut.result(30) == 4950
            assert pool.stats()["workers_connected"] == 2
        finally:
            pool.close()
            for p in procs:
                p.join(10)


# ---------------------------------------------------------------------------
# per-connection transfer cache
# ---------------------------------------------------------------------------


def test_transfer_cache_dedups_repeated_arrays(pool):
    """The same large array flowing to several consumers ships once per
    connection; repeats travel digest-only (§16 TransferCache)."""
    g = TaskGraph()
    src = g.add(lambda: np.ones(300_000), name="make", affinity="local")
    sums = [g.then(src, lambda a: float(a.sum()), name=f"s{i}") for i in range(4)]
    Executor(pool=pool).run(g).result(30)
    assert [t.result for t in sums] == [300_000.0] * 4
    s = pool.stats()
    assert s["cache_misses"] >= 1  # first send per connection
    assert s["cache_hits"] >= 1  # at least one repeat went digest-only


def test_transfer_cache_resets_after_respawn(pool):
    """A replacement worker holds no cached state: the same array misses
    again on the fresh connection instead of dangling a stale digest."""
    g = TaskGraph()
    src = g.add(lambda: np.full(200_000, 7.0), name="make", affinity="local")
    sums = [g.then(src, lambda a: float(a.sum())) for _ in range(4)]
    Executor(pool=pool).run(g).result(30)
    assert pool.stats()["cache_misses"] >= 1
    # kill both workers: every connection (and its cache) is replaced
    for p in list(pool._procs):
        if p is not None:
            p.kill()
    deadline = time.monotonic() + 20
    while pool.stats()["worker_restarts"] < 2 and time.monotonic() < deadline:
        time.sleep(0.05)
    # the replacement connections carry *fresh* caches: counters are zero
    s = pool.stats()
    assert s["cache_misses"] == 0 and s["cache_hits"] == 0
    g2 = TaskGraph()
    src2 = g2.add(lambda: np.full(200_000, 7.0), name="make2", affinity="local")
    sums2 = [g2.then(src2, lambda a: float(a.sum())) for _ in range(4)]
    Executor(pool=pool).run(g2).result(30)
    assert [t.result for t in sums2] == [1_400_000.0] * 4
    # the same array *missed* again — sent inline on the new connection,
    # not resolved against a digest the dead worker took with it
    assert pool.stats()["cache_misses"] >= 1


def test_large_array_result_returns_intact(pool):
    arr = pool.submit_future(lambda: np.arange(100_000, dtype=np.int64)).result(30)
    assert isinstance(arr, np.ndarray)
    assert arr.shape == (100_000,) and arr[-1] == 99_999


# ---------------------------------------------------------------------------
# consumer surfaces on the socket backend
# ---------------------------------------------------------------------------


def test_checkpoint_manager_on_socket_backend(tmp_path):
    from repro.checkpoint import CheckpointManager

    tree = {"w": np.arange(12.0).reshape(3, 4), "step": np.array(3)}
    with CheckpointManager(tmp_path, backend="socket") as mgr:
        mgr.save_async(3, tree)
        mgr.wait()
        restored, meta = mgr.restore(tree)
    assert meta["step"] == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_prefetcher_on_socket_backend():
    from repro.data import Prefetcher

    class Source:
        def batch(self, step):
            return {"x": np.full(4, float(step))}

    pf = Prefetcher(Source(), backend="socket", depth=2,
                    put_fn=lambda b: float(b["x"].sum()))
    try:
        assert [pf.get(30) for _ in range(5)] == [0.0, 4.0, 8.0, 12.0, 16.0]
    finally:
        pf.close()


def test_prefetcher_socket_requires_put_fn():
    from repro.data import Prefetcher

    class Source:
        def batch(self, step):  # pragma: no cover - never reached
            return {}

    with pytest.raises(ValueError, match="put_fn"):
        Prefetcher(Source(), backend="socket")


# ---------------------------------------------------------------------------
# framing primitives
# ---------------------------------------------------------------------------


def test_framed_conn_roundtrip_and_eof():
    a, b = socket.socketpair()
    ca, cb = FramedConn(a), FramedConn(b)
    try:
        ca.send(("job", 1, b"x" * 70_000, None))  # bigger than one segment
        kind, jid, blob, rest = cb.recv(timeout=5.0)
        assert (kind, jid, rest) == ("job", 1, None) and len(blob) == 70_000
        cb.send(("res", 1, True, "ok"))
        assert ca.recv(timeout=5.0) == ("res", 1, True, "ok")
        cb.close()
        with pytest.raises((EOFError, OSError)):
            ca.recv(timeout=5.0)
    finally:
        ca.close()
        cb.close()


def test_framed_conn_recv_timeout():
    a, b = socket.socketpair()
    ca, cb = FramedConn(a), FramedConn(b)
    try:
        with pytest.raises(TimeoutError):
            ca.recv(timeout=0.1)
    finally:
        ca.close()
        cb.close()


def test_stats_surface_has_transport_counters(pool):
    pool.submit_future(lambda: None).result(20)
    s = pool.stats()
    for key in (
        "remote_jobs",
        "worker_restarts",
        "worker_kills",
        "heartbeat_lapses",
        "handshakes_rejected",
        "workers_connected",
        "cache_hits",
        "cache_misses",
    ):
        assert key in s, key
