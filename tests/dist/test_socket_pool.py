"""SocketPool transport behavior (DESIGN.md §16): the framed job protocol,
handshake gating, the worker launcher, per-connection transfer caching and
the consumer surfaces — everything socket-*specific*. The backend-portable
scheduler semantics are certified by ``tests/dist/conformance.py``; the
fault battery (real kills, half-open sockets, heartbeat lapses) lives in
``test_socket_chaos.py``."""
import os
import pickle
import socket
import struct
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core import Executor, Task, TaskGraph
from repro.dist import SocketPool, UnpicklableTaskError, WorkerDiedError
from repro.dist.remote_worker import (
    AUTHKEY_ENV,
    MAGIC,
    PROTOCOL_VERSION,
    AuthenticationError,
    FramedConn,
    answer_challenge,
    deliver_challenge,
    run_worker,
    spawn_workers,
    worker_caps,
)


@pytest.fixture()
def pool():
    with SocketPool(2, name="test-sockpool") as p:
        yield p


# ---------------------------------------------------------------------------
# placement + wiring (socket-specific: the body crosses a TCP frame)
# ---------------------------------------------------------------------------


def test_remote_execution_crosses_the_socket(pool):
    assert pool.submit_future(lambda: os.getpid()).result(20) != os.getpid()
    s = pool.stats()
    assert s["remote_jobs"] >= 1
    assert s["workers_connected"] == 2


def test_affinity_local_pins_to_parent(pool):
    t = Task(lambda: os.getpid(), affinity="local")
    t.propagate_errors = False
    assert Executor(pool=pool).run(t).result(20) == os.getpid()


def test_unpicklable_body_raises_clear_error_at_submit(pool):
    import threading

    lock = threading.Lock()
    t = Task(lambda: lock.acquire(), name="locked", affinity="remote")
    with pytest.raises(UnpicklableTaskError, match="locked"):
        pool.submit(t)
    assert not t.started


def test_remote_exception_type_survives(pool):
    with pytest.raises(ZeroDivisionError):
        pool.submit_future(lambda: 1 // 0).result(20)


def test_workers_alias_and_liveness_validation():
    with SocketPool(workers=1) as p:
        assert p.num_threads == 1
        assert p.submit_future(lambda: "hi").result(20) == "hi"
    with pytest.raises(ValueError, match="liveness"):
        SocketPool(1, heartbeat_s=0.5, liveness_s=0.5)


# ---------------------------------------------------------------------------
# handshake gating
# ---------------------------------------------------------------------------


def _raw_hello(address, hello, *, authkey, timeout=5.0):
    """Authenticate, send ``hello``, return the ack (the attach path a
    well-keyed but possibly version-skewed worker walks)."""
    conn = FramedConn(socket.create_connection(address, timeout=timeout))
    try:
        answer_challenge(conn, authkey, timeout=timeout)
        deliver_challenge(conn, authkey, timeout=timeout)
        conn.send(hello)
        return conn.recv(timeout=timeout)
    finally:
        conn.close()


def test_handshake_rejects_version_mismatch(pool):
    ack = _raw_hello(
        pool.address,
        {"magic": MAGIC, "version": 999, "caps": worker_caps()},
        authkey=pool.authkey,
    )
    assert ack["ok"] is False and "protocol" in ack["error"]
    assert ack["version"] == PROTOCOL_VERSION  # the rejection names ours
    assert pool.stats()["handshakes_rejected"] == 1
    # the pool keeps serving on its existing workers
    assert pool.submit_future(lambda: 21 * 2).result(20) == 42


def test_handshake_rejects_wrong_magic(pool):
    ack = _raw_hello(
        pool.address,
        {"magic": "not-repro", "version": 1, "caps": {}},
        authkey=pool.authkey,
    )
    assert ack["ok"] is False
    assert pool.submit_future(lambda: "fine").result(20) == "fine"


def test_handshake_rejects_when_slots_full(pool):
    """All slots occupied: a well-formed extra worker is turned away."""
    ack = _raw_hello(
        pool.address,
        {"magic": MAGIC, "version": PROTOCOL_VERSION, "caps": worker_caps()},
        authkey=pool.authkey,
    )
    assert ack["ok"] is False and "slot" in ack["error"]
    assert pool.submit_future(lambda: "serving").result(20) == "serving"


# ---------------------------------------------------------------------------
# authentication: nothing from an unauthenticated peer is ever unpickled
# ---------------------------------------------------------------------------

_EVIL_TRIPPED = False


def _trip_evil_flag():
    global _EVIL_TRIPPED
    _EVIL_TRIPPED = True
    return ()


class _EvilPayload:
    """Unpickling this object calls ``_trip_evil_flag`` — the in-process
    stand-in for an RCE gadget on the wire."""

    def __reduce__(self):
        return (_trip_evil_flag, ())


def test_unauthenticated_pickle_is_never_loaded(pool):
    """A peer that skips the challenge and fires a malicious pickle at
    the listener is dropped before any ``pickle.loads`` runs (the accept
    loop and the pool share this process, so the gadget would trip the
    flag right here if it were ever unpickled)."""
    global _EVIL_TRIPPED
    _EVIL_TRIPPED = False
    payload = pickle.dumps(_EvilPayload(), protocol=pickle.HIGHEST_PROTOCOL)
    with socket.create_connection(pool.address, timeout=5.0) as sk:
        sk.sendall(struct.pack("!I", len(payload)) + payload)
        sk.settimeout(5.0)
        # the parent reads our bytes only as a (wrong) HMAC digest and
        # hangs up; drain until EOF to observe the rejection
        while True:
            try:
                if not sk.recv(4096):
                    break
            except OSError:
                break
    deadline = time.monotonic() + 5.0
    while pool.stats()["auth_failures"] < 1 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert not _EVIL_TRIPPED
    assert pool.stats()["auth_failures"] == 1
    assert pool.stats()["handshakes_rejected"] == 0  # dropped pre-handshake
    assert pool.submit_future(lambda: "still serving").result(20) == "still serving"


def test_wrong_authkey_is_rejected(pool):
    conn = FramedConn(socket.create_connection(pool.address, timeout=5.0))
    try:
        with pytest.raises(AuthenticationError):
            answer_challenge(conn, b"not-the-key", timeout=5.0)
    finally:
        conn.close()
    deadline = time.monotonic() + 5.0
    while pool.stats()["auth_failures"] < 1 and time.monotonic() < deadline:
        time.sleep(0.02)
    assert pool.stats()["auth_failures"] == 1
    assert pool.submit_future(lambda: 2 + 2).result(20) == 4


def test_worker_refuses_unauthenticated_parent():
    """The worker side is symmetric: a rogue listener that feeds
    ``run_worker`` a pickled frame instead of a challenge gets dropped
    (exit code 1) without the payload ever being unpickled."""
    global _EVIL_TRIPPED
    _EVIL_TRIPPED = False
    listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    listener.bind(("127.0.0.1", 0))
    listener.listen(1)
    host, port = listener.getsockname()[:2]

    def _rogue_parent():
        sk, _ = listener.accept()
        payload = pickle.dumps(_EvilPayload(), protocol=pickle.HIGHEST_PROTOCOL)
        sk.sendall(struct.pack("!I", len(payload)) + payload)
        time.sleep(0.5)
        sk.close()

    import threading

    t = threading.Thread(target=_rogue_parent, daemon=True)
    t.start()
    try:
        code = run_worker(host, port, authkey=b"worker-key", connect_timeout=5.0)
    finally:
        t.join(10)
        listener.close()
    assert code == 1
    assert not _EVIL_TRIPPED


def test_nonloopback_bind_requires_explicit_authkey():
    with pytest.raises(ValueError, match="authkey"):
        SocketPool(1, host="0.0.0.0")
    # an explicit key makes the same bind legal
    with SocketPool(1, host="0.0.0.0", authkey=b"fleet-secret") as p:
        assert p.authkey == b"fleet-secret"
        assert p.submit_future(lambda: "keyed").result(20) == "keyed"


# ---------------------------------------------------------------------------
# remote attach: the launcher CLI and spawn_workers
# ---------------------------------------------------------------------------


def test_cli_worker_attaches_and_serves():
    """``python -m repro.dist.remote_worker --connect host:port`` fills a
    slot: the pool records no local process for it, the handshake carries
    the CLI's pid, and an orderly close sends ``bye`` (worker exits 0)."""
    import repro.dist.remote_worker as rw

    src_dir = os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(rw.__file__)))
    )
    env = {**os.environ}
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src_dir, env.get("PYTHONPATH")) if p
    )
    with SocketPool(1, spawn_local=False) as pool:
        host, port = pool.address
        env[AUTHKEY_ENV] = pool.authkey.hex()
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.dist.remote_worker",
             "--connect", f"{host}:{port}"],
            env=env,
        )
        try:
            assert pool.submit_future(lambda: os.getpid()).result(30) == proc.pid
            assert pool._procs[0] is None  # remote slot: no local Process
            assert pool._caps[0]["pid"] == proc.pid
        finally:
            pool.close()
            assert proc.wait(10) == 0  # "bye" -> orderly exit
    # close() before the fixture-style exit above; the context exit is a no-op


def test_submit_parks_until_a_worker_attaches():
    """spawn_local=False: jobs wait for capacity, then flow. The §16
    dispatcher blocks on the slot-ready event, not on a dead endpoint."""
    with SocketPool(1, spawn_local=False, connect_timeout=30.0) as pool:
        fut = pool.submit_future(lambda: "late but served")
        time.sleep(0.2)  # genuinely parked: nothing to run it yet
        assert not fut.done()
        procs = spawn_workers(1, pool.address, authkey=pool.authkey)
        try:
            assert fut.result(30) == "late but served"
        finally:
            pool.close()
            for p in procs:
                p.join(10)


def test_spawn_workers_returns_live_processes():
    with SocketPool(2, spawn_local=False) as pool:
        procs = spawn_workers(2, pool.address, authkey=pool.authkey)
        try:
            fut = pool.submit_future(lambda: sum(range(100)))
            assert fut.result(30) == 4950
            # the second worker may still be mid-handshake (mutual auth
            # adds round trips); wait for it rather than racing it
            deadline = time.monotonic() + 20
            while (pool.stats()["workers_connected"] < 2
                   and time.monotonic() < deadline):
                time.sleep(0.05)
            assert pool.stats()["workers_connected"] == 2
        finally:
            pool.close()
            for p in procs:
                p.join(10)


# ---------------------------------------------------------------------------
# slot binding and pending-worker lifecycle
# ---------------------------------------------------------------------------


def test_spawned_workers_bind_by_nonce(pool):
    """Each locally spawned worker's connection is bound to its Process
    via the per-spawn nonce echoed in the hello caps."""
    for i in range(2):
        assert pool._procs[i] is not None
        assert pool._caps[i]["nonce"] == pool._procs[i].spawn_nonce


def test_slot_binding_ignores_pid_collision():
    """A connecting worker must be bound to a pending local Process only
    via its spawn nonce, never its self-reported pid: a remote worker
    whose pid collides with a pending local worker's must not adopt that
    Process (exitcode probes and watchdog SIGKILLs would target a
    stranger)."""

    class FakePending:
        pid = 987654
        spawn_nonce = "nonce-of-a-real-local-spawn"
        exitcode = None

    fake = FakePending()
    with SocketPool(1, spawn_local=False) as pool:
        with pool._proc_lock:
            pool._pending_procs.append(fake)
        caps = worker_caps()
        caps["pid"] = fake.pid  # the collision
        conn = FramedConn(socket.create_connection(pool.address, timeout=5.0))
        try:
            answer_challenge(conn, pool.authkey, timeout=5.0)
            deliver_challenge(conn, pool.authkey, timeout=5.0)
            conn.send({"magic": MAGIC, "version": PROTOCOL_VERSION, "caps": caps})
            assert conn.recv(timeout=5.0)["ok"] is True
            assert pool._slot_ready[0].wait(5.0)
            assert pool._procs[0] is None  # not mis-bound to the fake
            with pool._proc_lock:
                assert fake in pool._pending_procs  # still awaiting its own
                pool._pending_procs.remove(fake)
        finally:
            conn.close()
            pool.close()


def _exit_immediately():
    return  # a spawned worker that dies before ever connecting


def test_dead_pending_worker_is_replaced(monkeypatch):
    """A respawned local worker that exits before connecting (import
    failure, startup OOM kill) must not strand its slot: the monitor
    detects the exited pending process and forks a replacement, instead
    of capacity being silently lost for the pool's lifetime."""
    import multiprocessing as mp

    import repro.dist.socket_pool as sp

    real_spawn = sp.spawn_workers
    doomed = {"armed": False, "fired": False}

    def flaky_spawn(n, address, **kw):
        if doomed["armed"] and not doomed["fired"]:
            doomed["fired"] = True
            procs = []
            for _ in range(n):
                p = mp.get_context("fork").Process(
                    target=_exit_immediately, daemon=True
                )
                p.spawn_nonce = "doomed-before-connect"
                p.start()
                procs.append(p)
            return procs
        return real_spawn(n, address, **kw)

    monkeypatch.setattr(sp, "spawn_workers", flaky_spawn)
    with SocketPool(1, heartbeat_s=0.05, name="refill-sock") as pool:
        assert pool.submit_future(lambda: 1).result(20) == 1
        doomed["armed"] = True
        pool._procs[0].kill()  # respawn path hands the slot the doomed child
        deadline = time.monotonic() + 30
        while pool.stats()["pending_respawns"] < 1 and time.monotonic() < deadline:
            time.sleep(0.05)
        assert pool.stats()["pending_respawns"] >= 1
        # the monitor's replacement (a healthy worker) restores capacity
        assert pool.submit_future(lambda: "revived").result(30) == "revived"
        assert pool.stats()["worker_restarts"] >= 1


# ---------------------------------------------------------------------------
# per-connection transfer cache
# ---------------------------------------------------------------------------


def test_transfer_cache_dedups_repeated_arrays(pool):
    """The same large array flowing to several consumers ships once per
    connection; repeats travel digest-only (§16 TransferCache)."""
    g = TaskGraph()
    src = g.add(lambda: np.ones(300_000), name="make", affinity="local")
    sums = [g.then(src, lambda a: float(a.sum()), name=f"s{i}") for i in range(4)]
    Executor(pool=pool).run(g).result(30)
    assert [t.result for t in sums] == [300_000.0] * 4
    s = pool.stats()
    assert s["cache_misses"] >= 1  # first send per connection
    assert s["cache_hits"] >= 1  # at least one repeat went digest-only


def test_transfer_cache_resets_after_respawn(pool):
    """A replacement worker holds no cached state: the same array misses
    again on the fresh connection instead of dangling a stale digest."""
    g = TaskGraph()
    src = g.add(lambda: np.full(200_000, 7.0), name="make", affinity="local")
    sums = [g.then(src, lambda a: float(a.sum())) for _ in range(4)]
    Executor(pool=pool).run(g).result(30)
    assert pool.stats()["cache_misses"] >= 1
    # kill both workers: every connection (and its cache) is replaced
    for p in list(pool._procs):
        if p is not None:
            p.kill()
    deadline = time.monotonic() + 20
    while pool.stats()["worker_restarts"] < 2 and time.monotonic() < deadline:
        time.sleep(0.05)
    # the replacement connections carry *fresh* caches: counters are zero
    s = pool.stats()
    assert s["cache_misses"] == 0 and s["cache_hits"] == 0
    g2 = TaskGraph()
    src2 = g2.add(lambda: np.full(200_000, 7.0), name="make2", affinity="local")
    sums2 = [g2.then(src2, lambda a: float(a.sum())) for _ in range(4)]
    Executor(pool=pool).run(g2).result(30)
    assert [t.result for t in sums2] == [1_400_000.0] * 4
    # the same array *missed* again — sent inline on the new connection,
    # not resolved against a digest the dead worker took with it
    assert pool.stats()["cache_misses"] >= 1


def test_large_array_result_returns_intact(pool):
    arr = pool.submit_future(lambda: np.arange(100_000, dtype=np.int64)).result(30)
    assert isinstance(arr, np.ndarray)
    assert arr.shape == (100_000,) and arr[-1] == 99_999


# ---------------------------------------------------------------------------
# consumer surfaces on the socket backend
# ---------------------------------------------------------------------------


def test_checkpoint_manager_on_socket_backend(tmp_path):
    from repro.checkpoint import CheckpointManager

    tree = {"w": np.arange(12.0).reshape(3, 4), "step": np.array(3)}
    with CheckpointManager(tmp_path, backend="socket") as mgr:
        mgr.save_async(3, tree)
        mgr.wait()
        restored, meta = mgr.restore(tree)
    assert meta["step"] == 3
    np.testing.assert_array_equal(restored["w"], tree["w"])


def test_prefetcher_on_socket_backend():
    from repro.data import Prefetcher

    class Source:
        def batch(self, step):
            return {"x": np.full(4, float(step))}

    pf = Prefetcher(Source(), backend="socket", depth=2,
                    put_fn=lambda b: float(b["x"].sum()))
    try:
        assert [pf.get(30) for _ in range(5)] == [0.0, 4.0, 8.0, 12.0, 16.0]
    finally:
        pf.close()


def test_prefetcher_socket_requires_put_fn():
    from repro.data import Prefetcher

    class Source:
        def batch(self, step):  # pragma: no cover - never reached
            return {}

    with pytest.raises(ValueError, match="put_fn"):
        Prefetcher(Source(), backend="socket")


# ---------------------------------------------------------------------------
# framing primitives
# ---------------------------------------------------------------------------


def test_framed_conn_roundtrip_and_eof():
    a, b = socket.socketpair()
    ca, cb = FramedConn(a), FramedConn(b)
    try:
        ca.send(("job", 1, b"x" * 70_000, None))  # bigger than one segment
        kind, jid, blob, rest = cb.recv(timeout=5.0)
        assert (kind, jid, rest) == ("job", 1, None) and len(blob) == 70_000
        cb.send(("res", 1, True, "ok"))
        assert ca.recv(timeout=5.0) == ("res", 1, True, "ok")
        cb.close()
        with pytest.raises((EOFError, OSError)):
            ca.recv(timeout=5.0)
    finally:
        ca.close()
        cb.close()


def test_recv_restores_blocking_socket():
    """A timed recv must not leave its timeout armed on the socket: the
    next large ``send`` would otherwise run ``sendall`` under the stale
    liveness window, so big frames over slow links could never succeed
    (the transport retry would deterministically fail the same way)."""
    a, b = socket.socketpair()
    ca, cb = FramedConn(a), FramedConn(b)
    try:
        cb.send(("hb",))
        assert ca.recv(timeout=2.5) == ("hb",)
        assert a.gettimeout() is None  # restored: sends are unbounded
        with pytest.raises(TimeoutError):
            ca.recv(timeout=0.05)
        assert a.gettimeout() is None  # restored on the timeout path too
    finally:
        ca.close()
        cb.close()


def test_framed_conn_recv_timeout():
    a, b = socket.socketpair()
    ca, cb = FramedConn(a), FramedConn(b)
    try:
        with pytest.raises(TimeoutError):
            ca.recv(timeout=0.1)
    finally:
        ca.close()
        cb.close()


def test_stats_surface_has_transport_counters(pool):
    pool.submit_future(lambda: None).result(20)
    s = pool.stats()
    for key in (
        "remote_jobs",
        "worker_restarts",
        "worker_kills",
        "heartbeat_lapses",
        "handshakes_rejected",
        "auth_failures",
        "pending_respawns",
        "empty_slot_timeouts",
        "workers_connected",
        "cache_hits",
        "cache_misses",
    ):
        assert key in s, key
