"""Cross-backend conformance suite: one matrix, four executors.

Every test here runs — with the *same* parametrized assertions, no
backend-specific skips — on all four execution backends (DESIGN.md §11,
§16): **serial**, **thread**, **process** and **socket**. This is the
certification surface for any new transport: a pool that passes this
file provides the §9/§10 scheduler contract (lifecycle, priorities,
conditions and weak cycles, subflows, counted completion), §12 replay
parity, the §14 fault model (retry, cooperative timeout, the
at-most-once gate for started transport losses) and §8 observer
accounting, indistinguishably from the paper's thread pool.

Process-safe idioms apply throughout (they are what make one suite
possible): loop/convergence state lives in condition bodies (which
always run scheduler-side) or flows along dataflow edges; attempt
counters are pinned ``affinity="local"``; assertions read parent-side
task state (``result`` / ``done`` / ``exception``), never closure cells
a remote body would have mutated in its own address space.

Backend-*specific* behavior lives elsewhere: thread-only timing tests in
``tests/core/test_executor.py``, pipe-transport faults in
``tests/dist/test_process_pool.py``, socket-transport faults and the
chaos battery in ``tests/dist/test_socket_pool.py`` /
``test_socket_chaos.py``.
"""
import asyncio
import threading
import time

import pytest

from repro.core import (
    Executor,
    RetryPolicy,
    Task,
    TaskGraph,
    TaskTimeoutError,
    checkpoint,
)
from repro.dist import WorkerDiedError

BACKENDS = ("serial", "thread", "process", "socket")


@pytest.fixture(params=BACKENDS)
def ex(request):
    """One Executor per backend — the whole suite runs on all four."""
    n = 2 if request.param in ("process", "socket") else 4
    with Executor(n, backend=request.param) as e:
        yield e


def _build_loop(iters):
    """entry -> body -> more? with a weak back-edge to body.

    Loop state lives in the *condition* body — conditions always execute
    scheduler-side, so the counter is authoritative on every backend.
    """
    g = TaskGraph("loop")
    state = {"i": 0, "runs": 0}
    entry = g.add(lambda: state.update(i=0), name="entry", affinity="local")
    body = g.add(lambda: None, name="body")  # remote-eligible each pass
    body.after(entry)

    def more():
        state["i"] += 1
        state["runs"] += 1
        return 0 if state["i"] < iters else 1

    cond = g.add(more, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)
    return g, state


# ---------------------------------------------------------------------------
# lifecycle + facade basics
# ---------------------------------------------------------------------------


def test_run_callable_returns_future(ex):
    assert ex.run(lambda: 6 * 7).result(30) == 42


def test_run_single_task_resolves_to_result(ex):
    t = Task(lambda: "payload")
    t.propagate_errors = False
    assert ex.run(t).result(30) == "payload"


def test_run_graph_and_iterable(ex):
    g = TaskGraph()
    a = g.add(lambda: 3)
    b = g.then(a, lambda x: x * x)
    assert ex.run(g).result(30) is None
    assert b.result == 9
    # an anonymous iterable of tasks is wrapped in a graph; the dataflow
    # edge proves t2 ran after t1 on any backend
    t1 = Task(lambda: 20)
    t2 = Task(lambda x: x + 1, takes_inputs=True)
    t2.succeed(t1)
    assert ex.run([t1, t2]).result(30) is None
    assert t2.result == 21


def test_submit_alias(ex):
    assert ex.submit(lambda: "ok").result(30) == "ok"


def test_run_failure_delivered_through_future(ex):
    with pytest.raises(ValueError, match="boom"):
        ex.run(lambda: (_ for _ in ()).throw(ValueError("boom"))).result(30)
    # the backend stays healthy afterwards
    assert ex.run(lambda: "still alive").result(30) == "still alive"


def test_failure_propagates_along_dataflow_edges(ex):
    g = TaskGraph()
    bad = g.add(lambda: (_ for _ in ()).throw(RuntimeError("upstream died")))
    down = g.then(bad, lambda x: x)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(RuntimeError, match="upstream died"):
        ex.run(g).result(30)
    assert isinstance(down.exception, RuntimeError)  # adopted, body skipped


def test_wait_idle_after_work(ex):
    ex.run(lambda: 1).result(30)
    assert ex.wait_idle(30) is True


def test_lifecycle_close_is_idempotent_and_final():
    """Every backend constructs, serves, closes — and a second close is a
    no-op. (The one test here that owns its executors: lifecycle IS the
    thing under test, so the fixture cannot provide it.)"""
    for backend in BACKENDS:
        e = Executor(2, backend=backend)
        try:
            assert e.run(lambda: backend).result(30) == backend
        finally:
            e.close()
        e.close()  # idempotent
        assert e.pool._stop


def test_prewired_single_task_runs(ex):
    """Submitting one pre-wired (non-source) Task runs exactly that task,
    as ThreadPool._schedule does — no backend may reject it as a
    sourceless graph."""
    t1 = Task(lambda: "unrun")
    t2 = Task(lambda x: (x, "ran"), takes_inputs=True)
    t2.succeed(t1)
    t2.propagate_errors = False
    assert ex.run(t2).result(30) == (None, "ran")  # t1 never ran: slot is None


# ---------------------------------------------------------------------------
# priorities
# ---------------------------------------------------------------------------


def test_run_graph_priority_overrides_non_explicit_bands(ex):
    """run(graph, priority=) follows the ThreadPool.submit contract: every
    task without an explicit band is promoted, explicit bands win.
    (Serial ignores bands at runtime but records them identically.)"""
    g = TaskGraph()
    a = g.add(lambda: None)
    b = a.then(lambda _x: None)
    c = g.add(lambda: None, priority=-2.0)
    ex.run(g, priority=3.0).result(30)
    assert a.priority == b.priority == 3.0
    assert c.priority == -2.0


def test_subflow_priority_inherited_from_spawner(ex):
    g = TaskGraph()
    captured = []

    def spawn(rt):  # spawner bodies always run scheduler-side
        captured.append(rt.add(lambda: None).priority)
        captured.append(rt.add(lambda: None, priority=-1.0).priority)

    g.add(spawn, takes_runtime=True, priority=2.5)
    ex.run(g).result(30)
    assert captured == [2.5, -1.0]


# ---------------------------------------------------------------------------
# condition tasks: branching + weak cycles
# ---------------------------------------------------------------------------


def test_condition_selects_single_branch(ex):
    g = TaskGraph("branch")
    src = g.add(lambda: None, name="src")
    pick = g.add(lambda: 1, kind="condition", name="pick")
    pick.after(src)
    left = g.add(lambda: "L", name="left")
    right = g.add(lambda: "R", name="right")
    pick.precede(left, right)  # branch order = wiring order
    assert ex.run(g).result(30) is None
    # every member of a condition graph re-arms after running (clearing
    # `started` for the next pass), so assert on results — rearm keeps them
    assert right.result == "R"
    assert left.result is None  # branch not taken


def test_branch_not_taken_resets_cleanly_across_runs(ex):
    """Un-run branches leave no residue: across run_count > 1 each run
    releases exactly the branch its condition names."""
    sel = {"v": 0}
    g = TaskGraph()
    pick = g.add(lambda: sel["v"], kind="condition")  # conditions run in-parent
    a = g.add(lambda: "a")
    b = g.add(lambda: "b")
    pick.precede(a, b)
    taken = []
    for v in (0, 1, 0):
        sel["v"] = v
        if taken:
            g.reset()
        assert ex.run(g).result(30) is None
        assert (a.result is None) != (b.result is None)  # exactly one branch ran
        taken.append(a.result or b.result)
    assert taken == ["a", "b", "a"]
    assert g.run_count == 3


def test_condition_out_of_range_ends_run(ex):
    """A non-int / out-of-range return selects nothing — the loop's exit."""
    g = TaskGraph()
    c = g.add(lambda: 99, kind="condition")
    dead = g.add(lambda: "never")
    c.precede(dead)
    assert ex.run(g).result(30) is None
    assert dead.result is None  # branch never released


def test_condition_loop_bounded_iteration(ex):
    g, state = _build_loop(7)
    assert ex.run(g).result(30) is None
    assert state["runs"] == 7


def test_condition_loop_rerunnable(ex):
    g, state = _build_loop(4)
    for expect in (4, 8, 12):
        ex.run(g).result(30)
        assert state["runs"] == expect
        g.reset()
    assert g.run_count == 3


def test_condition_loop_failure_resolves_future(ex):
    boom = {"at": 3, "i": 0}
    g = TaskGraph()
    entry = g.add(lambda: boom.update(i=0), name="entry", affinity="local")

    # pass counting and the triggered failure stay scheduler-side
    # (affinity="local"): the loop machinery under test is identical on
    # every backend, and the counter must be authoritative
    def body():
        boom["i"] += 1
        if boom["i"] == boom["at"]:
            raise ValueError("pass 3 failed")

    bt = g.add(body, name="body", affinity="local")
    bt.after(entry)
    # the condition consumes the body's value edge, so a body failure
    # propagates into it (skip + adopt) and the loop stops that pass
    cond = g.add(
        lambda _x: 0 if boom["i"] < 10 else 1, kind="condition", takes_inputs=True
    )
    cond.succeed(bt)
    cond.precede(bt)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(ValueError, match="pass 3"):
        ex.run(g).result(30)
    assert boom["i"] == 3  # the loop stopped at the failing pass


# ---------------------------------------------------------------------------
# counted completion
# ---------------------------------------------------------------------------


def test_counted_completion_resolves_exactly_at_quiescence(ex):
    """Condition graphs complete by counted quiescence (§10), not by the
    hidden-sink protocol: the run future resolves only after the final
    pass, and the pool is immediately idle when it does."""
    g, state = _build_loop(5)
    fut = ex.run(g)
    assert fut.result(30) is None
    assert state["runs"] == 5  # resolved exactly at the last pass
    assert ex.wait_idle(10) is True  # nothing still in flight behind it


def test_counted_completion_branch_not_taken_is_not_awaited(ex):
    """The counted protocol must not wait for branches the condition
    never released — a not-taken branch would otherwise hang the run."""
    g = TaskGraph()
    c = g.add(lambda: 0, kind="condition")
    taken = g.add(lambda: "yes")
    skipped = g.add(lambda: "no")
    c.precede(taken, skipped)
    assert ex.run(g).result(30) is None
    assert taken.result == "yes" and skipped.result is None


# ---------------------------------------------------------------------------
# dynamic subflows
# ---------------------------------------------------------------------------


def test_subflow_join_before_successor(ex):
    """Every runtime-spawned task completes before the spawner's successor
    runs, and the gather's result is visible through the spawner."""
    g = TaskGraph()

    def spawn(rt):
        ws = [rt.add(lambda i=i: i * i, name=f"w{i}") for i in range(8)]
        return rt.gather(ws)

    sp = g.add(spawn, takes_runtime=True, name="spawn")
    # the spawner's dataflow value is the gather's result (join unwraps it)
    done = g.then(sp, lambda vals: sorted(vals))
    assert ex.run(g).result(30) is None
    assert done.result == [i * i for i in range(8)]
    assert all(w.done for w in sp._spawned)  # joined before the successor


def test_subflow_sized_by_runtime_data(ex):
    """The fan-out width comes from data the task sees at execution time."""
    g = TaskGraph()
    width = g.add(lambda: 5, name="width")

    def spawn(rt, n):
        return rt.gather([rt.add(lambda i=i: i, name=f"s{i}") for i in range(n)])

    sp = g.add(spawn, takes_inputs=True, takes_runtime=True, name="spawn")
    sp.succeed(width)
    total = g.then(sp, sum)
    assert ex.run(g).result(30) is None
    assert total.result == sum(range(5))
    assert len(sp._spawned) == 6  # 5 workers + gather


def test_subflow_failure_propagates_to_future(ex):
    g = TaskGraph()

    def spawn(rt):
        rt.add(lambda: None)
        rt.add(lambda: (_ for _ in ()).throw(RuntimeError("shard died")))

    sp = g.add(spawn, takes_runtime=True)
    g.then(sp, lambda _gt: None)
    for t in g.tasks:
        t.propagate_errors = False
    with pytest.raises(RuntimeError, match="shard died"):
        ex.run(g).result(30)
    assert isinstance(sp.exception, RuntimeError)  # adopted by the spawner
    ex.wait_idle(30)  # pool not poisoned


def test_nested_subflow_spawner(ex):
    """A spawned task may itself be a takes_runtime spawner; the outer
    successor still waits for the innermost join."""
    g = TaskGraph()

    def outer_spawn(rt):
        def inner_spawn(rt2):
            return rt2.gather([rt2.add(lambda i=i: ("inner", i)) for i in range(3)])

        return rt.add(inner_spawn, takes_runtime=True, name="inner")

    sp = g.add(outer_spawn, takes_runtime=True, name="outer")
    after = g.then(sp, lambda inner_vals: sorted(inner_vals))
    assert ex.run(g).result(30) is None
    assert after.result == [("inner", i) for i in range(3)]


# ---------------------------------------------------------------------------
# §12 replay parity
# ---------------------------------------------------------------------------


def test_replay_parity_across_passes(ex):
    """Pass 1 runs live, later passes replay (where the backend compiles
    plans): results must be identical in every pass, the plan must stay
    un-diverged, and plan availability must match the backend contract
    (every ThreadPool-derived backend compiles; serial never does)."""
    g = TaskGraph("chain")
    a = g.add(lambda: 2, name="a")
    b = g.then(a, lambda v: v + 3, name="b")
    c = g.then(b, lambda v: v * 10, name="c")
    results = []
    for _ in range(4):
        ex.run(g).result(30)
        results.append((a.result, b.result, c.result))
    assert results == [(2, 5, 50)] * 4
    assert (g.replay_plan is not None) == (ex.backend != "serial")
    if g.replay_plan is not None:
        assert not g.replay_plan.diverged


def test_replay_parity_with_condition_loop(ex):
    """Counted (condition) graphs run replay-armed passes too: the loop
    executes the same number of body passes every round."""
    g, state = _build_loop(3)
    for expect in (3, 6, 9):
        ex.run(g).result(30)
        assert state["runs"] == expect
        g.reset()


# ---------------------------------------------------------------------------
# §14: retry / timeout / at-most-once — the backend-uniform contract
# ---------------------------------------------------------------------------


def test_retry_to_success(ex):
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise ValueError(f"transient {len(calls)}")
        return 42

    t = Task(flaky, name="flaky", affinity="local",
             retry=RetryPolicy(max_attempts=5, backoff=0.001))
    t.propagate_errors = False
    assert ex.run(t).result(30) == 42
    assert ex.stats()["retries"] == 2


def test_cooperative_timeout(ex):
    def body():
        for _ in range(200):
            time.sleep(0.005)
            checkpoint()

    t = Task(body, name="deadline", affinity="local", timeout=0.05)
    t.propagate_errors = False
    with pytest.raises(TaskTimeoutError, match="deadline"):
        ex.run(t).result(30)
    assert ex.stats()["timeouts"] == 1


def test_at_most_once_gate_for_started_losses(ex):
    """The §14 gate is scheduler-side and must hold on every backend: a
    ``WorkerDiedError(started=True)`` is never retried for a
    non-idempotent task — even under a matching policy — and is retried
    normally once the task declares ``idempotent=True``."""
    calls = []

    def started_loss():
        calls.append(1)
        raise WorkerDiedError("synthetic started transport loss", started=True)

    pol = RetryPolicy(max_attempts=3, backoff=0, retry_on=WorkerDiedError)
    t = Task(started_loss, name="amo", affinity="local", retry=pol)
    t.propagate_errors = False
    with pytest.raises(WorkerDiedError):
        ex.run(t).result(30)
    assert len(calls) == 1  # started=True + non-idempotent: no retry

    calls.clear()
    t2 = Task(started_loss, name="amo-idem", affinity="local", retry=pol,
              idempotent=True)
    t2.propagate_errors = False
    with pytest.raises(WorkerDiedError):
        ex.run(t2).result(30)
    assert len(calls) == 3  # idempotent: policy runs to exhaustion


def test_pre_start_losses_always_retryable(ex):
    """``started=False`` transport losses are safe on any backend: the
    body never ran, so a matching policy retries regardless of
    idempotency."""
    calls = []

    def prestart_loss():
        calls.append(1)
        if len(calls) < 2:
            raise WorkerDiedError("synthetic pre-start loss", started=False)
        return "delivered"

    t = Task(prestart_loss, name="prestart", affinity="local",
             retry=RetryPolicy(max_attempts=3, backoff=0, retry_on=WorkerDiedError))
    t.propagate_errors = False
    assert ex.run(t).result(30) == "delivered"
    assert len(calls) == 2


# ---------------------------------------------------------------------------
# §8 observer accounting
# ---------------------------------------------------------------------------


class _CountingObserver:
    """Thread-safe §8 observer counting scheduler events."""

    def __init__(self):
        self._lock = threading.Lock()
        self.submits = 0
        self.starts = 0
        self.finishes = 0
        self.retries = 0
        self.timeouts = 0

    def _bump(self, field):
        with self._lock:
            setattr(self, field, getattr(self, field) + 1)

    def on_submit(self, task):
        self._bump("submits")

    def on_start(self, task, worker):
        self._bump("starts")

    def on_finish(self, task, worker):
        self._bump("finishes")

    def on_steal(self, task, thief, victim):  # pragma: no cover - not compared
        pass

    def on_retry(self, task, attempt, worker):
        self._bump("retries")

    def on_timeout(self, task, worker):
        self._bump("timeouts")


def _observed_graph():
    g = TaskGraph("observed")
    layer = [g.add(lambda i=i: i, name=f"t{i}") for i in range(6)]
    g.gather(layer, name="sink")
    return g


def test_observer_counts_balanced(ex):
    obs = _CountingObserver()
    ex.add_observer(obs)
    try:
        ex.run(_observed_graph()).result(30)
        ex.wait_idle(30)
    finally:
        ex.remove_observer(obs)
    assert obs.starts == obs.finishes >= 7  # 6 tasks + gather (+ bookkeeping)
    # on_submit is a queue-push event: inline continuations skip it and
    # the serial baseline has no queue, so the portable invariant is a
    # bound, not equality — every queued task is eventually started
    assert obs.submits <= obs.starts
    assert obs.retries == obs.timeouts == 0


def test_observer_counts_identical_across_backends():
    """The same graph produces the same §8 *execution* ledger on every
    backend — offloading bodies must not add, drop or double any start,
    finish, retry or timeout event. (Submit counts are queue events and
    legitimately interleaving-dependent: inline continuations never
    queue, so they are excluded from the cross-backend comparison.)"""
    ledgers = {}
    for backend in BACKENDS:
        obs = _CountingObserver()
        with Executor(2, backend=backend) as e:
            e.add_observer(obs)
            e.run(_observed_graph()).result(30)
            e.wait_idle(30)
        ledgers[backend] = (obs.starts, obs.finishes, obs.retries, obs.timeouts)
    assert len(set(ledgers.values())) == 1, ledgers


# ---------------------------------------------------------------------------
# run_until + asyncio bridge
# ---------------------------------------------------------------------------


def test_run_until_reruns_to_convergence(ex):
    # convergence state is carried by the task's own result: the predicate
    # reads parent-side task state, valid on every backend
    state = {"x": 100.0}
    g = TaskGraph()

    def halve():
        state["x"] /= 2
        return state["x"]

    t = g.add(halve, affinity="local")  # caller-side loop, caller-side state
    rounds = ex.run_until(g, lambda: t.result < 1.0)
    assert rounds == 7  # 100 / 2^7 < 1
    assert g.run_count == 7


def test_run_until_max_rounds(ex):
    g = TaskGraph()
    g.add(lambda: None)
    with pytest.raises(RuntimeError, match="still false"):
        ex.run_until(g, lambda: False, max_rounds=3)
    assert g.run_count == 3


def test_await_future_from_asyncio(ex):
    async def main():
        return await ex.run(lambda: 6 * 7)

    assert asyncio.run(main()) == 42


def test_await_future_already_resolved(ex):
    fut = ex.run(lambda: "early")
    fut.result(30)

    async def main():
        return await fut

    assert asyncio.run(main()) == "early"


def test_await_future_delivers_exception(ex):
    async def main():
        await ex.run(lambda: (_ for _ in ()).throw(ValueError("async boom")))

    with pytest.raises(ValueError, match="async boom"):
        asyncio.run(main())


def test_co_run_graph_with_condition_loop(ex):
    g, state = _build_loop(5)

    async def main():
        await ex.co_run(g)
        return state["runs"]

    assert asyncio.run(main()) == 5


def test_co_run_concurrent_awaits(ex):
    """Several co_run awaitables progress concurrently on one loop."""

    async def main():
        futs = [ex.co_run(lambda i=i: i * 10) for i in range(5)]
        return await asyncio.gather(*futs)

    assert asyncio.run(main()) == [0, 10, 20, 30, 40]
