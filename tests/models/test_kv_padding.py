"""Zero-padded KV heads (beyond-paper TP optimization) must be EXACT:
same logits as the unpadded model, zero pads preserved by a train step."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def cfgs():
    base = dict(
        name="padtest", family="dense", num_layers=2, d_model=64,
        num_heads=6, num_kv_heads=2, d_ff=128, vocab_size=128,
        dtype="float32", remat="none", qkv_bias=True,
    )
    return ModelConfig(**base), ModelConfig(**base, kv_pad_to=4)


def _copy_real_into_padded(p_ref, p_pad):
    """Copy unpadded weights into the padded tree (pads stay zero)."""

    def one(ref, pad):
        if ref.shape == pad.shape:
            return ref
        out = jnp.zeros_like(pad)
        sl = tuple(slice(0, s) for s in ref.shape)
        return out.at[sl].set(ref)

    return jax.tree.map(one, p_ref, jax.tree.map(jnp.zeros_like, p_pad))


def test_padded_model_matches_unpadded_exactly():
    cfg, cfg_pad = cfgs()
    m, mp = build_model(cfg), build_model(cfg_pad)
    params = m.init(jax.random.PRNGKey(0))
    params_pad = _copy_real_into_padded(params, mp.init(jax.random.PRNGKey(1)))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    l0, _ = jax.jit(m.loss)(params, batch)
    l1, _ = jax.jit(mp.loss)(params_pad, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-6)
    # prefill logits identical too
    g0, _ = jax.jit(m.prefill)(params, {"tokens": tokens})
    g1, _ = jax.jit(mp.prefill)(params_pad, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(g0), np.asarray(g1), atol=1e-5)


def test_zero_pads_stay_zero_after_train_step():
    _, cfg_pad = cfgs()
    mp = build_model(cfg_pad)
    params = mp.init(jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-2, weight_decay=0.1)
    opt = adamw_init(ocfg, params)
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg_pad.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}

    @jax.jit
    def step(params, opt):
        (_, _), grads = jax.value_and_grad(
            lambda p: mp.loss(p, batch), has_aux=True
        )(params)
        p2, o2, _ = adamw_update(ocfg, jnp.asarray(1e-2), params, grads, opt)
        return p2, o2

    for _ in range(3):
        params, opt = step(params, opt)

    KV, KVp = cfg_pad.num_kv_heads, cfg_pad.kv_heads_padded
    H, Hp = cfg_pad.num_heads, cfg_pad.heads_padded
    for grp in ("s0",):
        attn = params["layers"][grp]["attn"]
        assert attn["wq"].shape[-2] == Hp and attn["wk"].shape[-2] == KVp
        np.testing.assert_array_equal(np.asarray(attn["wq"][..., H:, :]), 0.0)
        np.testing.assert_array_equal(np.asarray(attn["wk"][..., KV:, :]), 0.0)
        np.testing.assert_array_equal(np.asarray(attn["wv"][..., KV:, :]), 0.0)
        np.testing.assert_array_equal(np.asarray(attn["wo"][..., H:, :, :]), 0.0)
