"""Per-architecture smoke tests (assignment requirement).

Each assigned arch instantiates its REDUCED config (same family/topology,
small dims) and runs one forward + one train step on CPU, asserting output
shapes and the absence of NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_NAMES, get_config, get_reduced, param_count
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_batch(cfg, key, B=2, S=16):
    S_text = S - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    tokens = jax.random.randint(key, (B, S_text), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((B, cfg.num_image_tokens, cfg.vision_dim), jnp.bfloat16)
    if cfg.is_encdec:
        batch["frames"] = jnp.ones((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(params, batch)
        new_params, new_opt, _ = adamw_update(ocfg, jnp.asarray(1e-3), params, grads, opt_state)
        return new_params, new_opt, loss, metrics

    ocfg = AdamWConfig(lr=1e-3)
    opt_state = adamw_init(ocfg, params)
    params2, opt2, loss, metrics = train_step(params, opt_state, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    assert float(metrics["tokens"]) > 0
    # params actually changed and stayed finite
    changed = jax.tree.map(lambda a, b: jnp.any(a != b), params, params2)
    assert any(bool(x) for x in jax.tree.leaves(changed)), f"{arch}: no param updated"
    for leaf in jax.tree.leaves(params2):
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32))), f"{arch}: NaN in params"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_reduced_prefill_decode_shapes(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = make_batch(cfg, jax.random.PRNGKey(1), B, S)
    batch.pop("targets")
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    from repro.models.lm import extend_caches

    caches = extend_caches(caches, 2)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, caches2 = jax.jit(model.decode_step)(
        params, tok, caches, jnp.array(S, jnp.int32)
    )
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
    }[arch]
    cfg = get_config(arch)
    L, d, H, KV, ff, V = spec
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == H and cfg.num_kv_heads == KV
    ff_actual = cfg.moe_d_ff if cfg.is_moe else cfg.d_ff
    assert ff_actual == ff
    assert cfg.vocab_size == V


def test_param_counts_in_expected_range():
    """Analytic counts should land near the advertised model sizes."""
    expect = {
        "deepseek-coder-33b": (30e9, 36e9),
        "phi4-mini-3.8b": (3.0e9, 4.6e9),
        "tinyllama-1.1b": (0.9e9, 1.3e9),
        "qwen1.5-4b": (3.0e9, 5.0e9),
        "hymba-1.5b": (1.0e9, 2.0e9),
        "whisper-medium": (0.6e9, 1.0e9),
        "paligemma-3b": (2.0e9, 3.5e9),  # backbone only (frontend stubbed)
        "granite-moe-1b-a400m": (0.8e9, 1.6e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))["total"]
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B outside [{lo / 1e9}, {hi / 1e9}]"


def test_shape_suite_skip_rules():
    """long_500k only for sub-quadratic archs (mamba2, hymba)."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        shapes = cfg.shapes()
        if arch in ("mamba2-1.3b", "hymba-1.5b"):
            assert "long_500k" in shapes
        else:
            assert "long_500k" not in shapes
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
