"""§15 Executor(verify=) integration: modes, caching, consumers stay clean."""
import warnings

import pytest

import repro.analysis.verify as verify_mod
from repro.analysis.verify import GraphVerificationError, verify_graph
from repro.core import Executor, TaskGraph


def racy_graph(name="racy"):
    g = TaskGraph(name)
    total = 0

    def wa():
        nonlocal total
        total += 1

    def wb():
        nonlocal total
        total += 2

    g.add(wa, name="wa")
    g.add(wb, name="wb")
    return g


def clean_graph(name="clean"):
    g = TaskGraph(name)
    a = g.add(lambda: 21, name="a")
    g.then(a, lambda x: x * 2, name="b")
    return g


# -- verify_graph facade -------------------------------------------------------


def test_verify_graph_report_shape():
    rep = verify_graph(clean_graph())
    assert rep.ok and rep.errors == [] and "verified clean" in str(rep)
    bad = verify_graph(racy_graph())
    assert not bad.ok and bad.errors
    with pytest.raises(GraphVerificationError) as exc:
        bad.raise_if_errors()
    assert exc.value.report is bad
    assert "shared-state-race" in str(exc.value)


# -- executor modes ------------------------------------------------------------


def test_strict_raises_before_any_task_runs():
    ran = []
    g = racy_graph()
    g.add(lambda: ran.append(1), name="probe")
    with Executor(2, verify="strict") as ex:
        with pytest.raises(GraphVerificationError):
            ex.run(g)
    assert ran == []  # the graph never reached the pool


def test_warn_mode_warns_but_runs():
    g = racy_graph()
    with Executor(2, verify="warn") as ex:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ex.run(g).result(10)
    assert any("shared-state-race" in str(w.message) for w in caught)


def test_off_is_default_and_silent():
    g = racy_graph()
    with Executor(2) as ex:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            ex.run(g).result(10)
    assert caught == []


def test_unknown_mode_rejected():
    with pytest.raises(ValueError, match="verify"):
        Executor(1, verify="loud")


def test_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_VERIFY", "strict")
    with Executor(2) as ex:
        with pytest.raises(GraphVerificationError):
            ex.run(racy_graph())
    monkeypatch.setenv("REPRO_VERIFY", "off")
    with Executor(2) as ex:  # env only sets the default; explicit arg wins
        ex.run(clean_graph()).result(10)


def test_strict_green_on_clean_graph_and_result_flows():
    g = clean_graph()
    with Executor(2, verify="strict") as ex:
        ex.run(g).result(10)
    assert g.tasks[1].result == 42


# -- epoch caching -------------------------------------------------------------


def test_verification_cached_per_structure(monkeypatch):
    calls = []
    real = verify_mod.verify_graph

    def counting(graph, **kw):
        calls.append(graph.name)
        return real(graph, **kw)

    monkeypatch.setattr(verify_mod, "verify_graph", counting)
    g = clean_graph("cached")
    with Executor(2, verify="warn") as ex:
        ex.run(g).result(10)
        ex.run(g).result(10)  # same structure: cached, no second pass
        assert calls == ["cached"]
        g.then(g.tasks[-1], lambda x: x, name="c")  # structural change bumps epoch
        ex.run(g).result(10)
        assert calls == ["cached", "cached"]


def test_strict_failure_not_cached(monkeypatch):
    g = racy_graph()
    with Executor(2, verify="strict") as ex:
        with pytest.raises(GraphVerificationError):
            ex.run(g)
        with pytest.raises(GraphVerificationError):
            ex.run(g)  # unchanged broken graph re-raises, not silently cached


# -- shipped consumers stay clean under strict ---------------------------------


def test_prefetcher_lane_graphs_verify_strict():
    from repro.data.pipeline import Prefetcher

    class Src:
        def batch(self, step):
            return {"x": step}

    pf = Prefetcher(Src(), backend="serial", depth=2)
    try:
        for lane in pf._lanes:
            verify_graph(lane.graph).raise_if_errors()
    finally:
        pf.close()


def test_checkpoint_template_graph_verifies_strict(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep=2)
    try:
        mgr.save_async(1, {"w": [1.0, 2.0]})
        mgr.wait()
        verify_graph(mgr._tpl_graph).raise_if_errors()
    finally:
        mgr.close()
