"""§15 schedule fuzzer: result identity across seeded interleavings."""
import pytest

from repro.analysis.fuzz import _corpus, fuzz_schedules, main
from repro.core import TaskGraph


def test_corpus_graphs_are_schedule_independent():
    for graph, reset in _corpus():
        report = fuzz_schedules(graph, schedules=6, reset=reset)
        assert report.ok, str(report)


def test_schedule_dependent_race_is_flagged():
    # two unordered writers to one slot + a reader below the join: the
    # reader's value depends purely on which writer the schedule ran last
    g = TaskGraph("last-writer-wins")
    slot = {}

    def wa():
        slot["x"] = 1

    def wb():
        slot["x"] = 2

    a = g.add(wa, name="wa")
    b = g.add(wb, name="wb")
    g.gather([a, b], fn=lambda *_: slot["x"], name="read")
    report = fuzz_schedules(g, schedules=8, reset=slot.clear)
    assert not report.ok
    assert report.rerun_deterministic  # same schedule twice agrees...
    assert {f.rule for f in report.findings} == {"schedule-dependent-result"}
    found = [f for f in report.findings if "read" in f.tasks]
    assert found and "depends on execution order" in found[0].message


def test_rerun_nondeterminism_is_separated_from_schedule_dependence():
    g = TaskGraph("stateful")
    state = {"n": 0}

    def bump():
        state["n"] += 1
        return state["n"]

    g.add(bump, name="bump")
    report = fuzz_schedules(g, schedules=8)  # no reset: state leaks across runs
    assert not report.ok and not report.rerun_deterministic
    (f,) = report.findings
    assert f.rule == "rerun-nondeterministic" and "reset=" in f.message
    # with the reset hook the same graph fuzzes clean
    assert fuzz_schedules(g, schedules=8, reset=lambda: state.update(n=0)).ok


def test_exceptions_fingerprint_stably():
    g = TaskGraph("boom")

    def blow():
        raise ValueError("expected")

    g.add(blow, name="blow")
    report = fuzz_schedules(g, schedules=4)
    assert report.ok  # deterministic failure is still schedule-independent
    assert report.baseline["blow"] == ("exception", "ValueError", "expected")


def test_non_terminating_loop_hits_step_limit():
    g = TaskGraph("forever")
    entry = g.add(None, name="entry")
    body = g.add(lambda: 1, name="body")
    body.after(entry)
    c = g.add(lambda: 0, kind="condition", name="again")
    c.after(body)
    c.precede(body)
    with pytest.raises(RuntimeError, match="weak-loop-no-exit"):
        fuzz_schedules(g, schedules=2)


def test_graph_left_reusable_after_fuzzing():
    from repro.core import Executor

    g = TaskGraph("reuse")
    a = g.add(lambda: 21, name="a")
    g.then(a, lambda x: x * 2, name="b")
    assert fuzz_schedules(g, schedules=4).ok
    with Executor(2) as ex:
        ex.run(g).result(10)
    assert g.tasks[1].result == 42


def test_cli_quick_exits_zero(capsys):
    assert main(["--quick"]) == 0
    err = capsys.readouterr().err
    assert "fuzz[fuzz-diamond]" in err and "ok" in err
