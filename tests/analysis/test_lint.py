"""§15 graph linter: one true-positive and one clean-pass per rule."""
import pytest

from repro.analysis.lint import RULES, Finding, lint_graph, rule_catalog
from repro.core import RetryPolicy, TaskGraph


def rules_of(findings):
    return {f.rule for f in findings}


def lint(g, **kw):
    kw.setdefault("races", False)
    return lint_graph(g, **kw)


# -- strong-cycle --------------------------------------------------------------


def test_strong_cycle_true_positive_names_path():
    g = TaskGraph("cyc")
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    b.succeed(a)
    a.succeed(b)
    (f,) = [f for f in lint(g) if f.rule == "strong-cycle"]
    assert f.severity == "error"
    assert "a -> b -> a" in f.message
    assert f.tasks == ("a", "b")


def test_strong_cycle_clean_on_weak_loop():
    g = TaskGraph("loop")
    entry = g.add(None, name="entry")
    body = g.add(lambda: None, name="body")
    body.after(entry)
    cond = g.add(lambda: 2, kind="condition", name="more")
    cond.after(body)
    cond.precede(body)
    assert "strong-cycle" not in rules_of(lint(g))


# -- unreachable-task ----------------------------------------------------------


def test_unreachable_true_positive_external_predecessor():
    other = TaskGraph("other")
    ext = other.add(lambda: None, name="ext")
    g = TaskGraph("main")
    t = g.add(lambda: None, name="dangling")
    t.after(ext)  # strong pred lives in a different graph
    found = [f for f in lint(g) if f.rule == "unreachable-task"]
    assert found and "outside this graph" in found[0].message


def test_unreachable_clean_and_not_duplicated_for_cycles():
    g = TaskGraph("ok")
    a = g.add(lambda: None, name="a")
    g.then(a, lambda x: x, name="b")
    assert "unreachable-task" not in rules_of(lint(g))
    # cycle members are the strong-cycle rule's report, not this rule's
    g2 = TaskGraph("cyc")
    x = g2.add(lambda: None, name="x")
    y = g2.add(lambda: None, name="y")
    x.succeed(y)
    y.succeed(x)
    assert "unreachable-task" not in rules_of(lint(g2))


# -- orphan-task ---------------------------------------------------------------


def test_orphan_true_positive():
    g = TaskGraph("orphan")
    g.add(lambda: 1, name="real")
    g.add(None, name="placeholder")
    (f,) = [f for f in lint(g) if f.rule == "orphan-task"]
    assert f.severity == "warning" and f.tasks == ("placeholder",)


def test_orphan_clean_when_wired_or_alone():
    g = TaskGraph("wired")
    entry = g.add(None, name="entry")
    body = g.add(lambda: 1, name="body")
    body.after(entry)
    assert "orphan-task" not in rules_of(lint(g))
    solo = TaskGraph("solo")
    solo.add(None, name="only")
    assert "orphan-task" not in rules_of(lint(solo))


# -- condition-branch-range ----------------------------------------------------


def test_branch_range_error_when_no_return_selects():
    g = TaskGraph("condbad")
    entry = g.add(None, name="entry")
    c = g.add(lambda: 7, kind="condition", name="pick")
    c.after(entry)
    c.precede(g.add(lambda: 1, name="tgt"))
    (f,) = [f for f in lint(g) if f.rule == "condition-branch-range"]
    assert f.severity == "error" and "[7]" in f.message


def test_branch_range_warns_out_of_cycle_only():
    # outside a cycle, a sometimes-out-of-range constant is a warning
    g = TaskGraph("maybe")
    entry = g.add(None, name="entry")
    c = g.add(lambda x=0: 0 if x else 3, kind="condition", name="pick")
    c.after(entry)
    c.precede(g.add(lambda: 1, name="tgt"))
    (f,) = [f for f in lint(g) if f.rule == "condition-branch-range"]
    assert f.severity == "warning" and "[3]" in f.message
    # inside a cycle the same shape is the loop-exit idiom: clean
    g2 = TaskGraph("loop")
    entry2 = g2.add(None, name="entry")
    body = g2.add(lambda: 1, name="body")
    body.after(entry2)
    c2 = g2.add(lambda x=0: 0 if x else 3, kind="condition", name="more")
    c2.after(body)
    c2.precede(body)
    assert "condition-branch-range" not in rules_of(lint(g2))


def test_branch_range_flags_condition_without_successors():
    g = TaskGraph("nosucc")
    entry = g.add(None, name="entry")
    c = g.add(lambda: 0, kind="condition", name="lonely")
    c.after(entry)
    found = [f for f in lint(g) if f.rule == "condition-branch-range"]
    assert found and "no successors" in found[0].message


def test_branch_range_declines_dynamic_bodies():
    g = TaskGraph("dyn")
    entry = g.add(None, name="entry")

    def decide():
        import os

        return len(os.getcwd()) % 2

    c = g.add(decide, kind="condition", name="pick")
    c.after(entry)
    c.precede(g.add(lambda: 1, name="tgt"))
    assert "condition-branch-range" not in rules_of(lint(g))


# -- weak-loop-no-exit ---------------------------------------------------------


def test_weak_loop_no_exit_true_positive():
    g = TaskGraph("noexit")
    entry = g.add(None, name="entry")
    body = g.add(lambda: 1, name="body")
    body.after(entry)
    c = g.add(lambda: 0, kind="condition", name="again")
    c.after(body)
    c.precede(body)
    (f,) = [f for f in lint(g) if f.rule == "weak-loop-no-exit"]
    assert f.severity == "error" and "body" in f.tasks and "again" in f.tasks


def test_weak_loop_clean_with_reachable_exit():
    g = TaskGraph("exit")
    entry = g.add(None, name="entry")
    body = g.add(lambda: 1, name="body")
    body.after(entry)
    state = {"n": 0}

    def more():
        state["n"] += 1
        return 0 if state["n"] < 3 else 9  # 9 selects nothing: the loop drains

    c = g.add(more, kind="condition", name="more")
    c.after(body)
    c.precede(body)
    assert "weak-loop-no-exit" not in rules_of(lint(g))


# -- priority-inversion --------------------------------------------------------


def test_priority_inversion_true_positive():
    g = TaskGraph("inv")
    low = g.add(lambda: 1, name="low", priority=0.0)
    high = g.add(lambda: 2, name="high", priority=5.0)
    high.succeed(low)
    (f,) = [f for f in lint(g) if f.rule == "priority-inversion"]
    assert f.severity == "warning" and f.tasks == ("low", "high")


def test_priority_inversion_clean_on_weak_edges_and_equal_bands():
    g = TaskGraph("ok")
    entry = g.add(None, name="entry", priority=5.0)
    tick = g.add(lambda: 1, name="tick", priority=5.0)
    tick.after(entry)
    c = g.add(lambda: 2, kind="condition", name="more", priority=5.0)
    c.after(tick)
    c.precede(tick)  # weak edges never count, whatever the bands
    assert "priority-inversion" not in rules_of(lint(g))


# -- retry-non-idempotent ------------------------------------------------------


@pytest.mark.parametrize("backend", ["serial", "thread", "process"])
def test_retry_non_idempotent_fires_only_where_offload_is_possible(backend):
    g = TaskGraph("retry")
    g.add(lambda: 1, name="flaky", retry=RetryPolicy(max_attempts=3))
    fired = "retry-non-idempotent" in rules_of(lint(g, backend=backend))
    assert fired == (backend == "process")


def test_retry_non_idempotent_remote_fires_without_backend_context():
    g = TaskGraph("retry-remote")
    g.add(
        lambda: 1, name="flaky", affinity="remote", retry=RetryPolicy(max_attempts=3)
    )
    (f,) = [f for f in lint(g) if f.rule == "retry-non-idempotent"]
    assert "at-most-once" in f.message


@pytest.mark.parametrize("backend", ["serial", "thread", "process"])
def test_retry_clean_when_idempotent_or_local(backend):
    g = TaskGraph("ok")
    g.add(
        lambda: 1,
        name="safe",
        retry=RetryPolicy(max_attempts=3),
        idempotent=True,
        affinity="remote",
    )
    g.add(
        lambda: 2, name="pinned", retry=RetryPolicy(max_attempts=3), affinity="local"
    )
    assert "retry-non-idempotent" not in rules_of(lint(g, backend=backend))


# -- remote-unpicklable --------------------------------------------------------


def test_remote_unpicklable_true_positive():
    import threading

    lock = threading.Lock()
    g = TaskGraph("wire")
    g.add(lambda: lock.acquire(False), name="locked", affinity="remote")
    (f,) = [f for f in lint(g) if f.rule == "remote-unpicklable"]
    assert f.severity == "error" and "locked" in f.tasks


def test_remote_unpicklable_clean_for_wireable_bodies():
    g = TaskGraph("wire-ok")
    g.add(lambda: 40 + 2, name="pure", affinity="remote")
    assert "remote-unpicklable" not in rules_of(lint(g))


# -- affinity-ignored ----------------------------------------------------------


def test_affinity_ignored_true_positive_on_condition():
    g = TaskGraph("aff")
    entry = g.add(None, name="entry")
    c = g.add(lambda: 0, kind="condition", name="pick", affinity="remote")
    c.after(entry)
    c.precede(g.add(lambda: 1, name="tgt"))
    (f,) = [f for f in lint(g) if f.rule == "affinity-ignored"]
    assert "condition" in f.message


def test_affinity_ignored_clean_for_plain_remote_body():
    g = TaskGraph("aff-ok")
    g.add(lambda: 1, name="worker", affinity="remote")
    assert "affinity-ignored" not in rules_of(lint(g))


# -- timeout-control-flow ------------------------------------------------------


def test_timeout_control_flow_true_positive():
    g = TaskGraph("to")
    entry = g.add(None, name="entry")
    c = g.add(lambda: 0, kind="condition", name="pick", timeout=1.0)
    c.after(entry)
    c.precede(g.add(lambda: 1, name="tgt"))
    (f,) = [f for f in lint(g) if f.rule == "timeout-control-flow"]
    assert f.severity == "warning"


def test_timeout_clean_on_plain_bodies():
    g = TaskGraph("to-ok")
    g.add(lambda: 1, name="bounded", timeout=5.0)
    assert "timeout-control-flow" not in rules_of(lint(g))


# -- framework -----------------------------------------------------------------


def test_rule_catalog_lists_every_rule():
    cat = rule_catalog()
    for name in RULES:
        assert name in cat


def test_rules_subset_selection():
    g = TaskGraph("cyc")
    a = g.add(lambda: None, name="a")
    b = g.add(lambda: None, name="b")
    a.succeed(b)
    b.succeed(a)
    only = lint_graph(g, rules=["strong-cycle"], races=False)
    assert rules_of(only) == {"strong-cycle"}
    with pytest.raises(KeyError):
        lint_graph(g, rules=["no-such-rule"], races=False)


def test_finding_str_is_informative():
    f = Finding("strong-cycle", "error", "boom", ("a", "b"), "g")
    assert str(f) == "error[strong-cycle] graph 'g': boom [a, b]"
