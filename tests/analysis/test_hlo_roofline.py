"""Unit tests for the HLO collective parser and the roofline model."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import collective_traffic, op_histogram
from repro.analysis.roofline import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    model_flops,
    terms_from_analysis,
)
from repro.configs import get_config


def test_parser_on_synthetic_hlo():
    hlo = """
HloModule m
ENTRY e {
  %x = bf16[128,256]{1,0} parameter(0)
  %ar = bf16[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(%x), replica_groups=[2,8]<=[16], dimensions={0}
  %rs = f32[16,256]{1,0} reduce-scatter(%ag), replica_groups={{0,1}}, to_apply=%add
  %cp = bf16[64,64]{1,0} collective-permute(%x), source_target_pairs={{0,1}}
}
"""
    t = collective_traffic(hlo)
    b = t["bytes_by_kind"]
    # all-reduce: 2 * 128*256*2 * 3/4
    assert b["all-reduce"] == pytest.approx(2 * 128 * 256 * 2 * 3 / 4)
    # all-gather: 512*256*4 * 7/8 (group size 8 from iota form)
    assert b["all-gather"] == pytest.approx(512 * 256 * 4 * 7 / 8)
    # reduce-scatter: result * (n-1) with n=2
    assert b["reduce-scatter"] == pytest.approx(16 * 256 * 4 * 1)
    # permute: plain size
    assert b["collective-permute"] == pytest.approx(64 * 64 * 2)
    assert t["count_by_kind"]["all-reduce"] == 1


def test_parser_ignores_async_done_pairs():
    hlo = """
  %s = bf16[128]{0} all-gather-start(%x), replica_groups={{0,1}}
  %d = bf16[128]{0} all-gather-done(%s), replica_groups={{0,1}}
"""
    t = collective_traffic(hlo)
    assert t["count_by_kind"].get("all-gather", 0) == 1


def test_parser_on_real_lowering():
    """End-to-end: a sharded matmul must show a psum in the parsed traffic."""
    mesh = jax.make_mesh((1,), ("model",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    @jax.jit
    def f(a, b):
        return a @ b

    A = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    lowered = jax.jit(
        lambda a, b: a @ b,
        in_shardings=(NamedSharding(mesh, P(None, "model")), NamedSharding(mesh, P("model", None))),
        out_shardings=NamedSharding(mesh, P()),
    ).lower(A, A)
    txt = lowered.compile().as_text()
    hist = op_histogram(txt)
    assert isinstance(hist, dict)  # parses without error on real HLO


def test_roofline_terms_and_dominance():
    t = terms_from_analysis(PEAK_FLOPS_BF16, HBM_BW * 0.5, ICI_BW * 0.25)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(0.5)
    assert t.collective_s == pytest.approx(0.25)
    assert t.dominant == "compute" and t.dominant_s == pytest.approx(1.0)


def test_model_flops_scaling():
    cfg = get_config("tinyllama-1.1b")
    f1 = model_flops(cfg, 4096, 256, "train")
    f2 = model_flops(cfg, 4096, 512, "train")
    assert f2["total"] == pytest.approx(2 * f1["total"])  # linear in batch
    fp = model_flops(cfg, 4096, 256, "prefill")
    assert fp["total"] < f1["total"]  # no backward
    fd = model_flops(cfg, 32768, 128, "decode")
    assert fd["total"] < fp["total"]  # one token per seq


def test_model_flops_window_discount():
    full = get_config("tinyllama-1.1b")
    win = full.replace(window=1024, global_layers=())
    a = model_flops(full, 32768, 32, "prefill")["attention"]
    b = model_flops(win, 32768, 32, "prefill")["attention"]
    assert b < a * 0.1  # 1k window over 32k seq cuts >90% of attention work


def test_mla_decode_flops_reflect_absorbed_form():
    mla = get_config("deepseek-v2-236b")
    f = model_flops(mla, 32768, 128, "decode")
    # absorbed-form decode attention contracts against kv_lora (512+64) per
    # head: MORE flops than a 128-dim dense head, in exchange for the ~8x
    # smaller cache (MLA trades compute for memory bandwidth)
    dense_equiv = 4.0 * 128 * 128 * 32768 * 128 * 60
    assert f["attention"] > dense_equiv
    per_head_dim = 2 * mla.kv_lora_rank + mla.qk_rope_head_dim
    expect = 2.0 * mla.num_heads * per_head_dim * 32768 * 128 * 60
    assert f["attention"] == pytest.approx(expect)
