"""§15 race detector: static dis-level scan + dynamic vector-clock witness."""
import pytest

from repro.analysis.lint import lint_graph
from repro.analysis.races import RaceObserver, detect_races, task_writes
from repro.core import Executor, TaskGraph

COUNTER = 0  # module global written by the global-race fixtures below


def race_rules(findings):
    return [f for f in findings if f.rule == "shared-state-race"]


def make_closure_pair(g):
    """Two independent tasks bumping the same captured variable."""
    total = 0

    def bump_a():
        nonlocal total
        total += 1

    def bump_b():
        nonlocal total
        total += 2

    return g.add(bump_a, name="bump_a"), g.add(bump_b, name="bump_b")


# -- static: task_writes -------------------------------------------------------


def test_task_writes_sees_closure_cell():
    g = TaskGraph("w")
    a, b = make_closure_pair(g)
    wa, wb = task_writes(a), task_writes(b)
    assert wa and wb
    # same cell ⇒ same key; the description names the variable
    assert set(wa) == set(wb)
    assert "captured variable 'total'" in next(iter(wa.values()))


def test_task_writes_sees_global_and_attr():
    class Box:
        def __init__(self):
            self.n = 0

        def poke(self):
            self.n += 1

    box = Box()

    def bump_global():
        global COUNTER
        COUNTER += 1

    g = TaskGraph("w")
    tg = g.add(bump_global, name="g")
    ta = g.add(box.poke, name="a")
    assert any(k[0] == "global" for k in task_writes(tg))
    attr_keys = [k for k in task_writes(ta) if k[0] == "attr"]
    assert attr_keys and attr_keys[0][2] == "n"


def test_task_writes_recurses_into_nested_functions():
    total = 0

    def outer():
        def inner():
            nonlocal total
            total += 1

        inner()

    g = TaskGraph("w")
    t = g.add(outer, name="outer")
    assert any(k[0] == "cell" for k in task_writes(t))


def test_task_writes_ignores_local_state():
    def pure():
        acc = 0
        for i in range(4):
            acc += i
        return acc

    g = TaskGraph("w")
    assert task_writes(g.add(pure, name="pure")) == {}


def test_task_writes_handles_non_functions():
    g = TaskGraph("w")
    assert task_writes(g.add(None, name="none")) == {}
    assert task_writes(g.add(min, name="builtin")) == {}


# -- static: detect_races ------------------------------------------------------


def test_detect_races_flags_unordered_closure_writers():
    g = TaskGraph("racy")
    a, b = make_closure_pair(g)
    (f,) = race_rules(detect_races(g))
    assert f.severity == "error"
    assert set(f.tasks) == {"bump_a", "bump_b"}
    assert "captured variable 'total'" in f.message


def test_detect_races_clean_when_edge_orders_writers():
    g = TaskGraph("ordered")
    a, b = make_closure_pair(g)
    b.succeed(a)
    assert detect_races(g) == []


def test_detect_races_weak_edges_order_too():
    # §10 loop: body and condition both touch the loop counter, but the
    # weak back-edge serializes each pass — not a race.
    g = TaskGraph("loop")
    entry = g.add(None, name="entry")
    i = 0

    def body():
        nonlocal i
        i += 1

    def more():
        nonlocal i
        return 0 if i < 3 else 9

    b = g.add(body, name="body")
    b.after(entry)
    c = g.add(more, kind="condition", name="more")
    c.after(b)
    c.precede(b)
    assert detect_races(g) == []


def test_detect_races_different_cells_do_not_collide():
    g = TaskGraph("distinct")

    def make(name):
        n = 0

        def bump():
            nonlocal n
            return n

        return g.add(bump, name=name)

    make("x")
    make("y")
    assert detect_races(g) == []


def test_lint_graph_includes_races_by_default():
    g = TaskGraph("racy")
    make_closure_pair(g)
    assert race_rules(lint_graph(g))
    assert not race_rules(lint_graph(g, races=False))


# -- dynamic: RaceObserver -----------------------------------------------------


def test_race_observer_orders_chain():
    g = TaskGraph("chain")
    a = g.add(lambda: 1, name="a")
    b = g.add(lambda: 2, name="b")
    b.succeed(a)
    obs = RaceObserver(g)
    with Executor(2, observers=[obs]) as ex:
        ex.run(g).result(10)
    assert obs.happens_before(a, b)
    assert not obs.happens_before(b, a)
    assert not obs.concurrent(a, b)


def test_race_observer_confirms_static_race():
    g = TaskGraph("racy")
    a, b = make_closure_pair(g)
    findings = detect_races(g)
    obs = RaceObserver(g)
    with Executor(2, observers=[obs]) as ex:
        ex.run(g).result(10)
    assert obs.concurrent(a, b)
    (report,) = obs.check(findings)
    assert report["status"] == "confirmed-concurrent"


def test_race_observer_check_unrun_graph_reports_not_observed():
    g = TaskGraph("racy")
    make_closure_pair(g)
    obs = RaceObserver(g)  # never attached to a run
    (report,) = obs.check(detect_races(g))
    assert report["status"] == "not-observed"


def test_race_observer_ignores_foreign_tasks():
    g = TaskGraph("mine")
    a = g.add(lambda: 1, name="a")
    other = TaskGraph("other")
    x = other.add(lambda: 2, name="x")
    obs = RaceObserver(g)
    obs.on_start(x, worker=0)  # must not blow up or pollute clocks
    obs.on_finish(x, worker=0)
    assert not obs.happens_before(x, a)


@pytest.mark.parametrize("backend", ["serial", "thread"])
def test_race_observer_backend_parity(backend):
    g = TaskGraph("diamond")
    src = g.add(lambda: 0, name="src")
    l = g.add(lambda: 1, name="l")
    r = g.add(lambda: 2, name="r")
    join = g.add(lambda: 3, name="join")
    l.succeed(src)
    r.succeed(src)
    join.succeed(l, r)
    obs = RaceObserver(g)
    with Executor(2, backend=backend, observers=[obs]) as ex:
        ex.run(g).result(10)
    # graph order holds on every backend; the branches stay unordered
    assert obs.happens_before(src, join)
    assert obs.concurrent(l, r)
