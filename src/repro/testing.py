"""Minimal property-testing fallback for environments without ``hypothesis``.

The test suite prefers the real `hypothesis <https://hypothesis.works>`_
(pinned in ``requirements-dev.txt``); when it is not installed the test
modules fall back to this shim::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from repro.testing import given, settings, st

The shim implements just the surface the suite uses — ``given`` (positional
and keyword strategies), ``settings(max_examples=, deadline=)``,
``st.integers/booleans/lists/sampled_from/floats/composite`` — drawing
deterministic pseudo-random examples from a seed derived from the test's
qualified name, so failures reproduce across runs and machines. It does no
shrinking and no coverage-guided search; it is a stand-in, not a
replacement.
"""
from __future__ import annotations

import inspect
import random
from typing import Any, Callable, Optional, Sequence

__all__ = ["given", "settings", "st"]

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A draw function ``rng -> value`` with hypothesis-like spelling."""

    def __init__(self, draw_fn: Callable[[random.Random], Any]) -> None:
        self._draw_fn = draw_fn

    def example(self, rng: random.Random) -> Any:
        return self._draw_fn(rng)


class _Strategies:
    @staticmethod
    def integers(min_value: Optional[int] = None, max_value: Optional[int] = None) -> Strategy:
        lo = -(2**16) if min_value is None else min_value
        hi = 2**16 if max_value is None else max_value
        return Strategy(lambda rng: rng.randint(lo, hi))

    @staticmethod
    def booleans() -> Strategy:
        return Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_kw: Any) -> Strategy:
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> Strategy:
        elements = list(elements)
        return Strategy(lambda rng: rng.choice(elements))

    @staticmethod
    def lists(elem: Strategy, *, min_size: int = 0, max_size: Optional[int] = None) -> Strategy:
        hi = max_size if max_size is not None else min_size + 10

        def draw(rng: random.Random) -> list:
            return [elem.example(rng) for _ in range(rng.randint(min_size, hi))]

        return Strategy(draw)

    @staticmethod
    def composite(fn: Callable) -> Callable[..., Strategy]:
        def builder(*args: Any, **kw: Any) -> Strategy:
            def draw_value(rng: random.Random) -> Any:
                def draw(strategy: Strategy) -> Any:
                    return strategy.example(rng)

                return fn(draw, *args, **kw)

            return Strategy(draw_value)

        return builder


st = _Strategies()


def given(*arg_strats: Strategy, **kw_strats: Strategy):
    """Run the test once per drawn example (rightmost params, like hypothesis)."""

    def deco(test: Callable) -> Callable:
        sig = inspect.signature(test)
        params = list(sig.parameters.values())
        n = len(arg_strats)
        target_names = [p.name for p in params[len(params) - n :]] if n else []
        drawn = set(target_names) | set(kw_strats)

        def wrapper(*args: Any, **kwargs: Any) -> None:
            for i in range(getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)):
                rng = random.Random(f"{test.__module__}.{test.__qualname__}:{i}")
                call_kw = dict(kwargs)
                for name, strat in zip(target_names, arg_strats):
                    call_kw[name] = strat.example(rng)
                for name, strat in kw_strats.items():
                    call_kw[name] = strat.example(rng)
                test(*args, **call_kw)

        wrapper.__name__ = test.__name__
        wrapper.__qualname__ = test.__qualname__
        wrapper.__module__ = test.__module__
        wrapper.__doc__ = test.__doc__
        # hide the drawn params from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(
            parameters=[p for p in params if p.name not in drawn]
        )
        wrapper._max_examples = _DEFAULT_MAX_EXAMPLES
        return wrapper

    return deco


def settings(max_examples: Optional[int] = None, deadline: Any = None, **_kw: Any):
    """Configure a ``given``-wrapped test (only max_examples is honored)."""

    def deco(fn: Callable) -> Callable:
        if max_examples is not None and hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn

    return deco
