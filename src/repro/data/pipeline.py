"""ThreadPool-driven input pipeline: the dataflow runtime in production.

Each prefetch lane is one **condition-looped dataflow graph**
(DESIGN.md §10)

    entry -> produce (CPU, numpy) -> transform (device_put) -> deliver
               ^                                                  |
               '---------------- more? (condition) <--------------'

built once per lane and submitted through the :class:`Executor` facade.
The condition task closes the cycle with a weak back-edge: while steps are
assigned to the lane it returns branch 0 (loop — the next produce starts
*inside the pool*, no Python-side resubmission, no submit syscall per
step), and when the lane's queue is empty it returns an out-of-range index
and the run drains. The host batch flows produce→transform→deliver along
value edges as before; each pass of the deliver task resolves that step's
future from its completion callback. A lane is only re-*submitted* when a
step arrives after its loop exited — under a steady consumer the graph
loops in the workers indefinitely.

Lane graphs are built once and never mutated, so every re-submission
after the first replays the lane's captured
:class:`~repro.core.ReplayPlan` (DESIGN.md §12): restarting an idle lane
is a plan re-arm — no per-task reset walk, no re-wiring beyond the §11
placement refresh — and the produce→transform→deliver loop runs as fused
replay segments.

``depth`` lanes run concurrently on the work-stealing pool, so host-side
data work overlaps device steps (the GIL-releasing regime the pool
targets — DESIGN.md §2). The pipeline cursor is just the step index:
checkpointable and restorable with no draining protocol. Straggler
mitigation falls out of work stealing, and ``depth`` bounds how far ahead
we buffer.

Cancellation (``close``) became *queue-side*: cancelling a step's future
removes it from its lane's queue before the source ever sees it; the pass
already producing is drained cooperatively and each exited loop winds down
through its condition task, so a shared pool comes back clean.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Optional

import jax

from repro.core import Executor, Future, RetryPolicy, Task, TaskGraph, ThreadPool


class _SkipSentinel:
    """Sentinel batch for a pass whose step was cancelled away.

    Pickles back to the module singleton so identity checks (``b is
    _SKIP``) survive the process backend's worker boundary."""

    def __reduce__(self):
        return (_get_skip, ())

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<_SKIP>"


def _get_skip() -> "_SkipSentinel":
    return _SKIP


_SKIP = _SkipSentinel()


class _Lane:
    """One condition-looped produce→transform→deliver graph.

    A lane owns a queue of assigned steps. Passes are serialized by the
    graph's own cycle (produce k+depth cannot start before the condition
    task of pass k chose to loop), so the mutable ``_current`` cell and
    the per-step future map are guarded only against the assigning
    consumer thread.
    """

    __slots__ = (
        "graph",
        "produce",
        "transform",
        "deliver",
        "cond",
        "_exec",
        "_source",
        "_lk",
        "_pending",
        "_futures",
        "_running",
        "_run_future",
        "_current",
    )

    def __init__(
        self,
        index: int,
        source: Any,
        put_fn: Callable[[dict], Any],
        executor: Executor,
        transform_retry: Optional[RetryPolicy] = None,
    ) -> None:
        self._exec = executor
        self._source = source
        self._lk = threading.Lock()
        self._pending: deque[int] = deque()
        self._futures: dict[int, Future] = {}
        self._running = False
        self._run_future: Optional[Future] = None
        self._current: Optional[int] = None
        g = TaskGraph(f"prefetch-lane{index}")
        entry = g.add(None, name=f"entry:{index}")
        # produce is pinned in-parent BY CONTRACT, not by the accident of
        # its bound method failing to pickle: it mutates lane state under
        # _lk and pulls from the user's source, and pickling would walk
        # the whole source object graph at submit just to fail on the lock
        self.produce = g.add(self._produce, name=f"produce:{index}", affinity="local")
        self.produce.after(entry)
        # transform is the lane's only remote-eligible body: on the process
        # backend the CPU-bound batch transform escapes the GIL while
        # produce (stateful bound method) and deliver (identity — a round
        # trip would ship the batch twice for nothing) stay in-parent
        self.transform = g.then(
            self.produce,
            lambda b: b if b is _SKIP else put_fn(b),
            name=f"transform:{index}",
        )
        if transform_retry is not None:
            # §14: the transform is the lane's only stateless body (pure
            # batch -> batch), so it alone may carry a retry policy —
            # produce mutates lane state and must stay exactly-once
            self.transform.retry_policy = transform_retry
            self.transform.idempotent = True
        self.deliver = self.transform.then(lambda b: b, name=f"deliver:{index}")
        self.deliver.affinity = "local"
        self.cond = g.add(self._more, kind="condition", name=f"more:{index}")
        self.cond.after(self.deliver)
        self.cond.precede(self.produce)  # branch 0: weak back-edge, loop
        for t in g.tasks:
            t.propagate_errors = False  # lane errors go to step futures only
        self.deliver.on_done = self._resolve
        self.graph = g

    # -- pool-side (worker threads) -------------------------------------------

    def _produce(self) -> Any:
        with self._lk:
            if not self._pending:  # every queued step was cancelled away
                self._current = None
                return _SKIP
            self._current = self._pending.popleft()
        return self._source.batch(self._current)

    def _more(self) -> int:
        """Condition body: loop while steps are queued, else exit."""
        with self._lk:
            if self._pending:
                return 0  # branch 0 -> produce (weak back-edge)
            self._running = False
            return 1  # out of range -> the run drains

    def _resolve(self, task: Task) -> None:
        with self._lk:
            fut = self._futures.pop(self._current, None)
        if fut is None:  # _SKIP pass, or the step's future was cancelled
            return
        if task.exception is not None:
            fut.set_exception(task.exception)
        else:
            fut.set_result(task.result)

    # -- consumer-side ---------------------------------------------------------

    def assign(self, step: int) -> Future:
        """Queue ``step`` on this lane; restart the loop if it had exited.

        The restart waits out the previous run's drain first — resetting a
        graph whose condition task is still completing would race its
        fan-out (§10: rounds are sequential per graph).
        """
        fut = Future(canceller=lambda: self._cancel_step(step))
        with self._lk:
            self._pending.append(step)
            self._futures[step] = fut
            start = not self._running
            if start:
                self._running = True
        if start:
            rf = self._run_future
            if rf is not None:
                try:
                    rf.result(60)
                except BaseException:  # noqa: BLE001 - old run's verdict is per-step
                    if not rf.done():
                        # the drain timed out: the old run still owns the
                        # graph's task state — resubmitting now would race
                        # its fan-out. Roll the step back out of the lane
                        # (no orphaned queue entry for a run that never
                        # started) and deliver the failure through the
                        # step's own future, where the consumer reads it.
                        with self._lk:
                            try:
                                self._pending.remove(step)
                            except ValueError:
                                pass
                            self._futures.pop(step, None)
                            self._running = False
                        fut.set_exception(
                            TimeoutError(
                                "prefetch lane restart: previous loop still draining"
                            )
                        )
                        return fut
            self._run_future = self._exec.run(self.graph)  # counted submission re-arms
        return fut

    def _cancel_step(self, step: int) -> bool:
        """True iff the step was still queued — the source never sees it."""
        with self._lk:
            try:
                self._pending.remove(step)
            except ValueError:
                return False  # already producing (or done): cooperative drain
            self._futures.pop(step, None)
            return True

    def drain(self, timeout: float) -> None:
        if self._run_future is not None:
            try:
                self._run_future.result(timeout)
            except BaseException:  # noqa: BLE001 - drain only; verdicts are per-step
                pass


class Prefetcher:
    """Ordered prefetching over condition-looped lane graphs (module docs).

    ``backend`` selects the execution backend for an *owned* pool (the
    same ``"thread"`` / ``"process"`` / ``"socket"`` / ``"serial"``
    switch as :class:`~repro.core.Executor`; ignored when ``pool`` is
    given). With ``backend="process"`` (or ``"socket"``) each lane's
    transform body runs in a worker process — CPU-bound transforms
    (tokenization, augmentation, numpy-side preprocessing) overlap truly
    in parallel. Pass a numpy-level ``put_fn`` in that case: the default
    jax ``device_put`` transform must talk to this process's devices, so
    it belongs on the thread backend.
    """

    def __init__(
        self,
        source: Any,  # .batch(step) -> dict of np arrays
        *,
        pool: Optional[ThreadPool] = None,
        backend: Optional[str] = None,
        depth: int = 2,
        start_step: int = 0,
        put_fn: Optional[Callable[[dict], Any]] = None,  # e.g. sharded device_put
        transform_retry: Optional[RetryPolicy] = None,  # §14: retry flaky transforms
    ) -> None:
        self.source = source
        if pool is not None and backend is not None:
            # same contract as Executor: a silently ignored backend= would
            # leave CPU-bound transforms GIL-serialized with no signal
            raise ValueError("pass either backend= or pool=, not both")
        if pool is not None:
            self.pool = pool
            self._own_pool = False
            self._exec = Executor(pool=self.pool)
        else:
            self._exec = Executor(2, backend=backend, name="prefetch")
            self.pool = self._exec.pool
            self._own_pool = True
        if self._exec.backend in ("process", "socket") and put_fn is None:
            # checked against the *resolved* backend (a ProcessPool or
            # SocketPool handed in via pool= must not bypass it): the
            # default transform is jax.device_put-shaped — it must talk to
            # THIS process's devices and would run jax post-fork, both
            # wrong in a worker. Fail loudly instead of silently
            # delivering host numpy batches transformed in a worker.
            if self._own_pool:
                self._exec.close()
            raise ValueError(
                f'Prefetcher on a {self._exec.backend} backend requires an '
                "explicit numpy-level put_fn: the default jax device_put "
                "transform belongs on the thread backend (DESIGN.md §11). "
                'Pass put_fn=<numpy transform>, or use backend="thread".'
            )
        self.depth = max(1, depth)
        self.put_fn = put_fn or (lambda b: jax.tree.map(jax.numpy.asarray, b))
        self._lanes = [
            _Lane(i, source, self.put_fn, self._exec, transform_retry)
            for i in range(self.depth)
        ]
        self._inflight: dict[int, Future] = {}
        self._next_submit = start_step
        self._next_read = start_step
        for _ in range(self.depth):
            self._submit_one()

    # -- internals ------------------------------------------------------------

    def _submit_one(self) -> None:
        step = self._next_submit
        self._next_submit += 1
        lane = self._lanes[step % self.depth]
        self._inflight[step] = lane.assign(step)

    # -- public ------------------------------------------------------------------

    def get(self, timeout: float = 120.0) -> Any:
        """Next batch, in order; refills the prefetch window."""
        step = self._next_read
        self._next_read += 1
        fut = self._inflight.pop(step)
        batch = fut.result(timeout)
        self._submit_one()
        return batch

    @property
    def cursor(self) -> int:
        """Checkpointable resume point (first unconsumed step)."""
        return self._next_read

    def close(self, timeout: float = 30.0) -> None:
        """Cancel or drain every in-flight step, then release the pool.

        Steps still queued on their lane are cancelled (the source never
        sees them); the pass already producing is drained — abandoning it
        would leave ``batch()`` racing a closed pool, and on a shared pool
        it would leak tasks into the next user. Each lane's condition loop
        then drains itself, so the pool comes back quiescent.
        """
        # cancel pass first (stops everything not yet started), then drain
        # the stragglers — cancelling before draining minimizes wasted work
        running = [fut for fut in self._inflight.values() if not fut.cancel()]
        for fut in running:
            try:
                fut.result(timeout)
            except BaseException:  # noqa: BLE001 - drain only; result unused
                pass
        self._inflight.clear()
        for lane in self._lanes:
            lane.drain(timeout)
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
