"""ThreadPool-driven input pipeline: the paper's scheduler in production.

Each batch is a small task graph
    generate (CPU, numpy)  ->  device_put (transfer)
submitted ``depth`` steps ahead on the work-stealing pool, so host-side data
work overlaps device steps (the GIL-releasing regime the pool targets —
DESIGN.md §2). The pipeline cursor is just the step index: checkpointable
and restorable with no draining protocol. Straggler mitigation falls out of
work stealing: a slow generate task gets picked up by whichever worker goes
idle first, and ``depth`` bounds how far ahead we buffer.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.core import Future, TaskGraph, ThreadPool


class Prefetcher:
    def __init__(
        self,
        source: Any,  # .batch(step) -> dict of np arrays
        *,
        pool: Optional[ThreadPool] = None,
        depth: int = 2,
        start_step: int = 0,
        put_fn: Optional[Callable[[dict], Any]] = None,  # e.g. sharded device_put
    ) -> None:
        self.source = source
        self.pool = pool or ThreadPool(2)
        self._own_pool = pool is None
        self.depth = max(1, depth)
        self.put_fn = put_fn or (lambda b: jax.tree.map(jax.numpy.asarray, b))
        self._inflight: dict[int, Future] = {}
        self._next_submit = start_step
        self._next_read = start_step
        for _ in range(self.depth):
            self._submit_one()

    # -- internals ------------------------------------------------------------

    def _submit_one(self) -> None:
        step = self._next_submit
        self._next_submit += 1

        def produce():
            host_batch = self.source.batch(step)  # numpy work
            return self.put_fn(host_batch)  # transfer (GIL-releasing)

        self._inflight[step] = self.pool.submit_future(produce)

    # -- public ------------------------------------------------------------------

    def get(self, timeout: float = 120.0) -> Any:
        """Next batch, in order; refills the prefetch window."""
        step = self._next_read
        self._next_read += 1
        fut = self._inflight.pop(step)
        batch = fut.result(timeout)
        self._submit_one()
        return batch

    @property
    def cursor(self) -> int:
        """Checkpointable resume point (first unconsumed step)."""
        return self._next_read

    def close(self, timeout: float = 30.0) -> None:
        """Cancel or drain every in-flight batch, then release the pool.

        Futures whose produce task has not started are cancelled (the
        source never sees those steps); tasks already running are drained —
        abandoning them would leave produce() racing a closed pool, and on
        a shared pool it would leak tasks into the next user.
        """
        # cancel pass first (stops everything not yet started), then drain
        # the stragglers — cancelling before draining minimizes wasted work
        running = [fut for fut in self._inflight.values() if not fut.cancel()]
        for fut in running:
            try:
                fut.result(timeout)
            except BaseException:  # noqa: BLE001 - drain only; result unused
                pass
        self._inflight.clear()
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
