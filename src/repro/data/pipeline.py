"""ThreadPool-driven input pipeline: the dataflow runtime in production.

Each prefetch lane is one **re-runnable dataflow graph** (DESIGN.md §8)

    produce (CPU, numpy)  ->  transform (device_put)  ->  deliver

built once and re-run every ``depth`` steps: the produce task's return
value (the host batch) flows along the edge into the transform task as its
argument, and the transform's device batch flows into the deliver task,
whose completion resolves that round's future — no closure capture, no
side-channel dicts. ``depth`` lanes run concurrently on the work-stealing
pool, so host-side data work overlaps device steps (the GIL-releasing
regime the pool targets — DESIGN.md §2). The pipeline cursor is just the
step index: checkpointable and restorable with no draining protocol.
Straggler mitigation falls out of work stealing: a slow produce task gets
picked up by whichever worker goes idle first, and ``depth`` bounds how far
ahead we buffer.

The pipeline rides the scheduler's idle machinery for free (DESIGN.md §9):
between steps the pool's workers park on their events instead of polling,
a lane resubmission issues one targeted wakeup, and :meth:`Prefetcher.close`
returns as soon as in-flight produce bodies finish — the pool shutdown no
longer waits out park-timeout ticks.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax

from repro.core import Future, Task, TaskGraph, ThreadPool


class _Lane:
    """One produce→transform→deliver graph, re-run once per assigned step.

    Rounds are sequential per lane (step k and step k+depth share the lane),
    so the mutable ``step`` cell and the per-round ``future`` swap are safe:
    a lane is resubmitted only after its previous round was consumed or
    cancelled.
    """

    __slots__ = ("graph", "produce", "transform", "deliver", "step", "future", "_source")

    def __init__(self, index: int, source: Any, put_fn: Callable[[dict], Any]) -> None:
        self._source = source
        self.step = -1
        self.future: Optional[Future] = None
        g = TaskGraph(f"prefetch-lane{index}")
        self.produce = g.add(self._produce, name=f"produce:{index}")
        self.transform = g.then(self.produce, put_fn, name=f"transform:{index}")
        self.deliver = self.transform.then(lambda b: b, name=f"deliver:{index}")
        for t in (self.produce, self.transform, self.deliver):
            t.propagate_errors = False  # lane errors go to the future only
        self.deliver.on_done = self._resolve
        self.graph = g

    def _produce(self) -> dict:
        return self._source.batch(self.step)

    def _resolve(self, task: Task) -> None:
        fut = self.future
        if fut is None:  # pragma: no cover - resolve before first submit
            return
        if task.exception is not None:
            fut.set_exception(task.exception)
        else:
            fut.set_result(task.result)

    def submit(self, pool: ThreadPool, step: int) -> Future:
        self.step = step
        self.future = Future(canceller=self._cancel)
        pool.submit(self.graph)  # re-arms counters + per-run results
        return self.future

    def _cancel(self) -> bool:
        won = self.produce.cancel()
        if won:
            # produce never started: skip the whole lane round. A produce
            # already running completes normally and the round delivers.
            self.transform.cancel()
            self.deliver.cancel()
        return won


class Prefetcher:
    def __init__(
        self,
        source: Any,  # .batch(step) -> dict of np arrays
        *,
        pool: Optional[ThreadPool] = None,
        depth: int = 2,
        start_step: int = 0,
        put_fn: Optional[Callable[[dict], Any]] = None,  # e.g. sharded device_put
    ) -> None:
        self.source = source
        self.pool = pool or ThreadPool(2)
        self._own_pool = pool is None
        self.depth = max(1, depth)
        self.put_fn = put_fn or (lambda b: jax.tree.map(jax.numpy.asarray, b))
        self._lanes = [_Lane(i, source, self.put_fn) for i in range(self.depth)]
        self._inflight: dict[int, _Lane] = {}
        self._next_submit = start_step
        self._next_read = start_step
        for _ in range(self.depth):
            self._submit_one()

    # -- internals ------------------------------------------------------------

    def _submit_one(self) -> None:
        step = self._next_submit
        self._next_submit += 1
        lane = self._lanes[step % self.depth]
        lane.submit(self.pool, step)
        self._inflight[step] = lane

    # -- public ------------------------------------------------------------------

    def get(self, timeout: float = 120.0) -> Any:
        """Next batch, in order; refills the prefetch window."""
        step = self._next_read
        self._next_read += 1
        lane = self._inflight.pop(step)
        batch = lane.future.result(timeout)
        self._submit_one()
        return batch

    @property
    def cursor(self) -> int:
        """Checkpointable resume point (first unconsumed step)."""
        return self._next_read

    def close(self, timeout: float = 30.0) -> None:
        """Cancel or drain every in-flight lane, then release the pool.

        Lanes whose produce task has not started are cancelled (the source
        never sees those steps); rounds already producing are drained —
        abandoning them would leave produce() racing a closed pool, and on
        a shared pool it would leak tasks into the next user.
        """
        # cancel pass first (stops everything not yet started), then drain
        # the stragglers — cancelling before draining minimizes wasted work
        running = [lane for lane in self._inflight.values() if not lane.future.cancel()]
        for lane in running:
            try:
                lane.future.result(timeout)
            except BaseException:  # noqa: BLE001 - drain only; result unused
                pass
        self._inflight.clear()
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "Prefetcher":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
