from .pipeline import Prefetcher
from .synthetic import MemmapTokens, SyntheticTokens

__all__ = ["Prefetcher", "SyntheticTokens", "MemmapTokens"]
