"""Token sources: deterministic synthetic streams + memmapped corpora.

Both expose the same protocol:
  batch(step) -> dict of np arrays     (pure function of the step index)
so the pipeline is resumable from a bare step counter (checkpointable
cursor) and every host can slice out its own shard deterministically —
the multi-host story needs no coordination traffic at all.
"""
from __future__ import annotations

import pathlib

import numpy as np


class SyntheticTokens:
    """Deterministic pseudo-corpus with a learnable n-gram-ish structure.

    Markov-style sequences (next token = affine function of previous plus
    noise) so small models show decreasing loss — pure-uniform tokens have
    no learnable signal and make smoke training vacuous.
    """

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        host_id: int = 0,
        num_hosts: int = 1,
    ) -> None:
        assert global_batch % num_hosts == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // num_hosts
        self.seed = seed
        self.host_id = host_id

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, self.host_id, step))
        B, S, V = self.local_batch, self.seq_len, self.vocab_size
        x = np.empty((B, S + 1), np.uint64)
        x[:, 0] = rng.integers(0, V, B).astype(np.uint64)
        mult = np.uint64(6364136223846793005)
        inc = np.uint64(1442695040888963407)
        noise = rng.integers(0, 7, (B, S)).astype(np.uint64)
        with np.errstate(over="ignore"):  # uint64 wraparound is the point
            for t in range(S):
                x[:, t + 1] = (x[:, t] * mult + inc + noise[:, t]) % np.uint64(V)
        x = x.astype(np.int32)
        return {"tokens": x[:, :-1], "targets": x[:, 1:]}


class MemmapTokens:
    """File-backed token stream (one flat int32 file), deterministic slices."""

    def __init__(
        self,
        path: str | pathlib.Path,
        seq_len: int,
        global_batch: int,
        *,
        host_id: int = 0,
        num_hosts: int = 1,
    ) -> None:
        assert global_batch % num_hosts == 0
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.local_batch = global_batch // num_hosts
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.global_batch = global_batch
        n_windows = (len(self.data) - 1) // seq_len
        assert n_windows >= global_batch, "corpus too small for one batch"
        self.n_windows = n_windows

    def batch(self, step: int) -> dict:
        B, S = self.local_batch, self.seq_len
        base = (step * self.global_batch + self.host_id * B) % self.n_windows
        idx = (base + np.arange(B)) % self.n_windows
        tok = np.stack([self.data[i * S : i * S + S + 1] for i in idx])
        return {"tokens": tok[:, :-1].astype(np.int32), "targets": tok[:, 1:].astype(np.int32)}


def write_token_file(path: str | pathlib.Path, tokens: np.ndarray) -> None:
    np.asarray(tokens, np.int32).tofile(path)
