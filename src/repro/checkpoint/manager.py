"""Sharded, async, atomic checkpointing built on the paper's thread pool.

Format: one directory per step —
    step_000042.tmp/            (written)
        manifest.json           {paths, shapes, dtypes, step, meta}
        <leaf-path>.bin         raw little-endian bytes per leaf
    step_000042/                (atomic rename on commit)

Async saves run as a *dataflow* task graph on the work-stealing pool,
submitted through the :class:`~repro.core.Executor` facade. The per-leaf
shard writers are a **dynamic subflow** (DESIGN.md §10): a single
``takes_runtime`` task spawns one writer per leaf *from inside the
worker*, sized by the actual leaf count of the tree being saved — no
statically composed subgraph — and each writer *returns* its manifest
entry. The subflow's gather task collects the entries, the join protocol
guarantees they are all present before the spawner's successor runs, and
the commit task receives them as a value, so no shared manifest dict is
mutated from worker threads:

    prepare -> shard{ w:leaf... -> entries }::join -> commit(+gc)

so serialization and IO overlap training. Restore is elastic: leaves are
loaded as numpy and ``jax.device_put`` re-shards them onto WHATEVER mesh the
restarted job has (the manifest stores logical shapes only, never device
layouts), so a 256-chip checkpoint restores onto 8 chips or 512.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.core import Executor, RetryPolicy, Runtime, TaskGraph, ThreadPool

_SEP = "."


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def save_pytree(tree: Any, directory: str | pathlib.Path, *, meta: Optional[dict] = None) -> None:
    """Synchronous atomic save (the async manager decomposes the same steps)."""
    directory = pathlib.Path(directory)
    tmp = directory.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest: dict[str, Any] = {"leaves": {}, "meta": meta or {}}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "_") + ".bin"
        (tmp / fname).write_bytes(arr.tobytes())
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if directory.exists():
        shutil.rmtree(directory)
    tmp.rename(directory)  # commit point


def load_pytree(
    directory: str | pathlib.Path,
    like: Any,
    *,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of ``like``; re-shard via ``shardings``
    (a matching tree of NamedSharding / None) for elastic restore."""
    import ml_dtypes  # registered numpy extension dtypes (bfloat16)

    directory = pathlib.Path(directory)
    manifest = json.loads((directory / "manifest.json").read_text())
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    assert len(keys) == len(flat_like)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings, is_leaf=lambda x: x is None)[0]
        if shardings is not None
        else [None] * len(flat_like)
    )
    out = []
    for key, ref, shard in zip(keys, flat_like, shard_flat):
        info = manifest["leaves"][key]
        dtype = np.dtype(info["dtype"]) if info["dtype"] != "bfloat16" else ml_dtypes.bfloat16
        arr = np.frombuffer(
            (directory / info["file"]).read_bytes(), dtype=dtype
        ).reshape(info["shape"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async checkpoints with atomic commit, keep-k GC and resume.

    ``backend`` selects the execution backend for an owned pool (the
    :class:`~repro.core.Executor` switch; ignored when ``pool`` is
    given). With ``backend="process"`` the per-leaf shard writers —
    spawned as a §10 subflow — serialize and write their ``.bin`` files
    in worker processes, overlapping CPU-bound ``tobytes`` encoding
    across cores; the snapshot (device→host copy), the spawner and the
    commit/GC step stay in-parent by the §11 placement rule.

    The save graph's *shape* is save-invariant (prepare → shard →
    commit; the per-leaf writers are runtime-sized by the spawner), so
    the manager builds it once and feeds each save's payload through a
    slot dict the task bodies read at run time. Sequential saves then
    replay the captured :class:`~repro.core.ReplayPlan` (DESIGN.md §12)
    instead of building + wiring a fresh graph per step. Overlapping
    saves keep their old semantics: while the template graph is still
    draining a save, the next one runs on a disposable one-off graph.
    """

    def __init__(
        self,
        root: str | pathlib.Path,
        *,
        pool: Optional[ThreadPool] = None,
        backend: Optional[str] = None,
        keep: int = 3,
        write_retries: int = 2,
    ) -> None:
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        if pool is not None and backend is not None:
            # same contract as Executor: never silently ignore backend=
            raise ValueError("pass either backend= or pool=, not both")
        if pool is not None:
            self.pool = pool
            self._own_pool = False
            self._exec = Executor(pool=self.pool)
        else:
            self._exec = Executor(2, backend=backend, name="ckpt")
            self.pool = self._exec.pool
            self._own_pool = True
        self.keep = keep
        # §14: shard writes are idempotent (same bytes, same file), so
        # transient IO failures retry with a short backoff before the save
        # graph surfaces the error
        self._write_retry = (
            RetryPolicy(max_attempts=1 + write_retries, backoff=0.01, retry_on=OSError)
            if write_retries > 0
            else None
        )
        self._pending: list = []
        # §12 steady-state template: one cached save graph, replayed per
        # save; the payload slots are what each pass's bodies read.
        self._tpl_graph: Optional[TaskGraph] = None
        self._tpl_state: dict[str, Any] = {}
        self._tpl_busy: Optional[Any] = None  # run future of the template's save

    # -- save -----------------------------------------------------------------

    def save_async(self, step: int, tree: Any, *, meta: Optional[dict] = None) -> None:
        """Snapshot NOW (device->host, blocking only for the copy), then
        serialize + write + commit + gc in the background as a task graph."""
        flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(tree).items()}
        # unique tmp per save: concurrent saves of the same step (or a crashed
        # writer's leftovers) can never corrupt each other; commit is a rename
        payload = {
            "flat": flat,
            "directory": self.root / f"step_{step:08d}",
            "tmp": self.root
            / f"step_{step:08d}.tmp{id(tree) & 0xffff:x}{int(time.time() * 1e3) & 0xffff:x}",
            "meta": meta or {},
            "step": step,
        }
        self._pending.append(self._run_save(payload))

    def _run_save(self, payload: dict) -> Any:
        """Route a save through the cached template graph when it is idle
        (replayed from the second save on), or a disposable graph when an
        earlier save is still draining the template."""
        if self._tpl_graph is None:
            self._tpl_state = dict(payload)
            self._tpl_graph = self._build_save_graph(self._tpl_state)
            self._tpl_busy = fut = self._exec.run(self._tpl_graph)
            return fut
        busy = self._tpl_busy
        if busy is None or busy.done():
            self._tpl_state.clear()
            self._tpl_state.update(payload)
            self._tpl_busy = fut = self._exec.run(self._tpl_graph)
            return fut
        return self._exec.run(self._build_save_graph(dict(payload)))

    def _build_save_graph(self, state: dict) -> TaskGraph:
        """prepare -> shard{ v:leaf -> w:leaf ... }::join -> commit(+gc),
        with every save-specific value read from ``state`` at run time so
        the same graph object serves save after save."""

        def prepare():
            tmp = state["tmp"]
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)

        def write_leaf(tmp: pathlib.Path, key: str, arr: np.ndarray) -> tuple[str, dict]:
            fname = key.replace("/", "_") + ".bin"
            (tmp / fname).write_bytes(arr.tobytes())
            return key, {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
            }

        # Shard writers as a dynamic subflow (DESIGN.md §10): one writer
        # per leaf, spawned inside the worker and sized by the leaf count
        # of THIS pass's tree — the runtime sizing is exactly what lets a
        # replayed pass (§12) save a differently-shaped tree through the
        # same plan. Each leaf array reaches its writer along a dataflow
        # edge from a pinned-local value task — on the process backend
        # that routes the bytes through the §11 shared-memory arena
        # instead of pickling them into the writer's wire (and keeps
        # wiring cost flat: the array itself is never serialized with the
        # function).
        def shard(rt: Runtime):
            tmp = state["tmp"]
            writers = []
            for key, arr in state["flat"].items():
                val = rt.add(lambda a=arr: a, name=f"v:{key[:24]}", affinity="local")
                w = rt.then(
                    val,
                    lambda a, k=key, t=tmp: write_leaf(t, k, a),
                    name=f"w:{key[:24]}",
                )
                w.retry_policy = self._write_retry
                w.idempotent = True  # rewriting the same bytes is safe
                writers.append(w)
            return rt.gather(writers, name="entries")

        def commit(entries: list) -> None:
            # the spawner's value IS the gathered entry list: the join
            # unwrapped the subflow task the body returned (DESIGN.md §10)
            tmp, directory = state["tmp"], state["directory"]
            manifest = {
                "leaves": dict(entries),
                "meta": {**state["meta"], "step": state["step"]},
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if directory.exists():
                shutil.rmtree(directory)
            try:
                tmp.rename(directory)
            except OSError:
                shutil.rmtree(tmp, ignore_errors=True)  # lost a same-step race
            self._gc()

        g = TaskGraph("ckpt-save")
        prep = g.add(prepare, name="prepare")
        shard_t = g.add(shard, name="shard", takes_runtime=True)
        shard_t.after(prep)
        g.then(shard_t, commit, name="commit")
        return g

    def wait(self, timeout: float = 600.0) -> None:
        """Block until every save queued by *this manager* has committed.

        Waits on the per-save run futures, not pool-wide quiescence — on a
        shared pool, other residents (e.g. §10 prefetch lanes looping
        inside the workers) must not fail a wait whose saves are already
        durable. Raises :class:`TimeoutError` instead of proceeding on an
        unfinished save (§10 satellite): a caller that treats "wait
        returned" as "checkpoint durable" must never be lied to by a
        silent timeout. A save that *failed* re-raises its error here;
        unfinished saves stay tracked for a retried wait.
        """
        deadline = time.monotonic() + timeout
        pending, self._pending = self._pending, []
        for i, fut in enumerate(pending):
            try:
                fut.result(max(0.0, deadline - time.monotonic()))
            except TimeoutError:
                if not fut.done():  # genuinely still running: keep tracking
                    self._pending = pending[i:] + self._pending
                    raise TimeoutError(
                        f"checkpoint saves still in flight after {timeout}s"
                    ) from None
                # resolved while we timed out: take the save's own verdict —
                # a commit that landed microseconds late is still durable
                try:
                    fut.result(0)
                except BaseException:
                    self._pending = pending[i + 1 :] + self._pending
                    raise
            except BaseException:
                self._pending = pending[i + 1 :] + self._pending
                raise

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1].split(".")[0])
            for p in self.root.glob("step_*")
            if p.is_dir() and ".tmp" not in p.name and (p / "manifest.json").exists()
        )

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(
        self, like: Any, *, step: Optional[int] = None, shardings: Any = None
    ) -> tuple[Any, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        directory = self.root / f"step_{step:08d}"
        manifest = json.loads((directory / "manifest.json").read_text())
        tree = load_pytree(directory, like, shardings=shardings)
        return tree, manifest["meta"]

    # -- internals ----------------------------------------------------------------

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    def close(self) -> None:
        try:
            self.wait(60)
        finally:
            if self._own_pool:
                self.pool.close()

    def __enter__(self) -> "CheckpointManager":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
