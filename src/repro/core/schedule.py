"""Static schedule generation from dependency-counted task graphs.

This is the TPU-native adaptation of the paper's runtime (DESIGN.md §2): XLA
programs are statically scheduled, so the paper's *dynamic* execution policy
— dependency counting, continuation passing (run one newly-ready successor
inline), LIFO own-queue / FIFO steal — is executed here as a **deterministic
discrete-event simulation** at trace time. The simulator's per-worker
timelines become static schedules that `repro.parallel.pipeline` lowers to
``shard_map`` + ``ppermute`` steppers.

Applied to the (microbatch × stage) grid of pipeline parallelism, with
activation-buffer capacity expressed as *anti-dependency edges* (stage ``s``
may hold at most ``S - s`` in-flight activations, encoded as
``B(m, s) → F(m + S - s, s)``), the paper's B-before-F continuation priority
makes list scheduling reproduce the classic 1F1B schedule — the memory bound
becomes just more dependency edges for the paper's counter machinery.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Optional, Sequence

__all__ = [
    "SimTask",
    "SimResult",
    "simulate",
    "PipelineOp",
    "pipeline_task_graph",
    "pipeline_schedule",
    "gpipe_schedule",
    "schedule_to_table",
    "peak_activation_buffers",
]


@dataclass
class SimTask:
    """A node in the simulated graph.

    ``worker``: pin to a worker index (a pipeline stage / device), or None
    for stealable CPU-style tasks. ``priority``: larger runs first among
    ready tasks (the paper's successor order generalized to a key).
    """

    name: str
    cost: float = 1.0
    worker: Optional[int] = None
    priority: float = 0.0
    successors: list[int] = field(default_factory=list)
    num_predecessors: int = 0
    payload: object = None


@dataclass
class SimResult:
    timelines: list[list[tuple[int, float, float]]]  # per worker: (task, start, end)
    makespan: float
    start: dict[int, float]
    end: dict[int, float]


def _ready_push(
    queues: list[list[tuple[float, int, int]]],
    tasks: Sequence[SimTask],
    tid: int,
    home: int,
    seq: int,
) -> None:
    w = tasks[tid].worker
    target = w if w is not None else home
    # max-heap on (priority, recency): continuation passing is LIFO-biased,
    # so among equal priorities the most recently readied task runs first.
    heapq.heappush(queues[target], (-tasks[tid].priority, -seq, tid))


def simulate(
    tasks: Sequence[SimTask],
    num_workers: int,
    *,
    allow_steal: bool = True,
) -> SimResult:
    """Deterministic discrete-event simulation of the pool's policy.

    Each worker owns a priority-LIFO queue (models the paper's own-deque pop
    plus the inline-continuation rule, which together execute the newest
    ready successor first). Pinned tasks only ever enter their own worker's
    queue and are never stolen; unpinned tasks are stolen FIFO-by-readiness
    from the most loaded victim when a worker idles, like the top end of a
    Chase-Lev deque.
    """
    pending = [t.num_predecessors for t in tasks]
    queues: list[list[tuple[float, int, int]]] = [[] for _ in range(num_workers)]
    seq = 0
    for tid, t in enumerate(tasks):
        if pending[tid] == 0:
            _ready_push(queues, tasks, tid, tid % num_workers, seq)
            seq += 1

    timelines: list[list[tuple[int, float, float]]] = [[] for _ in range(num_workers)]
    start: dict[int, float] = {}
    end: dict[int, float] = {}
    busy = [False] * num_workers
    # completion-event heap: (time, order, task, worker). Successor counters
    # are decremented when the *completion event fires*, never earlier — the
    # exact analogue of the pool's end-of-body fan-out (paper §2.2).
    events: list[tuple[float, int, int, int]] = []
    counter = 0
    n_done = 0

    def _steal(w: int) -> Optional[int]:
        if not allow_steal:
            return None
        victims = sorted(range(num_workers), key=lambda v: -len(queues[v]))
        for v in victims:
            if v == w or not queues[v]:
                continue
            # steal the *oldest* ready unpinned task (FIFO end of the deque)
            cand = None
            for item in queues[v]:
                tid = item[2]
                if tasks[tid].worker is None:
                    if cand is None or item[1] > cand[1]:  # -seq larger == older
                        cand = item
            if cand is not None:
                queues[v].remove(cand)
                heapq.heapify(queues[v])
                return cand[2]
        return None

    def _dispatch(w: int, now: float) -> None:
        nonlocal counter
        if busy[w]:
            return
        tid = heapq.heappop(queues[w])[2] if queues[w] else _steal(w)
        if tid is None:
            return  # parks; re-dispatched at the next completion (notify)
        busy[w] = True
        t = tasks[tid]
        timelines[w].append((tid, now, now + t.cost))
        start[tid], end[tid] = now, now + t.cost
        heapq.heappush(events, (now + t.cost, counter, tid, w))
        counter += 1

    for w in range(num_workers):
        _dispatch(w, 0.0)

    total = len(tasks)
    while events:
        now, _, tid, w = heapq.heappop(events)
        busy[w] = False
        n_done += 1
        for succ in tasks[tid].successors:
            pending[succ] -= 1
            if pending[succ] == 0:
                _ready_push(queues, tasks, succ, w, seq)
                seq += 1
        # The finishing worker dispatches first: with priority-LIFO queues the
        # newest-readied successor runs inline on it (continuation passing).
        _dispatch(w, now)
        for v in range(num_workers):
            if v != w:
                _dispatch(v, now)

    if n_done < total:
        raise RuntimeError(
            "deadlock in schedule simulation: "
            f"{total - n_done} task(s) never became runnable"
        )
    return SimResult(
        timelines=timelines,
        makespan=max(end.values(), default=0.0),
        start=start,
        end=end,
    )


# ---------------------------------------------------------------------------
# Pipeline-parallel schedules from the task-graph machinery
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PipelineOp:
    kind: str  # 'F' or 'B'
    microbatch: int
    stage: int


def pipeline_task_graph(
    num_stages: int,
    num_microbatches: int,
    *,
    memory_limited: bool = True,
) -> list[SimTask]:
    """Build the (microbatch × stage) forward/backward dependency graph.

    Edges:
      F(m, s-1) → F(m, s)            activations flow down the pipe
      F(m, S-1) → B(m, S-1)          loss turns the microbatch around
      B(m, s+1) → B(m, s)            gradients flow back up
      B(m, s)   → F(m + S - s, s)    [memory_limited] stage s buffers at most
                                     S - s activations (anti-dependency) —
                                     with B-priority this yields 1F1B.
    Backward tasks get higher priority: the paper's continuation rule picks
    them as the inline successor, draining activations eagerly.
    """
    S, M = num_stages, num_microbatches
    tasks: list[SimTask] = []
    fid: dict[tuple[int, int], int] = {}
    bid: dict[tuple[int, int], int] = {}
    # Priorities: every backward beats every forward (the paper's
    # continuation rule drains completed microbatches first), and earlier
    # microbatches beat later ones within a kind (canonical pipeline order).
    for m in range(M):
        for s in range(S):
            fid[(m, s)] = len(tasks)
            tasks.append(
                SimTask(
                    name=f"F{m}.{s}",
                    worker=s,
                    priority=-float(m),
                    payload=PipelineOp("F", m, s),
                )
            )
    for m in range(M):
        for s in range(S):
            bid[(m, s)] = len(tasks)
            tasks.append(
                SimTask(
                    name=f"B{m}.{s}",
                    worker=s,
                    priority=1e6 - float(m),
                    payload=PipelineOp("B", m, s),
                )
            )

    def edge(a: int, b: int) -> None:
        tasks[a].successors.append(b)
        tasks[b].num_predecessors += 1

    for m in range(M):
        for s in range(S):
            if s > 0:
                edge(fid[(m, s - 1)], fid[(m, s)])
            if s < S - 1:
                edge(bid[(m, s + 1)], bid[(m, s)])
        edge(fid[(m, S - 1)], bid[(m, S - 1)])
    if memory_limited:
        for s in range(S):
            cap = S - s
            for m in range(M):
                if m + cap < M:
                    edge(bid[(m, s)], fid[(m + cap, s)])
    return tasks


def pipeline_schedule(num_stages: int, num_microbatches: int) -> SimResult:
    """1F1B-family schedule derived by simulating the paper's policy."""
    tasks = pipeline_task_graph(num_stages, num_microbatches, memory_limited=True)
    return simulate(tasks, num_stages, allow_steal=False)


def gpipe_schedule(num_stages: int, num_microbatches: int) -> SimResult:
    """GPipe (all-forward-then-all-backward): no anti-dependency edges and
    forward-priority — the memory-hungry baseline the paper's policy beats."""
    tasks = pipeline_task_graph(num_stages, num_microbatches, memory_limited=False)
    for t in tasks:
        m = t.payload.microbatch
        t.priority = (1e6 - m) if t.payload.kind == "F" else -float(m)
    return simulate(tasks, num_stages, allow_steal=False)


def schedule_to_table(
    tasks: Sequence[SimTask], result: SimResult, num_stages: int
) -> list[list[Optional[PipelineOp]]]:
    """Flatten a pipeline SimResult into a dense tick table.

    ``table[tick][stage]`` is the PipelineOp that stage executes at that tick
    (or None = bubble). Unit costs ⇒ integer ticks. This is what the
    shard_map executor consumes: every tick is one fwd or bwd step plus a
    ``ppermute`` halo exchange at the boundary.
    """
    ticks = int(round(result.makespan))
    table: list[list[Optional[PipelineOp]]] = [[None] * num_stages for _ in range(ticks)]
    for w, tl in enumerate(result.timelines):
        for tid, s0, _s1 in tl:
            op = tasks[tid].payload
            if isinstance(op, PipelineOp):
                table[int(round(s0))][w] = op
    return table


def peak_activation_buffers(
    tasks: Sequence[SimTask], result: SimResult, num_stages: int
) -> list[int]:
    """Max simultaneously-buffered forward activations per stage.

    An activation for microbatch m lives at stage s from end(F(m,s)) until
    end(B(m,s)). 1F1B caps this at S - s; GPipe reaches M.
    """
    peaks = [0] * num_stages
    f_end: dict[tuple[int, int], float] = {}
    b_end: dict[tuple[int, int], float] = {}
    for tid, t in enumerate(tasks):
        op = t.payload
        if isinstance(op, PipelineOp):
            (f_end if op.kind == "F" else b_end)[(op.microbatch, op.stage)] = result.end[tid]
    for s in range(num_stages):
        times = sorted(
            [(f_end[k], +1) for k in f_end if k[1] == s]
            + [(b_end[k], -1) for k in b_end if k[1] == s]
        )
        cur = 0
        for _t, d in times:
            cur += d
            peaks[s] = max(peaks[s], cur)
    return peaks
