"""Executor facade: the one front door to the task-graph runtime.

DESIGN.md §10. The low-level surface (``ThreadPool`` / ``TaskGraph`` /
``Task``) stays available — and everything here is a thin composition of
it — but consumers should talk to :class:`Executor`:

    with Executor(4) as ex:
        fut = ex.run(graph)            # any graph: DAG, condition-cyclic,
        fut.result()                   # subflow-spawning — one entry point
        ex.run_until(graph, converged) # re-run until a predicate holds
        await ex.co_run(graph)         # same, from asyncio

What the facade buys over raw ``ThreadPool``:

* **one submission path** — ``run`` accepts a ``TaskGraph``, a ``Task``, a
  bare callable or an iterable of tasks, always returns a
  :class:`~repro.core.Future`, and picks the right completion protocol
  (hidden-sink for DAGs, counted for condition graphs) automatically;
* **control-flow loops** — ``run_until`` is the Python-side companion to
  in-graph condition cycles: re-submit a (reset) graph until ``predicate``
  says done, for convergence loops whose check lives outside the graph;
* **asyncio interop** — ``co_run`` plus ``Future.__await__`` let async
  servers await pool work without blocking their event loop;
* **lifecycle** — context-manager close, observer attachment, and a
  ``wait_idle`` that reports timeout as a ``bool`` (the §10 satellite
  contract) instead of mixing it with task failure.

Migration from the old call sites is mechanical (see README):

    pool.run(g)                 ->  ex.run(g).result()
    g.as_future(pool)           ->  ex.run(g)
    pool.submit_future(fn)      ->  ex.submit(fn)
    pool.wait_idle(t) + except  ->  if not ex.wait_idle(t): ...
"""
from __future__ import annotations

import os
import time
import warnings
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .baseline import SerialPool
from .graph import Runtime, TaskGraph
from .pool import Future, ThreadPool
from .task import RetryPolicy, Task

__all__ = ["Executor", "Runtime"]


class Executor:
    """Facade over an execution backend running task graphs.

    Parameters
    ----------
    num_threads:
        Worker count for an owned pool (``os.cpu_count()`` default, as in
        the paper): worker *threads* for the thread backend, worker
        *processes* for the process backend. Ignored for ``serial`` and
        when ``pool`` is given.
    backend:
        Which execution backend to own (DESIGN.md §11):

        * ``"thread"`` (default) — the paper's work-stealing
          :class:`ThreadPool`; best for IO/GIL-releasing bodies and
          minimum per-task overhead.
        * ``"process"`` — :class:`repro.dist.ProcessPool`: the same
          scheduler, with task bodies shipped to worker processes so
          CPU-bound pure-Python bodies actually run in parallel. Large
          array edge values cross via shared memory.
        * ``"socket"`` — :class:`repro.dist.SocketPool`: the same
          scheduler again, with bodies shipped over TCP to connected
          worker processes — locally forked by default, or joined from
          other hosts (``python -m repro.dist.remote_worker --connect
          host:port``). Large arrays cross each connection once via a
          content-hashed transfer cache (DESIGN.md §16).
        * ``"serial"`` — :class:`~repro.core.SerialPool`: everything on
          the calling thread; the zero-overhead floor and a
          deterministic debugging backend.

        Every graph kind — DAGs, condition loops, subflows, ``run_until``,
        the asyncio bridge — behaves identically on all four (the
        backend-parametrized conformance suite in ``tests/dist``
        enforces it).
    pool:
        Adopt an existing (possibly shared) pool instead of owning one;
        ``close()`` then leaves it running. Mutually exclusive with
        ``backend``.
    observers, name, deque_cls:
        Forwarded to the owned pool (see ``ThreadPool``).
    verify:
        Pre-submission static verification (DESIGN.md §15). ``"off"``
        (default) submits untouched; ``"warn"`` runs the
        :mod:`repro.analysis` linter + race detector over each graph the
        first time it is submitted (and again only after structural
        mutation, tracked by the §12 epoch fingerprint) and reports
        findings through :mod:`warnings`; ``"strict"`` raises
        :class:`~repro.analysis.verify.GraphVerificationError` on
        error-severity findings before any task runs. The default comes
        from the ``REPRO_VERIFY`` environment variable when set —
        flipping a whole deployment to ``warn`` needs no code change.
        Verification is per *graph submission*, never per task: with
        ``"off"`` the only cost is one attribute test in :meth:`run`.
    backend_kwargs:
        Extra keyword arguments for the owned pool's constructor (e.g.
        ``mp_context="spawn"`` or ``arena_threshold=...`` for the process
        backend).

    Doctest — the backend is a constructor switch, not an API change::

        >>> from repro.core import Executor, TaskGraph
        >>> for backend in ("serial", "thread"):
        ...     g = TaskGraph()
        ...     total = g.gather([g.add(lambda i=i: i * i) for i in range(4)])
        ...     with Executor(2, backend=backend) as ex:
        ...         _ = ex.run(g).result(10)
        ...     print(backend, sum(total.result))
        serial 14
        thread 14
    """

    def __init__(
        self,
        num_threads: Optional[int] = None,
        *,
        backend: Optional[str] = None,
        pool: Optional[Any] = None,
        observers: Sequence[Any] = (),
        name: str = "repro-executor",
        deque_cls: Optional[type] = None,
        verify: Optional[str] = None,
        **backend_kwargs: Any,
    ) -> None:
        if verify is None:
            verify = os.environ.get("REPRO_VERIFY", "off")
        if verify not in ("off", "warn", "strict"):
            raise ValueError(
                f"unknown verify mode {verify!r}; expected 'off', 'warn' or 'strict'"
            )
        # None when off: the hot-path check in run() is one falsy test
        self._verify_mode: Optional[str] = None if verify == "off" else verify
        if pool is not None:
            if backend is not None:
                raise ValueError("pass either backend= or pool=, not both")
            self.pool = pool
            if isinstance(pool, SerialPool):
                self.backend = "serial"
            elif hasattr(pool, "_caches"):  # dist.SocketPool (also has _procs)
                self.backend = "socket"
            elif hasattr(pool, "_procs"):  # dist.ProcessPool
                self.backend = "process"
            else:
                self.backend = "thread"
            self._own_pool = False
            for obs in observers:
                pool.add_observer(obs)
            return
        backend = backend or "thread"
        self.backend = backend
        if backend == "serial":
            self.pool = SerialPool(observers=observers)
        elif backend in ("thread", "process", "socket"):
            kwargs: dict[str, Any] = {"name": name, "observers": observers}
            if deque_cls is not None:
                kwargs["deque_cls"] = deque_cls
            kwargs.update(backend_kwargs)
            if backend == "thread":
                self.pool = ThreadPool(num_threads, **kwargs)
            elif backend == "process":
                from repro.dist import ProcessPool  # deferred: core stays below dist

                self.pool = ProcessPool(num_threads, **kwargs)
            else:
                from repro.dist import SocketPool  # deferred: core stays below dist

                self.pool = SocketPool(num_threads, **kwargs)
        else:
            raise ValueError(
                f"unknown backend {backend!r}; expected 'thread', 'process', "
                "'socket' or 'serial'"
            )
        self._own_pool = True

    # -- submission ------------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return self.pool.num_threads

    def run(
        self,
        work: Union[TaskGraph, Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
        replay: bool = True,
        retry: Optional[RetryPolicy] = None,
        timeout: Optional[float] = None,
        idempotent: bool = False,
    ) -> Future:
        """Submit ``work`` and return a :class:`Future` for its completion.

        * ``TaskGraph`` — the whole graph; resolves to ``None`` on success,
          to the first task failure otherwise. Condition graphs use
          counted completion; plain DAGs keep the hidden-sink fast path.
        * ``Task`` — a single (possibly pre-wired) task; resolves to its
          ``result``.
        * callable — like ``submit_future``; resolves to the return value.
        * iterable of tasks — wrapped in an anonymous ``TaskGraph``.

        ``priority`` (when given) follows the ``ThreadPool.submit``
        contract everywhere: for graphs and iterables it overrides every
        member task that never chose an explicit band of its own.

        ``replay`` (graphs only, DESIGN.md §12): re-running an unchanged
        graph dispatches from its captured :class:`~repro.core.ReplayPlan`
        — the first pass runs live and records, later passes skip the
        per-task countdown walk. Any structural change, divergent
        condition branch or cancellation falls back to live dispatch
        transparently; pass ``replay=False`` to force live dispatch.

        ``retry`` / ``timeout`` / ``idempotent`` (DESIGN.md §14, callable
        submissions only) wrap the callable in a task carrying that fault
        policy; graphs and pre-built tasks declare theirs per task at
        construction (``TaskGraph.add(..., retry=..., timeout=...)``).
        """
        if retry is not None or timeout is not None or idempotent:
            if not callable(work) or isinstance(work, (Task, TaskGraph)):
                raise ValueError(
                    "retry=/timeout=/idempotent= apply to callable submissions; "
                    "graphs and tasks declare fault policy per task "
                    "(TaskGraph.add / Task constructor)"
                )
            task = Task(work, retry=retry, timeout=timeout, idempotent=idempotent)
            task.propagate_errors = False
            return self.run(task, priority=priority)
        if isinstance(work, TaskGraph):
            if priority is not None:
                self._apply_priority(work.tasks, priority)
            if self._verify_mode is not None:
                self._verify(work)
            return work.as_future(self.pool, replay=replay)
        if isinstance(work, Task):
            task = work
            fut = Future(canceller=task.cancel)
            prev_cb = task.on_done
            if getattr(prev_cb, "_executor_resolver", False):
                # re-running the same Task through the facade: unwind our
                # previous wrapper instead of chaining (and leaking) one
                # Future + closure per round
                prev_cb = prev_cb._wrapped

            def _resolve(t: Task) -> None:
                if prev_cb is not None:
                    prev_cb(t)
                if t.exception is not None:
                    fut.set_exception(t.exception)
                else:
                    fut.set_result(t.result)

            _resolve._executor_resolver = True  # type: ignore[attr-defined]
            _resolve._wrapped = prev_cb  # type: ignore[attr-defined]
            task.on_done = _resolve
            self.pool.submit(task, priority=priority)
            return fut
        if callable(work):
            return self.pool.submit_future(work, priority=priority or 0.0)
        tasks = list(work)
        if priority is not None:
            self._apply_priority(tasks, priority)
        # Re-running the same iterable: if the tasks already share one graph
        # that contains exactly them (e.g. the anonymous wrapper a previous
        # run() adopted them into), reuse it — its tracked sink membership
        # is what makes build-once/run-N futures resolve correctly.
        g0 = tasks[0].graph if tasks else None
        if g0 is not None and len(g0) == len(tasks) and all(t.graph is g0 for t in tasks):
            if self._verify_mode is not None:
                self._verify(g0)
            return g0.as_future(self.pool)
        g = TaskGraph("executor-run")
        g.adopt(*tasks)
        if self._verify_mode is not None:
            self._verify(g)
        return g.as_future(self.pool)

    def _verify(self, graph: TaskGraph) -> None:
        """§15 pre-submission verification (``verify="warn"|"strict"``).

        Cached by the graph's §12 epoch fingerprint: a build-once /
        run-N graph verifies exactly once, and again only after a
        structural mutation. Runtime-spawned subflows are born after
        submission and are not covered — lint spawner scripts with
        ``python -m repro.analysis.lint`` for that.
        """
        if graph._verified_epoch == graph._epoch:
            return
        from repro.analysis.verify import verify_graph  # lazy: analysis is opt-in

        report = verify_graph(graph, backend=self.backend)
        if self._verify_mode == "strict":
            report.raise_if_errors()  # before caching: resubmission re-raises
        graph._verified_epoch = graph._epoch
        if not report.ok:
            warnings.warn(str(report), stacklevel=3)

    @staticmethod
    def _apply_priority(tasks: Sequence[Task], priority: float) -> None:
        """Override the band of every task that never chose one explicitly
        (same propagation rule as ``ThreadPool.submit(task, priority=)``)."""
        for t in tasks:
            if not t._explicit_pr:
                t.priority = priority

    def submit(
        self,
        fn: Callable[[], Any],
        *,
        priority: float = 0.0,
        retry: Optional[RetryPolicy] = None,
        timeout: Optional[float] = None,
        idempotent: bool = False,
    ) -> Future:
        """Fire-and-collect a callable (alias of ``submit_future``); the
        §14 fault-policy keywords match :meth:`run`."""
        if retry is not None or timeout is not None or idempotent:
            return self.run(
                fn, priority=priority, retry=retry, timeout=timeout, idempotent=idempotent
            )
        return self.pool.submit_future(fn, priority=priority)

    def run_until(
        self,
        graph: TaskGraph,
        predicate: Callable[[], bool],
        *,
        max_rounds: Optional[int] = None,
        timeout: Optional[float] = None,
    ) -> int:
        """Re-run ``graph`` (reset between rounds) until ``predicate()``
        holds; returns the number of rounds executed (≥ 1, do-while).

        The in-graph alternative — a condition task closing a weak cycle —
        keeps the loop on the workers with zero resubmission cost; this is
        for convergence checks that must run on the caller's side.
        Raises ``TimeoutError`` past ``timeout`` (seconds, whole call) and
        ``RuntimeError`` if ``max_rounds`` rounds leave the predicate
        false.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        rounds = 0
        while True:
            if rounds:
                graph.reset()
            remaining = None
            if deadline is not None:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(f"run_until: timed out after {rounds} rounds")
            self.run(graph).result(remaining)
            rounds += 1
            if predicate():
                return rounds
            if max_rounds is not None and rounds >= max_rounds:
                raise RuntimeError(
                    f"run_until: predicate still false after {rounds} rounds"
                )

    # -- asyncio bridge ---------------------------------------------------------

    async def co_run(
        self,
        work: Union[TaskGraph, Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
        replay: bool = True,
    ) -> Any:
        """``await executor.co_run(graph)``: submit from an event loop and
        await the result without blocking the loop (``Future.__await__``
        transfers completion via ``call_soon_threadsafe``)."""
        return await self.run(work, priority=priority, replay=replay)

    # -- lifecycle --------------------------------------------------------------

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """True once the pool quiesced; False on timeout (§10 satellite
        contract — task failures still raise, timeouts never do)."""
        return self.pool.wait_idle(timeout)

    def add_observer(self, observer: Any) -> None:
        self.pool.add_observer(observer)

    def remove_observer(self, observer: Any) -> None:
        self.pool.remove_observer(observer)

    def stats(self) -> dict[str, int]:
        return self.pool.stats()

    def close(self) -> None:
        """Close the owned pool (no-op on an adopted shared pool)."""
        if self._own_pool:
            self.pool.close()

    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        own = "own" if self._own_pool else "shared"
        return f"Executor({self.pool.num_threads} workers, {self.backend} backend, {own} pool)"
