"""Observer layer for the thread pool (DESIGN.md §8).

Taskflow-style executor observation: the pool exposes four lifecycle hooks
and calls every attached observer at each of them —

    on_submit(task)                 task entered a queue (inbox or deque)
    on_start(task, worker)          a worker began executing the task
    on_finish(task, worker)         the task completed (ran, failed or was
                                    skipped as cancelled/poisoned)
    on_steal(task, thief, victim)   `thief` took the task from `victim`'s
                                    deque (inbox drains are not steals)
    on_retry(task, attempt, worker) a §14 retry: the failed attempt was
                                    re-armed and re-scheduled (`attempt`
                                    counts failed attempts so far, 1-based)
    on_timeout(task, worker)        an attempt exceeded its `timeout=`
                                    deadline (cooperative checkpoint raise,
                                    or a §11 hard worker kill)

Hooks run on the pool's worker threads (``on_submit`` on the submitting
thread), so implementations must be cheap and thread-safe; the pool
swallows observer exceptions rather than letting telemetry poison the
runtime. Inline continuations (paper §2.2) never re-enter a queue, so they
produce start/finish events but no submit event — exactly the property the
Chrome trace makes visible as back-to-back slices on one worker lane.

Observation is strictly opt-in on the scheduler hot path (DESIGN.md §9):
with no observers attached every event site is a single falsy-list check,
including the fused fan-out in ``_execute`` (which fires ``on_submit`` for
each successor it pushes, but never for the inline continuation). Park and
wakeup activity is deliberately *not* an observer event — it is aggregate
state, exported through the ``parked``/``wakeups`` counters in
``ThreadPool.stats()``.

Two implementations ship here:

* :class:`StatsObserver` — aggregate counters and per-task-name timing;
* :class:`ChromeTraceObserver` — a ``chrome://tracing`` / Perfetto trace
  exporter ("trace event format" JSON: one complete ``X`` event per task
  execution on the worker's lane, instant events for steals).

A third lives with the §15 verifier:
:class:`repro.analysis.races.RaceObserver` assigns vector clocks from
graph edges at ``on_start``/``on_finish`` — the runtime happens-before
witness that cross-checks the static race detector's report on a real
schedule. It is an ordinary :class:`PoolObserver`; the hooks above are
its entire contract.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Optional

from .task import Task

__all__ = ["PoolObserver", "StatsObserver", "ChromeTraceObserver"]


class PoolObserver:
    """No-op base class; subclass and override the hooks you need.

    Any object with these four methods works (the protocol is duck-typed);
    inheriting just saves writing the empty ones.
    """

    def on_submit(self, task: Task) -> None:  # noqa: B027 - intentional no-op
        pass

    def on_start(self, task: Task, worker: int) -> None:  # noqa: B027
        pass

    def on_finish(self, task: Task, worker: int) -> None:  # noqa: B027
        pass

    def on_steal(self, task: Task, thief: int, victim: int) -> None:  # noqa: B027
        pass

    def on_retry(self, task: Task, attempt: int, worker: int) -> None:  # noqa: B027
        pass

    def on_timeout(self, task: Task, worker: int) -> None:  # noqa: B027
        pass


class StatsObserver(PoolObserver):
    """Aggregate execution statistics.

    Counts submissions/starts/finishes/steals and accumulates wall time per
    task name (the prefix before ``:`` — so ``prefill:7`` and ``prefill:9``
    aggregate as ``prefill``). ``summary()`` returns a plain dict suitable
    for logging or JSON.

    Attach at pool construction or any time via ``add_observer``::

        >>> from repro.core import StatsObserver, ThreadPool
        >>> stats = StatsObserver()
        >>> with ThreadPool(2, observers=[stats]) as pool:
        ...     pool.run(lambda: None)
        >>> stats.summary()["finished"]
        1
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._starts: dict[int, float] = {}
        self.submitted = 0
        self.started = 0
        self.finished = 0
        self.stolen = 0
        self.errors = 0
        self.retried = 0
        self.timed_out = 0
        self.by_name: dict[str, list] = {}  # name -> [count, total_seconds]

    def on_submit(self, task: Task) -> None:
        with self._lock:
            self.submitted += 1

    def on_start(self, task: Task, worker: int) -> None:
        now = time.perf_counter()
        with self._lock:
            self.started += 1
            self._starts[id(task)] = now

    def on_finish(self, task: Task, worker: int) -> None:
        now = time.perf_counter()
        with self._lock:
            self.finished += 1
            if task.exception is not None:
                self.errors += 1
            t0 = self._starts.pop(id(task), None)
            if t0 is not None:
                key = (task.name or "<anon>").split(":")[0]
                cell = self.by_name.setdefault(key, [0, 0.0])
                cell[0] += 1
                cell[1] += now - t0

    def on_steal(self, task: Task, thief: int, victim: int) -> None:
        with self._lock:
            self.stolen += 1

    def on_retry(self, task: Task, attempt: int, worker: int) -> None:
        with self._lock:
            self.retried += 1

    def on_timeout(self, task: Task, worker: int) -> None:
        with self._lock:
            self.timed_out += 1

    def summary(self) -> dict[str, Any]:
        with self._lock:
            return {
                "submitted": self.submitted,
                "started": self.started,
                "finished": self.finished,
                "stolen": self.stolen,
                "errors": self.errors,
                "retried": self.retried,
                "timed_out": self.timed_out,
                "by_name": {
                    k: {"count": c, "total_s": s, "mean_us": (s / c * 1e6 if c else 0.0)}
                    for k, (c, s) in sorted(self.by_name.items())
                },
            }


class ChromeTraceObserver(PoolObserver):
    """Export pool execution as Chrome trace-event JSON.

    Open the saved file in ``chrome://tracing`` or https://ui.perfetto.dev:
    one lane (``tid``) per worker, one complete event per task execution,
    instant events marking steals. Timestamps are microseconds relative to
    observer construction (the format's expected unit).
    """

    def __init__(self, pid: int = 1) -> None:
        self._t0 = time.perf_counter()
        self._lock = threading.Lock()
        self._starts: dict[int, float] = {}
        self._events: list[dict[str, Any]] = []
        self.pid = pid

    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def on_start(self, task: Task, worker: int) -> None:
        with self._lock:
            self._starts[id(task)] = time.perf_counter()

    def on_finish(self, task: Task, worker: int) -> None:
        now = time.perf_counter()
        with self._lock:
            t0 = self._starts.pop(id(task), now)
            ev: dict[str, Any] = {
                "name": task.name or "task",
                "cat": "task",
                "ph": "X",
                "ts": self._us(t0),
                "dur": max(0.0, (now - t0) * 1e6),
                "pid": self.pid,
                "tid": worker,
            }
            args: dict[str, Any] = {}
            if task.priority:
                args["priority"] = task.priority
            if task.cancelled:
                args["cancelled"] = True
            elif task.exception is not None:
                args["error"] = repr(task.exception)
            if args:
                ev["args"] = args
            self._events.append(ev)

    def on_steal(self, task: Task, thief: int, victim: int) -> None:
        with self._lock:
            self._events.append(
                {
                    "name": f"steal:{task.name or 'task'}",
                    "cat": "steal",
                    "ph": "i",
                    "s": "t",
                    "ts": self._us(time.perf_counter()),
                    "pid": self.pid,
                    "tid": thief,
                    "args": {"victim": victim},
                }
            )

    def on_retry(self, task: Task, attempt: int, worker: int) -> None:
        # the failed attempt produced no finish slice (the task is not done
        # yet) — the instant event marks it on the worker's lane instead
        now = time.perf_counter()
        with self._lock:
            t0 = self._starts.pop(id(task), now)
            self._events.append(
                {
                    "name": f"retry:{task.name or 'task'}",
                    "cat": "fault",
                    "ph": "X",
                    "ts": self._us(t0),
                    "dur": max(0.0, (now - t0) * 1e6),
                    "pid": self.pid,
                    "tid": worker,
                    "args": {"attempt": attempt},
                }
            )

    def on_timeout(self, task: Task, worker: int) -> None:
        with self._lock:
            self._events.append(
                {
                    "name": f"timeout:{task.name or 'task'}",
                    "cat": "fault",
                    "ph": "i",
                    "s": "t",
                    "ts": self._us(time.perf_counter()),
                    "pid": self.pid,
                    "tid": worker,
                }
            )

    def to_trace(self, num_workers: Optional[int] = None) -> dict[str, Any]:
        """The trace as a dict (``{"traceEvents": [...]}`` container)."""
        with self._lock:
            events = list(self._events)
        meta = []
        if num_workers is not None:
            for i in range(num_workers):
                meta.append(
                    {
                        "name": "thread_name",
                        "ph": "M",
                        "pid": self.pid,
                        "tid": i,
                        "args": {"name": f"worker-{i}"},
                    }
                )
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def to_json(self, num_workers: Optional[int] = None) -> str:
        return json.dumps(self.to_trace(num_workers))

    def save(self, path: Any, num_workers: Optional[int] = None) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(num_workers))
