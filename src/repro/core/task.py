"""Task-graph primitives (paper §2.2).

A :class:`Task` is a thin wrapper over a nullary callable. Each task stores
references to its *successor* tasks and a counter of uncompleted
*predecessor* tasks. When the thread pool finishes a task body it decrements
the counter of every successor; one successor whose counter hits zero is
executed inline on the same worker thread (continuation passing), and any
other newly-ready successors are submitted to the pool. That policy is
implemented in ``pool.py``; this module only defines the data structure and
the dependency-wiring API.

The public API mirrors the paper::

    tasks: list[Task] = []
    get_a = Task(lambda: ...)
    get_sum = Task(lambda: ...)
    get_sum.succeed(get_a, get_b)     # get_sum runs after get_a and get_b
    pool.submit(tasks)

``Succeed`` is kept as an alias for drop-in similarity with the C++ API.

Beyond the paper, tasks carry a ``priority`` (larger runs first among ready
tasks — the same key the schedule simulator uses, DESIGN.md §3) and support
*cooperative cancellation*: :meth:`cancel` marks a task so its body is
skipped if it has not started yet; a task already running completes
normally. Both are what the serving engine builds on (prefill at low
priority, decode ticks at high priority, request abortion).

**Value-passing (dataflow) edges — DESIGN.md §8.** Every :meth:`succeed`
call records the predecessor in an ordered ``inputs`` list — the edge's
argument slot. A task constructed with ``takes_inputs=True`` consumes those
slots: its body is called as ``fn(pred_a.result, pred_b.result, ...)`` in
``succeed`` order, so results flow along edges instead of through captured
closures. Nullary tasks (the paper's model, and the default) ignore their
slots entirely, so ordering-only graphs are unchanged. :meth:`after` wires
an ordering-only edge that records no slot, for mixing control dependencies
into dataflow pipelines. A dataflow task whose input failed (exception or
cancellation) skips its body and propagates the *first* failed input's
exception — failure flows along the same edges as data.

The C++ implementation uses ``std::atomic<int>`` for the predecessor counter.
CPython's ``x -= 1`` is three bytecodes (load/sub/store) and *not* atomic.
Instead of a per-task lock (the pre-§9 design), the countdown is a list of
``num_predecessors`` tokens and the decrement is a single ``list.pop()`` —
one GIL-atomic method call, the direct analogue of ``fetch_sub``. The list
is pre-filled with ``range(n)`` and popped from the end, so exactly one
caller observes the token ``0``: that caller released the last dependency
and owns the ready transition. The cancel-vs-start race is arbitrated the
same way: a one-token claim list popped by whichever of ``run``/``cancel``
gets there first (DESIGN.md §9).

**Control flow in the graph — DESIGN.md §10.** Two task kinds extend the
static model:

* **Condition tasks** (``kind="condition"``, the Taskflow idea): every
  out-edge of a condition task is *weak* — it contributes no token to the
  successor's countdown and records no argument slot. When a condition
  task finishes, its integer return value selects exactly one successor
  (by wiring order), which is scheduled *directly*, bypassing its strong
  countdown; every other branch stays un-run this pass. Because weak edges
  carry no countdown, a weak back-edge may legally close a cycle — the
  executor re-arms loop tasks after each pass (:meth:`rearm`), which is
  what makes iterative retry/convergence loops expressible in the graph.
  A non-``int`` or out-of-range return selects nothing (the loop's exit).

* **Runtime tasks** (``takes_runtime=True``): the body receives a
  ``Runtime`` handle (``graph.py``) as its first argument and may spawn a
  *subflow* — a subgraph built inside the worker, sized by data only seen
  at runtime. The executor joins the subflow before releasing the
  spawner's successors (DESIGN.md §10 join protocol).
"""
from __future__ import annotations

from typing import Any, Callable, Iterable, Optional

__all__ = ["Task", "CancelledError", "RetryPolicy", "TaskTimeoutError"]


class CancelledError(RuntimeError):
    """Raised for tasks skipped because a predecessor failed or the task
    (or its future) was cancelled before it started."""


class TaskTimeoutError(TimeoutError):
    """A task body exceeded its ``timeout=`` budget (DESIGN.md §14).

    On the thread/serial backends the deadline is *cooperative*: the body
    observes it at :func:`~repro.core.pool.checkpoint` calls. On
    ``ProcessPool`` the watchdog hard-kills the worker process hosting the
    overdue body and the scheduler surfaces this error in its place.
    """


class RetryPolicy:
    """Declarative retry policy for a task body (DESIGN.md §14).

    A failed attempt whose exception matches ``retry_on`` is re-armed and
    re-scheduled through the §9 fast path, after a deterministic backoff
    delay of ``backoff * factor**(attempt-1)`` seconds (capped by
    ``max_backoff``). The delay is implemented as a pool-timed deferred
    requeue — no worker ever sleeps it off. When ``max_attempts`` is
    exhausted the final exception surfaces with the previous attempt's
    exception attached as its ``__context__`` chain.

    ``retry_on`` may be an exception type or a tuple of types; cancellation
    (:class:`CancelledError`) is never retried regardless.

        >>> from repro.core import RetryPolicy
        >>> p = RetryPolicy(max_attempts=3, backoff=0.1, factor=2.0)
        >>> [p.delay(a) for a in (1, 2)]
        [0.1, 0.2]
    """

    __slots__ = ("max_attempts", "backoff", "factor", "max_backoff", "retry_on")

    def __init__(
        self,
        max_attempts: int = 3,
        backoff: float = 0.0,
        *,
        factor: float = 2.0,
        max_backoff: Optional[float] = None,
        retry_on: Any = Exception,
    ) -> None:
        if max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if backoff < 0:
            raise ValueError("backoff must be >= 0 seconds")
        self.max_attempts = max_attempts
        self.backoff = backoff
        self.factor = factor
        self.max_backoff = max_backoff
        self.retry_on = retry_on

    def matches(self, exc: BaseException) -> bool:
        """Whether ``exc`` is retriable under this policy."""
        if isinstance(exc, CancelledError):
            return False
        return isinstance(exc, self.retry_on)

    def delay(self, attempt: int) -> float:
        """Backoff before re-running after failed attempt ``attempt`` (1-based)."""
        d = self.backoff * (self.factor ** (attempt - 1))
        if self.max_backoff is not None and d > self.max_backoff:
            return self.max_backoff
        return d

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (
            f"RetryPolicy(max_attempts={self.max_attempts}, "
            f"backoff={self.backoff}, factor={self.factor})"
        )


class Task:
    """A unit of work plus its task-graph bookkeeping.

    Attributes
    ----------
    fn:
        The wrapped callable (no arguments; the return value is stored on
        ``task.result`` — use closures/captures for richer data flow, as in
        the paper).
    successors:
        Tasks that depend on this one.
    num_predecessors:
        Static in-degree, set up via :meth:`succeed` / :meth:`after`.
    inputs:
        Ordered argument slots: the predecessors wired via :meth:`succeed`,
        in wiring order. Consumed only when ``takes_inputs`` is True.
    takes_inputs:
        When True the body is called with the recorded inputs' results as
        positional arguments (dataflow mode); when False (default) the body
        is nullary, as in the paper.
    priority:
        Larger runs first among ready tasks (own-deque bands, inbox bands
        and the inline-continuation pick — see pool.py). Default 0.0. A
        priority that was never set explicitly (``None`` at construction)
        is *inheritable*: ``then()`` continuations copy their parent's
        priority, and ``ThreadPool.submit(task, priority=...)`` propagates
        the override to reachable successors that never chose their own.
    kind:
        ``"static"`` (default) or ``"condition"`` (module docs above).
    takes_runtime:
        When True the body receives a ``Runtime`` handle as its first
        positional argument (before any dataflow inputs) and may spawn a
        joined subflow (module docs above).
    propagate_errors:
        When False, an exception from ``fn`` is recorded on the task (and
        delivered through any attached future / ``on_done``) but does not
        poison the pool. ``submit_future`` uses this.
    on_done:
        Optional callback ``fn(task)`` invoked by the executor exactly once
        after the task completes — whether it ran, failed, or was skipped
        (cancelled / poisoned graph). This is how futures observe tasks.
    affinity:
        Where the *body* may execute under a multi-process backend
        (DESIGN.md §11): ``"any"`` (default — offloaded to a worker
        process when the body serializes, run in-parent otherwise),
        ``"local"`` (always in-parent), or ``"remote"`` (must offload; an
        unserializable body raises ``UnpicklableTaskError`` at submit).
        Thread and serial backends ignore the field entirely. Control-flow
        bodies — conditions, ``takes_runtime`` spawners — always run
        in-parent regardless, because they drive the scheduler itself.
    retry_policy:
        Optional :class:`RetryPolicy` (also the ``retry=`` constructor
        keyword): a matching body failure re-arms the task and re-schedules
        it after a deterministic backoff instead of surfacing (DESIGN.md
        §14). Exhausted retries surface the final exception with earlier
        attempts on its ``__context__`` chain.
    timeout:
        Optional per-attempt deadline in seconds. Cooperative on thread/
        serial backends (the body must call
        :func:`~repro.core.pool.checkpoint`); enforced by a hard worker
        kill on ``ProcessPool``.
    idempotent:
        Declares the body safe to re-execute after it *started* and was
        lost (worker death / hard timeout kill on ``ProcessPool``). Bodies
        default to at-most-once: a started-but-lost non-idempotent body is
        never retried, even under a matching :class:`RetryPolicy`.

    The paper's ``(a+b)*(c+d)`` graph, wired exactly as in §2.2::

        >>> from repro.core import SerialExecutor, Task
        >>> box = {}
        >>> get_a = Task(lambda: box.__setitem__("a", 1), name="a")
        >>> get_b = Task(lambda: box.__setitem__("b", 2), name="b")
        >>> get_sum = Task(lambda: box.__setitem__("s", box["a"] + box["b"]))
        >>> _ = get_sum.succeed(get_a, get_b)   # runs after both
        >>> SerialExecutor().run([get_a, get_b, get_sum])
        >>> box["s"]
        3

    or dataflow-style, results flowing along the edges (DESIGN.md §8)::

        >>> a, b = Task(lambda: 1), Task(lambda: 2)
        >>> s = Task(lambda x, y: x + y, takes_inputs=True).succeed(a, b)
        >>> SerialExecutor().run([a, b, s])
        >>> s.result
        3
    """

    # Class-level flag, overridden by the §12 replay layer's meta nodes
    # (``replay.py``): lets the pool route queue-side observer events to
    # member tasks with a single attribute check and zero per-instance cost.
    _seg = False

    __slots__ = (
        "fn",
        "name",
        "priority",
        "successors",
        "num_predecessors",
        "num_weak_predecessors",
        "inputs",
        "takes_inputs",
        "kind",
        "takes_runtime",
        "graph",
        "result",
        "propagate_errors",
        "on_done",
        "ctx",
        "auto_rearm",
        "affinity",
        "_wire",
        "_slow",
        "_explicit_pr",
        "_spawned",
        "_pending",
        "_claim",
        "_done",
        "_started",
        "_cancelled",
        "exception",
        "retry_policy",
        "timeout",
        "idempotent",
        "_attempt",
        "_last_exc",
        "_timed_out",
        "_cancel_req",
    )

    def __init__(
        self,
        fn: Optional[Callable[..., Any]] = None,
        name: str = "",
        *,
        priority: Optional[float] = None,
        takes_inputs: bool = False,
        kind: str = "static",
        takes_runtime: bool = False,
        affinity: str = "any",
        retry: Optional[RetryPolicy] = None,
        timeout: Optional[float] = None,
        idempotent: bool = False,
    ) -> None:
        if kind not in ("static", "condition"):
            raise ValueError(f"unknown task kind {kind!r}")
        if affinity not in ("any", "local", "remote"):
            raise ValueError(f"unknown task affinity {affinity!r}")
        if timeout is not None and timeout <= 0:
            raise ValueError("timeout must be positive seconds")
        if kind == "condition" and takes_runtime:
            # the subflow splice would take over the weak successor list and
            # strongly decrement edges that hold no countdown tokens — every
            # branch would be silently skipped. Spawn from a branch instead.
            raise ValueError("a condition task cannot also take a runtime handle")
        self.fn = fn
        self.name = name
        self.priority = 0.0 if priority is None else priority
        self._explicit_pr = priority is not None
        self.successors: list[Task] = []
        self.num_predecessors = 0
        self.num_weak_predecessors = 0  # in-edges from condition tasks
        self.inputs: list[Task] = []  # ordered argument slots (succeed order)
        self.takes_inputs = takes_inputs
        self.kind = kind
        self.takes_runtime = takes_runtime
        self.graph: Any = None  # back-ref set by TaskGraph.add (for .then())
        self.result: Any = None
        self.propagate_errors = True
        self.on_done: Optional[Callable[["Task"], None]] = None
        # Per-submission run context (executor-counted completion) and the
        # slow-dispatch flag: the pool's fast path checks `_slow` once per
        # task; conditions, runtime tasks, re-armable loop members and
        # counted runs all route through the full-featured fan-out.
        self.ctx: Any = None
        self.auto_rearm = False
        # Process-backend placement (DESIGN.md §11): `affinity` is the
        # user's constraint; `_wire` caches the serialized body for the
        # current submission (None = run in-parent). Thread/serial
        # backends never touch either.
        self.affinity = affinity
        self._wire: Any = None
        self._slow = kind == "condition" or takes_runtime
        self._spawned: Optional[list[Task]] = None  # last run's subflow
        # Runtime countdown: a token list popped once per completed
        # predecessor; the popper receiving token 0 owns the ready
        # transition. reset() re-arms it. Roots have an empty countdown.
        self._pending: list = []
        # run/cancel claim: one token, popped by whichever side wins.
        self._claim: list = [0]
        self._done = False
        self._started = False
        self._cancelled = False
        self.exception: Optional[BaseException] = None
        # Fault tolerance (DESIGN.md §14): `retry_policy` governs re-arming
        # after a matching body failure, `timeout` bounds one attempt,
        # `idempotent` declares that a started-but-lost body (worker death
        # mid-execution, ProcessPool) is safe to run again. `_attempt`
        # counts completed failed attempts this arming; `_last_exc` chains
        # them; `_timed_out` is the watchdog's hard-kill mark.
        self.retry_policy = retry
        self.timeout = timeout
        self.idempotent = idempotent
        self._attempt = 0
        self._last_exc: Optional[BaseException] = None
        self._timed_out = False
        self._cancel_req = False

    @property
    def is_condition(self) -> bool:
        return self.kind == "condition"

    @property
    def is_source(self) -> bool:
        """No in-edges of either strength — schedulable at submission."""
        return self.num_predecessors == 0 and self.num_weak_predecessors == 0

    # -- graph wiring ---------------------------------------------------------

    def succeed(self, *predecessors: "Task") -> "Task":
        """Declare that ``self`` runs after every task in ``predecessors``.

        Matches the paper's ``task.Succeed(&a, &b)``. Each predecessor is
        also recorded as the next argument slot: a ``takes_inputs`` task
        receives the predecessors' results as positional arguments in
        wiring order (nullary tasks ignore the slots). Returns ``self`` so
        calls can be chained.

        An edge whose *predecessor* is a condition task is **weak**: it
        contributes no countdown token and no argument slot — the branch
        the condition selects is scheduled directly (module docs). The
        position of ``self`` in the condition's successor list is its
        branch index.
        """
        g = self.graph
        if g is not None:
            g._epoch += 1  # §12 structure fingerprint: wiring mutates shape
        for p in predecessors:
            p.successors.append(self)
            if p.kind == "condition":
                self.num_weak_predecessors += 1
            else:
                self.num_predecessors += 1
                self.inputs.append(p)
            pg = p.graph
            if pg is not None and pg is not g:
                pg._epoch += 1
        self._pending[:] = range(self.num_predecessors)
        return self

    def after(self, *predecessors: "Task") -> "Task":
        """Ordering-only edge: run after ``predecessors`` without recording
        an argument slot. Use for control dependencies (e.g. "the directory
        must exist") feeding into dataflow tasks. An edge from a condition
        task is weak here too (see :meth:`succeed`)."""
        g = self.graph
        if g is not None:
            g._epoch += 1  # §12 structure fingerprint: wiring mutates shape
        for p in predecessors:
            p.successors.append(self)
            if p.kind == "condition":
                self.num_weak_predecessors += 1
            else:
                self.num_predecessors += 1
            pg = p.graph
            if pg is not None and pg is not g:
                pg._epoch += 1
        self._pending[:] = range(self.num_predecessors)
        return self

    def precede(self, *successors: "Task") -> "Task":
        """Inverse wiring convenience: ``self`` runs before ``successors``."""
        for s in successors:
            s.succeed(self)
        return self

    def then(
        self,
        fn: Callable[..., Any],
        *,
        name: str = "",
        priority: Optional[float] = None,
    ) -> "Task":
        """Dataflow combinator: a new task consuming this task's result.

        Requires the task to belong to a :class:`~repro.core.TaskGraph`
        (``graph`` back-ref, set by ``TaskGraph.add``); the new task is
        added to the same graph. ``a.then(f).then(g)`` builds ``g(f(a()))``
        as a three-task pipeline. With no explicit ``priority`` the
        continuation inherits this task's priority band — a high-priority
        chain stays high-priority end to end.
        """
        if self.graph is None:
            raise ValueError("then() requires a task created via TaskGraph.add")
        t = self.graph.add(
            fn,
            name=name,
            priority=self.priority if priority is None else priority,
            takes_inputs=True,
        )
        t._explicit_pr = self._explicit_pr if priority is None else True
        t.succeed(self)
        return t

    # C++-style aliases
    Succeed = succeed
    Precede = precede

    # -- runtime ---------------------------------------------------------------

    def reset(self) -> None:
        """Re-arm the countdown so the same graph can be resubmitted.

        Clears the previous run's ``result``/``exception`` — results are
        per-run state, so a re-run can never observe a stale value through
        a dataflow edge. Both token lists are refilled in place (no fresh
        allocation on the re-run path).
        """
        self._pending[:] = range(self.num_predecessors)
        self._claim[:] = (0,)
        self._done = False
        self._started = False
        self._cancelled = False
        self.result = None
        self.exception = None
        self._spawned = None  # per-run record; a skipped spawner must not
        # surface a previous run's subflow to resolution or rendering
        self._attempt = 0
        self._last_exc = None
        self._timed_out = False
        self._cancel_req = False

    def rearm(self) -> None:
        """Re-arm for re-triggering *within* the same run (condition
        cycles, DESIGN.md §10).

        Unlike :meth:`reset`, the previous pass's ``result``/``exception``
        are kept — dataflow successors read them after the pass completes,
        and a condition loop's state legitimately persists across passes
        (the next pass overwrites it). A task cancelled mid-loop stays
        cancelled: its claim is left consumed, so every further trigger
        skips the body and the loop drains cooperatively.
        """
        self._pending[:] = range(self.num_predecessors)
        if not self._cancelled:
            self._claim[:] = (0,)
            self._started = False
        self._done = False
        if self._attempt:  # fresh retry budget per loop pass (rare branch)
            self._attempt = 0
            self._last_exc = None

    def decrement(self) -> bool:
        """Atomically decrement the pending count; True when it reaches zero.

        Analogue of ``fetch_sub(1) == 1`` in the C++ implementation: the
        single ``list.pop()`` bytecode is the atom, and the caller popping
        token ``0`` (the last element) wins the ready transition — exactly
        one winner per arming, with no lock on this per-edge hot path.
        """
        try:
            return self._pending.pop() == 0
        except IndexError:  # over-decrement: already released (defensive)
            return False

    def cancel(self) -> bool:
        """Cooperatively cancel: skip the body if it has not started yet.

        Returns True if the cancellation won the race (the body will never
        run); False if the task already started or finished. Dependency
        bookkeeping is unaffected either way — a cancelled task still
        completes (with :class:`CancelledError`) and releases successors.
        A body already running can observe the request cooperatively via
        :func:`~repro.core.pool.checkpoint` (DESIGN.md §14).
        """
        self._cancel_req = True  # visible to checkpoint() even once started
        if self._started or self._done:
            return False
        try:
            self._claim.pop()  # the run/cancel race atom
        except IndexError:
            # Claim already taken: by run() (cancel lost -> False) or by an
            # earlier cancel (repeat cancel stays True until the skipped
            # body completes — idempotent, as the Future contract requires).
            return self._cancelled
        self._cancelled = True
        return True

    @property
    def cancelled(self) -> bool:
        return self._cancelled

    @property
    def started(self) -> bool:
        return self._started

    @property
    def is_ready(self) -> bool:
        return not self._pending and not self._done

    @property
    def done(self) -> bool:
        return self._done

    def run(self, runtime: Any = None, invoke: Optional[Callable[..., Any]] = None) -> None:
        """Execute the wrapped callable (exceptions handled by the pool).

        A task cancelled before this point records :class:`CancelledError`
        and completes without calling ``fn``. A ``takes_inputs`` task whose
        input failed (or was cancelled) skips its body and adopts the first
        failed input's exception, so failure propagates along dataflow
        edges without poisoning the pool when ``propagate_errors`` is off.
        ``runtime`` (supplied by the executor for ``takes_runtime`` tasks)
        is passed to the body as its first positional argument.

        ``invoke`` is the process-backend dispatch seam (DESIGN.md §11):
        when given, the body call is delegated as ``invoke(fn, args)`` —
        every other piece of the protocol (claim race, cancellation,
        input-failure adoption, done transition) still runs here, on the
        scheduler side, so a remote body changes *where* ``fn`` executes
        and nothing else.
        """
        try:
            self._claim.pop()  # the run/cancel race atom
        except IndexError:  # cancel() claimed it first
            if self.exception is None:
                self.exception = CancelledError("task cancelled")
            self._done = True
            return
        self._started = True
        self.exception = None  # a re-armed loop pass must not report stale failures
        if self.takes_inputs:
            for p in self.inputs:
                if p.exception is not None:
                    self.exception = p.exception
                    self._done = True
                    return
            if self.fn is not None:
                args = tuple(p.result for p in self.inputs)
                if runtime is not None:
                    self.result = self.fn(runtime, *args)
                elif invoke is not None:
                    self.result = invoke(self.fn, args)
                else:
                    self.result = self.fn(*args)
        elif self.fn is not None:
            if runtime is not None:
                self.result = self.fn(runtime)
            elif invoke is not None:
                self.result = invoke(self.fn, ())
            else:
                self.result = self.fn()
        self._done = True

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        nm = self.name or (getattr(self.fn, "__name__", "") if self.fn else "")
        return f"Task({nm!r}, preds={self.num_predecessors}, succs={len(self.successors)})"


def iter_graph(tasks: Iterable[Task]) -> list[Task]:
    """All tasks reachable from ``tasks`` through successor edges."""
    seen: dict[int, Task] = {}
    stack = list(tasks)
    while stack:
        t = stack.pop()
        if id(t) in seen:
            continue
        seen[id(t)] = t
        stack.extend(t.successors)
    return list(seen.values())
