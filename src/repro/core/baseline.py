"""Baseline executors the paper's design is compared against.

The paper benchmarks its work-stealing pool against Taskflow (C++). Taskflow
is not available here, so EXPERIMENTS.md compares against the designs the
paper positions itself against in §1–2:

* :class:`NaiveThreadPool` — the "typical" pre-work-stealing design: a single
  mutex-protected global FIFO queue shared by all workers. Same Task-graph
  semantics (dependency counting), but every push/pop contends on one lock
  and there is no continuation passing — newly-ready successors are always
  re-queued.

* ``SerialExecutor`` — runs a task graph topologically on the calling thread;
  the zero-overhead floor for scheduling-overhead measurements.
"""
from __future__ import annotations

import threading
import time
from collections import deque as _pydeque
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .task import CancelledError, Task, TaskTimeoutError, iter_graph

__all__ = ["NaiveThreadPool", "SerialExecutor", "SerialPool"]


class NaiveThreadPool:
    """Single locked global queue, no stealing, no continuation passing."""

    def __init__(self, num_threads: Optional[int] = None) -> None:
        import os

        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        self._q: _pydeque[Task] = _pydeque()
        self._cond = threading.Condition()
        self._unfinished = 0
        self._stop = False
        self._first_error: Optional[BaseException] = None
        self._threads = [
            threading.Thread(target=self._worker, name=f"naive-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def submit(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        if isinstance(work, Task):
            self._push(work)
        elif callable(work):
            self._push(Task(work))
        else:
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            for t in graph:
                if t.is_source:
                    self._push(t)

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        self.submit(work)
        self.wait_idle()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """True once idle, False on timeout (matching ``ThreadPool``)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._unfinished == 0, timeout):
                return False
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err
        return True

    def close(self) -> None:
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "NaiveThreadPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- internals ------------------------------------------------------------

    def _push(self, task: Task) -> None:
        with self._cond:
            self._unfinished += 1
            self._q.append(task)
            self._cond.notify()

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop:
                    self._cond.wait()
                if self._stop:
                    return
                task = self._q.popleft()
            try:
                task.run()
            except BaseException as exc:  # noqa: BLE001
                task.exception = exc
                if task.propagate_errors:
                    with self._cond:
                        if self._first_error is None:
                            self._first_error = exc
            if task.on_done is not None:
                try:
                    task.on_done(task)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            ready = [s for s in task.successors if s.decrement()]
            with self._cond:
                for s in ready:
                    self._unfinished += 1
                    self._q.append(s)
                if ready:
                    self._cond.notify_all()
                self._unfinished -= 1
                if self._unfinished == 0:
                    self._cond.notify_all()


class SerialExecutor:
    """Topological execution on the calling thread (overhead floor).

    Supports the §10 task kinds too — condition branches/loops and
    runtime-spawned subflows — so the serial floor exists for every
    benchmark shape. ``NaiveThreadPool`` deliberately does not: it models
    the pre-work-stealing static design the paper argues against.
    """

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        from .graph import (  # deferred: baseline stays below graph.py
            Runtime,
            select_branch,
            splice_subflow,
        )

        if isinstance(work, Task):
            tasks = iter_graph([work])
        elif callable(work):
            Task(work).run()
            return
        else:
            tasks = iter_graph(list(work))
        has_cond = False
        for t in tasks:
            t.reset()
            if t.kind == "condition":
                has_cond = True
        stack = [t for t in tasks if t.is_source]
        while stack:
            t = stack.pop()
            rt = Runtime(t) if t.takes_runtime else None
            t.run(rt)
            if t.on_done is not None:
                try:
                    t.on_done(t)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            if has_cond:
                t.rearm()  # single-threaded: re-arm unconditionally
            if rt is not None and rt.sub.tasks and t.exception is None:
                sub, join = splice_subflow(t, rt.sub)  # shared join protocol
                t._spawned = sub
                roots = [s for s in sub if s.is_source]
                stack.extend(roots if roots else [join])
                continue
            if t.kind == "condition":
                branch = select_branch(t)  # shared §10 selection rule
                if branch is not None:
                    stack.append(branch)
                continue
            for s in t.successors:
                if s.decrement():
                    stack.append(s)

    def close(self) -> None:  # interface parity
        pass

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        return True

    def __enter__(self) -> "SerialExecutor":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


class SerialPool:
    """Pool-*protocol* adapter over in-thread topological execution.

    :class:`SerialExecutor` runs a graph; ``SerialPool`` additionally
    speaks the full :class:`~repro.core.ThreadPool` surface the rest of
    the runtime composes against — ``submit`` / ``submit_future`` /
    ``wait_idle`` / counted submission / observers — which is what lets
    ``Executor(backend="serial")`` drive every graph kind (DAGs, condition
    loops, subflows, ``as_future`` completion) with zero threads. Futures
    returned through this pool are resolved by the time the submitting
    call returns.

    Unlike :class:`SerialExecutor` (which lets a body's exception escape
    ``run``), failures here follow the pool contract: the exception is
    recorded on the task, poisons the run when ``propagate_errors`` is
    set (pending bodies are skipped with :class:`CancelledError`, exactly
    like a poisoned thread pool), and is re-raised by :meth:`wait_idle` or
    delivered through the attached future.

    §14 fault tolerance holds serially too: a retriable failure re-runs
    the body inline after sleeping the policy's backoff, ``timeout=``
    deadlines fire at ``checkpoint()`` calls, and ``stats()`` reports the
    same ``retries`` / ``timeouts`` counters as the thread backends.
    """

    # §14 body-dispatch seam (same shape as ``ThreadPool._offload``): a
    # FaultInjector wraps it; None means "call the body directly".
    _offload: Optional[Callable[[Task, int], None]] = None

    def __init__(self, observers: Any = ()) -> None:
        self._observers: list[Any] = list(observers)
        self._first_error: Optional[BaseException] = None
        self._executed = 0
        self._retries = 0
        self._timeouts = 0
        self._stop = False

    # -- pool protocol ---------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return 1

    def add_observer(self, observer: Any) -> None:
        self._observers.append(observer)

    def remove_observer(self, observer: Any) -> None:
        try:
            self._observers.remove(observer)
        except ValueError:
            pass

    def _notify(self, method: str, *args: Any) -> None:
        for obs in self._observers:
            try:
                getattr(obs, method)(*args)
            except BaseException:  # noqa: BLE001 - telemetry never poisons the run
                pass

    def submit(
        self,
        work: Union[Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
    ) -> None:
        """Run ``work`` to completion on the calling thread (priorities are
        irrelevant in a serial schedule and ignored)."""
        if isinstance(work, Task):
            # single-task contract parity: ThreadPool._schedule runs exactly
            # the given task (wired predecessors or not), then its fan-out
            self._run_stack([work])
        elif callable(work):
            self._run_graph([Task(work)])
        else:
            notify = getattr(work, "_notify_submitted", None)
            if notify is not None:
                notify()
            self._run_graph(iter_graph(list(work)))

    def submit_future(self, fn: Callable[[], Any], *, priority: float = 0.0):
        from .pool import Future  # deferred: baseline stays below pool.py

        task = Task(fn)
        task.propagate_errors = False
        fut = Future(canceller=task.cancel)

        def _resolve(t: Task) -> None:
            if t.exception is not None:
                fut.set_exception(t.exception)
            else:
                fut.set_result(t.result)

        task.on_done = _resolve
        self._run_graph([task])
        return fut

    def _submit_with_context(self, tasks: Sequence[Task], ctx: Any) -> bool:
        """Counted-completion shim: the graph runs synchronously, then one
        +1/−1 pulse drains the context and fires its completion callback."""
        graph = iter_graph(list(tasks))
        if not graph:
            return False
        self._run_graph(graph)
        ctx.update(1)
        ctx.update(-1)
        return True

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        self.submit(work)
        self.wait_idle()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        err, self._first_error = self._first_error, None
        if err is not None:
            raise err
        return True

    def stats(self) -> dict[str, int]:
        """`ThreadPool.stats` shape: ``executed`` counts real task
        executions; steals/parks/wakeups are structurally zero serially."""
        return {
            "executed": self._executed,
            "steals": 0,
            "parked": 0,
            "wakeups": 0,
            "retries": self._retries,
            "timeouts": self._timeouts,
        }

    def close(self) -> None:
        self._stop = True

    def __enter__(self) -> "SerialPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- execution ------------------------------------------------------------

    def _run_graph(self, tasks: list) -> None:
        """Graph-submission path: reset, arm condition members, run from
        the sources (mirrors ``ThreadPool.submit``'s iterable branch)."""
        has_cond = False
        for t in tasks:
            t.reset()
            if t.kind == "condition":
                has_cond = True
        if has_cond:
            for t in tasks:
                t.auto_rearm = True
        stack = [t for t in tasks if t.is_source]
        if not stack and tasks:
            raise ValueError("task graph has no sources (dependency cycle?)")
        self._run_stack(stack)

    def _run_stack(self, stack: list) -> None:
        from .graph import Runtime, select_branch, splice_subflow
        from .pool import _current  # §14 checkpoint state (deferred import)

        while stack:
            t = stack.pop()
            rt: Any = None
            while True:  # §14 retries happen inline — there is one thread
                if self._observers:
                    # §8 ledger parity with ThreadPool: one on_start per
                    # *attempt* (a retry re-dispatches there). on_submit
                    # stays structurally zero — it is a queue-push event,
                    # and the serial baseline has no queue (same rule as
                    # inline continuations on the thread backend).
                    self._notify("on_start", t, 0)
                _current.task = t
                _current.deadline = (
                    None if t.timeout is None else time.monotonic() + t.timeout
                )
                try:
                    if self._first_error is not None and t.propagate_errors:
                        t.exception = CancelledError("predecessor failed")
                        t._done = True  # noqa: SLF001 - pool-side protocol
                    elif t.takes_runtime:
                        rt = Runtime(t)  # fresh per attempt: no stale spawns
                        t._spawned = rt.sub.tasks
                        t.run(rt)
                    elif self._offload is not None:
                        self._offload(t, 0)
                    else:
                        t.run()
                except BaseException as exc:  # noqa: BLE001 - recorded, raised in wait
                    if isinstance(exc, TaskTimeoutError):
                        self._timeouts += 1
                        if self._observers:
                            self._notify("on_timeout", t, 0)
                    pol = t.retry_policy
                    if (
                        pol is not None
                        and pol.matches(exc)
                        and not (getattr(exc, "started", False) and not t.idempotent)
                        and t._attempt + 1 < pol.max_attempts
                    ):
                        t._attempt += 1
                        if exc.__context__ is None and t._last_exc is not None:
                            exc.__context__ = t._last_exc
                        t._last_exc = exc
                        t._claim[:] = (0,)
                        t._started = False
                        t._timed_out = False
                        t.exception = None
                        self._retries += 1
                        if self._observers:
                            self._notify("on_retry", t, t._attempt, 0)
                        delay = pol.delay(t._attempt)
                        if delay > 0:
                            time.sleep(delay)
                        continue
                    if (
                        t._last_exc is not None
                        and exc.__context__ is None
                        and exc is not t._last_exc
                    ):
                        exc.__context__ = t._last_exc
                    t.exception = exc
                    if t.propagate_errors and self._first_error is None:
                        self._first_error = exc
                break
            self._executed += 1
            if self._observers:
                self._notify("on_finish", t, 0)
            if t.on_done is not None:
                try:
                    t.on_done(t)
                except BaseException:  # noqa: BLE001 - callback errors dropped
                    pass
            if t.auto_rearm:
                t.rearm()
            if rt is not None and rt.sub.tasks and t.exception is None:
                sub, join = splice_subflow(t, rt.sub)
                if not t.propagate_errors:
                    for st in sub + [join]:
                        st.propagate_errors = False
                t._spawned = sub
                roots = [s for s in sub if s.is_source]
                stack.extend(roots if roots else [join])
                continue
            if t.kind == "condition":
                branch = select_branch(t)
                if branch is not None:
                    stack.append(branch)
                continue
            for s in t.successors:
                if s.decrement():
                    stack.append(s)
        # the serial pool borrows the *caller's* thread: leave no dangling
        # checkpoint state behind for code running after the submission
        _current.task = None
