"""Baseline executors the paper's design is compared against.

The paper benchmarks its work-stealing pool against Taskflow (C++). Taskflow
is not available here, so EXPERIMENTS.md compares against the designs the
paper positions itself against in §1–2:

* :class:`NaiveThreadPool` — the "typical" pre-work-stealing design: a single
  mutex-protected global FIFO queue shared by all workers. Same Task-graph
  semantics (dependency counting), but every push/pop contends on one lock
  and there is no continuation passing — newly-ready successors are always
  re-queued.

* ``SerialExecutor`` — runs a task graph topologically on the calling thread;
  the zero-overhead floor for scheduling-overhead measurements.
"""
from __future__ import annotations

import threading
from collections import deque as _pydeque
from typing import Any, Callable, Iterable, Optional, Union

from .task import Task, iter_graph

__all__ = ["NaiveThreadPool", "SerialExecutor"]


class NaiveThreadPool:
    """Single locked global queue, no stealing, no continuation passing."""

    def __init__(self, num_threads: Optional[int] = None) -> None:
        import os

        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        self._q: _pydeque[Task] = _pydeque()
        self._cond = threading.Condition()
        self._unfinished = 0
        self._stop = False
        self._first_error: Optional[BaseException] = None
        self._threads = [
            threading.Thread(target=self._worker, name=f"naive-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def submit(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        if isinstance(work, Task):
            self._push(work)
        elif callable(work):
            self._push(Task(work))
        else:
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            for t in graph:
                if t.num_predecessors == 0:
                    self._push(t)

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        self.submit(work)
        self.wait_idle()

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        with self._cond:
            if not self._cond.wait_for(lambda: self._unfinished == 0, timeout):
                raise TimeoutError("pool did not become idle within timeout")
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err

    def close(self) -> None:
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "NaiveThreadPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- internals ------------------------------------------------------------

    def _push(self, task: Task) -> None:
        with self._cond:
            self._unfinished += 1
            self._q.append(task)
            self._cond.notify()

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop:
                    self._cond.wait()
                if self._stop:
                    return
                task = self._q.popleft()
            try:
                task.run()
            except BaseException as exc:  # noqa: BLE001
                task.exception = exc
                if task.propagate_errors:
                    with self._cond:
                        if self._first_error is None:
                            self._first_error = exc
            if task.on_done is not None:
                try:
                    task.on_done(task)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            ready = [s for s in task.successors if s.decrement()]
            with self._cond:
                for s in ready:
                    self._unfinished += 1
                    self._q.append(s)
                if ready:
                    self._cond.notify_all()
                self._unfinished -= 1
                if self._unfinished == 0:
                    self._cond.notify_all()


class SerialExecutor:
    """Topological execution on the calling thread (overhead floor)."""

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        if isinstance(work, Task):
            tasks = iter_graph([work])
        elif callable(work):
            Task(work).run()
            return
        else:
            tasks = iter_graph(list(work))
        for t in tasks:
            t.reset()
        stack = [t for t in tasks if t.num_predecessors == 0]
        while stack:
            t = stack.pop()
            t.run()
            if t.on_done is not None:
                try:
                    t.on_done(t)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            for s in t.successors:
                if s.decrement():
                    stack.append(s)

    def close(self) -> None:  # interface parity
        pass

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        pass

    def __enter__(self) -> "SerialExecutor":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass
