"""Baseline executors the paper's design is compared against.

The paper benchmarks its work-stealing pool against Taskflow (C++). Taskflow
is not available here, so EXPERIMENTS.md compares against the designs the
paper positions itself against in §1–2:

* :class:`NaiveThreadPool` — the "typical" pre-work-stealing design: a single
  mutex-protected global FIFO queue shared by all workers. Same Task-graph
  semantics (dependency counting), but every push/pop contends on one lock
  and there is no continuation passing — newly-ready successors are always
  re-queued.

* ``SerialExecutor`` — runs a task graph topologically on the calling thread;
  the zero-overhead floor for scheduling-overhead measurements.
"""
from __future__ import annotations

import threading
from collections import deque as _pydeque
from typing import Any, Callable, Iterable, Optional, Union

from .task import Task, iter_graph

__all__ = ["NaiveThreadPool", "SerialExecutor"]


class NaiveThreadPool:
    """Single locked global queue, no stealing, no continuation passing."""

    def __init__(self, num_threads: Optional[int] = None) -> None:
        import os

        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        self._q: _pydeque[Task] = _pydeque()
        self._cond = threading.Condition()
        self._unfinished = 0
        self._stop = False
        self._first_error: Optional[BaseException] = None
        self._threads = [
            threading.Thread(target=self._worker, name=f"naive-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    def submit(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        if isinstance(work, Task):
            self._push(work)
        elif callable(work):
            self._push(Task(work))
        else:
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            for t in graph:
                if t.is_source:
                    self._push(t)

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        self.submit(work)
        self.wait_idle()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """True once idle, False on timeout (matching ``ThreadPool``)."""
        with self._cond:
            if not self._cond.wait_for(lambda: self._unfinished == 0, timeout):
                return False
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err
        return True

    def close(self) -> None:
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()

    def __enter__(self) -> "NaiveThreadPool":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # -- internals ------------------------------------------------------------

    def _push(self, task: Task) -> None:
        with self._cond:
            self._unfinished += 1
            self._q.append(task)
            self._cond.notify()

    def _worker(self) -> None:
        while True:
            with self._cond:
                while not self._q and not self._stop:
                    self._cond.wait()
                if self._stop:
                    return
                task = self._q.popleft()
            try:
                task.run()
            except BaseException as exc:  # noqa: BLE001
                task.exception = exc
                if task.propagate_errors:
                    with self._cond:
                        if self._first_error is None:
                            self._first_error = exc
            if task.on_done is not None:
                try:
                    task.on_done(task)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            ready = [s for s in task.successors if s.decrement()]
            with self._cond:
                for s in ready:
                    self._unfinished += 1
                    self._q.append(s)
                if ready:
                    self._cond.notify_all()
                self._unfinished -= 1
                if self._unfinished == 0:
                    self._cond.notify_all()


class SerialExecutor:
    """Topological execution on the calling thread (overhead floor).

    Supports the §10 task kinds too — condition branches/loops and
    runtime-spawned subflows — so the serial floor exists for every
    benchmark shape. ``NaiveThreadPool`` deliberately does not: it models
    the pre-work-stealing static design the paper argues against.
    """

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        from .graph import (  # deferred: baseline stays below graph.py
            Runtime,
            select_branch,
            splice_subflow,
        )

        if isinstance(work, Task):
            tasks = iter_graph([work])
        elif callable(work):
            Task(work).run()
            return
        else:
            tasks = iter_graph(list(work))
        has_cond = False
        for t in tasks:
            t.reset()
            if t.kind == "condition":
                has_cond = True
        stack = [t for t in tasks if t.is_source]
        while stack:
            t = stack.pop()
            rt = Runtime(t) if t.takes_runtime else None
            t.run(rt)
            if t.on_done is not None:
                try:
                    t.on_done(t)
                except BaseException:  # noqa: BLE001 - observer errors dropped
                    pass
            if has_cond:
                t.rearm()  # single-threaded: re-arm unconditionally
            if rt is not None and rt.sub.tasks and t.exception is None:
                sub, join = splice_subflow(t, rt.sub)  # shared join protocol
                t._spawned = sub
                roots = [s for s in sub if s.is_source]
                stack.extend(roots if roots else [join])
                continue
            if t.kind == "condition":
                branch = select_branch(t)  # shared §10 selection rule
                if branch is not None:
                    stack.append(branch)
                continue
            for s in t.successors:
                if s.decrement():
                    stack.append(s)

    def close(self) -> None:  # interface parity
        pass

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        return True

    def __enter__(self) -> "SerialExecutor":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass
