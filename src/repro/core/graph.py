"""TaskGraph convenience container on top of ``task.py``.

The paper's API works on any iterable of ``Task`` objects;
:class:`TaskGraph` adds the bookkeeping a framework wants: named task
creation, cycle validation (Kahn), root discovery, DOT export, and
helpers to build common shapes (map/reduce, wavefronts) used by the data
pipeline, checkpointing and benchmarks.

Beyond the container (DESIGN.md §8), a ``TaskGraph`` is the unit of the
*dataflow runtime*:

* **value-passing pipelines** via :meth:`then` / :meth:`gather` — results
  flow along edges as ordered arguments instead of through captured
  closures (``task.py`` docs);
* **composition** via :meth:`compose` — a whole subgraph embeds as a
  module behind source/sink boundary tasks, with the sink gathering the
  subgraph's sink results as a list;
* **re-runnable lifecycle** — results are per-run state; :meth:`reset`
  re-arms every task (counters, results, cancellation), ``run_count``
  tracks submissions, and each :meth:`as_future` call returns a fresh
  future for that run. Build once, run N times.

Control flow (DESIGN.md §10) rides on the same container: condition tasks
(``add(fn, kind="condition")``) branch and may close cycles through weak
back-edges — :meth:`validate` permits exactly those cycles — and
``takes_runtime`` tasks receive a :class:`Runtime` handle to spawn joined
subflows sized by runtime data. ``as_future`` switches to counted
completion for graphs containing condition tasks (a hidden sink task
cannot terminate a graph whose branches legitimately never run).
"""
from __future__ import annotations

from collections import deque as _pydeque
from typing import Any, Callable, Iterator, Optional, Sequence

from .task import CancelledError, RetryPolicy, Task

__all__ = ["TaskGraph", "Module", "Runtime", "CycleError"]


class CycleError(ValueError):
    """The task graph contains a dependency cycle."""


class _FinTask(Task):
    """Hidden ``as_future`` completion task.

    A distinct type (not just a name convention) so sink detection,
    ``validate`` and external-task adoption can recognize *any* graph's
    completion task — including a stale one left wired by a previous
    anonymous wrapper graph — and never mistake it for a real successor.
    """

    __slots__ = ()


class Module:
    """Handle to a composed subgraph (see :meth:`TaskGraph.compose`).

    ``source`` runs before every root of the subgraph; ``sink`` runs after
    every sink of the subgraph and its *result* is the list of the
    subgraph sinks' results (in ``sub.tasks`` order). Wire the module into
    the outer graph through these two boundary tasks::

        m = outer.compose(sub)
        m.source.after(prepare)          # sub starts after `prepare`
        commit = outer.then(m.sink, fn)  # fn receives the gathered results
    """

    __slots__ = ("source", "sink", "sub")

    def __init__(self, source: Task, sink: Task, sub: "TaskGraph") -> None:
        self.source = source
        self.sink = sink
        self.sub = sub

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Module({self.sub.name!r}, tasks={len(self.sub)})"


class Runtime:
    """Handle passed to a ``takes_runtime`` task's body (DESIGN.md §10).

    The body builds a *subflow* through this handle — a fresh subgraph
    sized by data only known at execution time::

        def shard(rt: Runtime):
            writers = [rt.add(lambda p=p: write(p)) for p in discover()]
            return rt.gather(writers)   # spawner's value = gathered results

    After the body returns, the executor splices the subflow in: the
    subflow runs, a hidden join task waits on its sinks, and only then are
    the spawning task's successors released (**join-before-successor**).
    The first subflow failure is adopted as the spawner's exception, and a
    body returning one of its own subflow tasks is *unwrapped* — the
    spawner's dataflow value becomes that task's result, so downstream
    consumers receive plain values. The builder API mirrors
    :class:`TaskGraph`; tasks default to the spawner's priority band so a
    prioritized parent doesn't fan out at band 0.
    """

    __slots__ = ("task", "sub")

    def __init__(self, task: Task) -> None:
        self.task = task
        self.sub = TaskGraph(f"{task.name or 'task'}::subflow")

    def add(
        self,
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "",
        priority: Optional[float] = None,
        takes_inputs: bool = False,
        kind: str = "static",
        takes_runtime: bool = False,
        affinity: str = "any",
        retry: Optional[RetryPolicy] = None,
        timeout: Optional[float] = None,
        idempotent: bool = False,
    ) -> Task:
        """Spawn one subflow task. Nested ``takes_runtime`` spawners are
        supported, as is ``kind="condition"`` with two constraints: acyclic
        branching only (subflow tasks are not re-armed, so weak *cycles*
        must live in the outer graph), and branches must re-converge before
        the subflow's sinks (the hidden join waits on every sink — a sink
        reachable only through an untaken branch would never release it).
        ``retry``/``timeout``/``idempotent`` attach §14 fault-tolerance
        policy exactly as on :meth:`TaskGraph.add`."""
        t = self.sub.add(
            fn,
            name=name,
            priority=self.task.priority if priority is None else priority,
            takes_inputs=takes_inputs,
            kind=kind,
            takes_runtime=takes_runtime,
            affinity=affinity,
            retry=retry,
            timeout=timeout,
            idempotent=idempotent,
        )
        t._explicit_pr = self.task._explicit_pr if priority is None else True
        return t

    def then(self, predecessor: Task, fn: Callable[..., Any], *, name: str = "") -> Task:
        t = self.add(fn, name=name, takes_inputs=True)
        t.succeed(predecessor)
        return t

    def gather(
        self,
        predecessors: Sequence[Task],
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "gather",
    ) -> Task:
        collect = fn if fn is not None else (lambda *vs: list(vs))
        t = self.add(collect, name=name, takes_inputs=True)
        t.succeed(*predecessors)
        return t

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Runtime({self.task.name!r}, spawned={len(self.sub)})"


def select_branch(task: Task) -> Optional[Task]:
    """The §10 condition-selection rule (shared by ``ThreadPool`` and
    ``SerialExecutor``): a finished condition task releases the successor
    its integer result names, or nothing — on a failed/cancelled pass, a
    non-``int`` result, or an out-of-range index (the loop-exit idiom)."""
    sel = task.result if task.exception is None else None
    if isinstance(sel, bool):
        sel = int(sel)
    if isinstance(sel, int) and 0 <= sel < len(task.successors):
        return task.successors[sel]
    return None


def splice_subflow(spawner: Task, sub: "TaskGraph") -> tuple[list[Task], Task]:
    """Wire a spawned subflow's hidden join (shared by ``ThreadPool`` and
    ``SerialExecutor`` — the join-before-successor protocol lives here
    exactly once). Returns ``(subflow_tasks, join)``.

    The join takes over the spawner's successor list and waits strongly on
    every subflow sink; its completion callback *unwraps* a body that
    returned one of its own subflow tasks (the spawner's dataflow value
    becomes that task's result) and adopts the first subflow failure as
    the spawner's exception. The caller schedules the subflow's sources
    (or the join itself when there are none) and attaches any
    executor-specific state (run context, priority dispatch flags).
    """
    tasks = list(sub.tasks)
    join = Task(
        name=f"{spawner.name or 'task'}::join",
        priority=spawner.priority if spawner._explicit_pr else None,
    )
    join.propagate_errors = False
    join.successors = list(spawner.successors)
    join.after(*[t for t in tasks if not t.successors])

    def _finish_join(_j: Task) -> None:
        res = spawner.result
        if isinstance(res, Task) and res.graph is sub:
            spawner.result = res.result
        if spawner.exception is not None:
            return
        first_cancel: Optional[BaseException] = None
        for st in tasks:
            if st.exception is None:
                continue
            if not isinstance(st.exception, CancelledError):
                spawner.exception = st.exception
                return
            first_cancel = first_cancel or st.exception
        spawner.exception = first_cancel

    join.on_done = _finish_join
    return tasks, join


class TaskGraph:
    """Named container of :class:`Task` objects plus the dataflow runtime
    (module docs above).

    Build once, run N times — through an :class:`~repro.core.Executor`
    (any backend), a :class:`~repro.core.ThreadPool`, or serially::

        >>> from repro.core import Executor, TaskGraph
        >>> g = TaskGraph("pipeline")
        >>> a = g.add(lambda: 2, name="a")
        >>> b = g.add(lambda: 3, name="b")
        >>> total = g.gather([a, b], fn=lambda x, y: x + y, name="sum")
        >>> with Executor(backend="serial") as ex:
        ...     _ = ex.run(g).result(10)
        >>> total.result
        5

    Parameters
    ----------
    name:
        Label used in DOT exports, trace events and error messages.
    """

    def __init__(self, name: str = "") -> None:
        self.name = name
        self.tasks: list[Task] = []
        self._fin: Optional[Task] = None  # hidden as_future completion task
        self._sinks: dict[int, Task] = {}  # tasks currently wired into _fin
        self._run_count = 0
        self._num_conditions = 0
        # -- §12 capture & replay bookkeeping (replay.py). `_epoch` is the
        # structure fingerprint: every add/adopt/succeed/after bumps it.
        # `_settled_epoch` records the epoch as of the last completed live
        # submission — compilation waits for structure to settle so a plan
        # never captures a graph whose sink reconciliation hasn't run.
        self._epoch = 0
        self._settled_epoch = -1
        self._plan: Any = None
        # epoch as of the last `Executor(verify=...)` pass over this graph
        # (analysis/verify.py) — re-verification happens only on mutation
        self._verified_epoch: Optional[int] = None

    # -- construction -----------------------------------------------------------

    def add(
        self,
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "",
        priority: Optional[float] = None,
        takes_inputs: bool = False,
        kind: str = "static",
        takes_runtime: bool = False,
        affinity: str = "any",
        retry: Optional[RetryPolicy] = None,
        timeout: Optional[float] = None,
        idempotent: bool = False,
    ) -> Task:
        """Create a :class:`Task` owned by this graph and return it.

        Parameters mirror the ``Task`` constructor (``fn`` body, wiring
        happens afterwards via :meth:`Task.succeed` / :meth:`Task.after`):
        ``takes_inputs`` turns on dataflow argument delivery,
        ``kind="condition"`` makes a §10 branching task, ``takes_runtime``
        hands the body a :class:`Runtime` for subflow spawning, and
        ``affinity`` constrains §11 process-backend placement
        (``"any"`` / ``"local"`` / ``"remote"``). ``retry`` attaches a §14
        :class:`~repro.core.RetryPolicy`, ``timeout`` a per-attempt
        deadline, and ``idempotent`` marks the body safe to re-run after a
        started-but-lost §11 attempt. An omitted ``name`` defaults to
        ``t<index>``; an omitted ``priority`` is inheritable (see
        ``Task.priority``). Raises ``ValueError`` for an unknown
        ``kind``/``affinity`` or a condition task that takes a runtime.
        """
        t = Task(
            fn,
            name=name or f"t{len(self.tasks)}",
            priority=priority,
            takes_inputs=takes_inputs,
            kind=kind,
            takes_runtime=takes_runtime,
            affinity=affinity,
            retry=retry,
            timeout=timeout,
            idempotent=idempotent,
        )
        t.graph = self
        self.tasks.append(t)
        self._epoch += 1
        if t.is_condition:
            self._num_conditions += 1
        return t

    @property
    def has_conditions(self) -> bool:
        return self._num_conditions > 0

    def emplace_back(self, fn: Optional[Callable[[], Any]] = None) -> Task:
        """Paper-style alias (``tasks.emplace_back([...])``)."""
        return self.add(fn)

    def adopt(self, *tasks: Task) -> None:
        """Explicitly take ownership of externally-created tasks."""
        for t in tasks:
            if t.graph is not self:
                t.graph = self
            self.tasks.append(t)
            self._epoch += 1
            if t.is_condition:
                self._num_conditions += 1

    def map_reduce(
        self,
        map_fns: Sequence[Callable[[], Any]],
        reduce_fn: Callable[[], Any],
        *,
        name: str = "reduce",
    ) -> Task:
        """Fan-out/fan-in: ``reduce_fn`` runs after every mapped task."""
        mapped = [self.add(fn, name=f"map{i}") for i, fn in enumerate(map_fns)]
        red = self.add(reduce_fn, name=name)
        red.succeed(*mapped)
        return red

    def chain(self, fns: Sequence[Callable[[], Any]], *, name: str = "chain") -> list[Task]:
        """Sequential chain of tasks."""
        out: list[Task] = []
        for i, fn in enumerate(fns):
            t = self.add(fn, name=f"{name}{i}")
            if out:
                t.succeed(out[-1])
            out.append(t)
        return out

    # -- dataflow combinators ------------------------------------------------------

    def then(
        self,
        predecessor: Task,
        fn: Callable[..., Any],
        *,
        name: str = "",
        priority: Optional[float] = None,
    ) -> Task:
        """A new task receiving ``predecessor``'s result as its argument.

        Inherits ``predecessor``'s priority band unless one is given —
        the fix for continuations silently falling back to band 0.0.
        """
        t = self.add(
            fn,
            name=name,
            priority=predecessor.priority if priority is None else priority,
            takes_inputs=True,
        )
        t._explicit_pr = predecessor._explicit_pr if priority is None else True
        t.succeed(predecessor)
        return t

    def gather(
        self,
        predecessors: Sequence[Task],
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "gather",
        priority: Optional[float] = None,
    ) -> Task:
        """Join: a task receiving every predecessor's result, in order.

        With no ``fn`` the task simply collects the results into a list —
        the dataflow analogue of ``asyncio.gather``. With no explicit
        ``priority`` the join inherits the highest predecessor band (a
        join must not demote a prioritized fan-in).
        """
        collect = fn if fn is not None else (lambda *vs: list(vs))
        if priority is None:
            pr = max((p.priority for p in predecessors), default=0.0)
            explicit = any(p._explicit_pr for p in predecessors)
        else:
            pr, explicit = priority, True
        t = self.add(collect, name=name, priority=pr, takes_inputs=True)
        t._explicit_pr = explicit
        t.succeed(*predecessors)
        return t

    def compose(self, sub: "TaskGraph", *, name: str = "") -> Module:
        """Embed ``sub`` as a module with source/sink boundary tasks.

        The subgraph's tasks are adopted into this graph (they run, reset
        and cancel with it — do not submit ``sub`` separately afterwards).
        The boundary source precedes every root of ``sub`` with an
        ordering-only edge; the boundary sink gathers the results of every
        sink of ``sub`` as a list, so a composed module participates in
        value-passing like a single task.
        """
        label = name or sub.name or "sub"
        src = self.add(None, name=f"{label}::src")
        roots = sub.roots()
        sinks = [t for t in sub.tasks if not t.successors]
        for r in roots:
            r.after(src)
        self.adopt(*sub.tasks)
        snk = self.gather(sinks, name=f"{label}::sink")
        # sink > source even when `sub` is empty, so downstream consumers
        # can never overtake the module's upstream ordering edges
        snk.after(src)
        return Module(src, snk, sub)

    # -- execution ----------------------------------------------------------------

    @property
    def run_count(self) -> int:
        """How many times this graph has been submitted (``as_future`` or
        ``ThreadPool.submit``)."""
        return self._run_count

    def reset(self) -> None:
        """Re-arm every task (and the hidden completion task) for a fresh
        run: counters, per-run results/exceptions and cancellation flags.

        ``ThreadPool.submit`` re-arms counters itself; explicit ``reset``
        exists so a partially-cancelled or failed graph can be returned to
        a clean slate before resubmission.
        """
        for t in self.tasks:
            t.reset()
        if self._fin is not None:
            self._fin.reset()

    def _notify_submitted(self) -> None:
        """Called by ``ThreadPool.submit`` when the graph is submitted."""
        self._run_count += 1

    # -- §12 capture & replay ------------------------------------------------------

    @property
    def replay_plan(self):
        """The compiled §12 :class:`~repro.core.ReplayPlan`, or ``None``
        when the graph has not yet settled (or was invalidated)."""
        return self._plan

    def invalidate_plan(self) -> None:
        """Drop the compiled replay plan explicitly.

        The next submission dispatches live and a fresh plan compiles once
        the structure settles again. Needed only for mutations the epoch
        fingerprint cannot see — e.g. rebinding ``task.fn`` on a §11
        process backend wants re-wiring semantics decided here (plan
        re-arm does refresh wires every pass, so plain ``fn`` rebinding is
        already safe; use this as the explicit escape hatch for anything
        else out-of-band).
        """
        self._plan = None

    def _mark_plan_diverged(self) -> None:
        p = self._plan
        if p is not None:
            p.diverged = True

    def _usable_plan(self, pool):
        """Return a plan ready to replay on ``pool``, compiling one when
        the structure has settled; an invalidated plan (mutated graph,
        divergence, different pool) is dropped so the caller takes the
        live path — whose full per-task reset clears any stale state —
        and the next settled submission recompiles."""
        plan = self._plan
        if plan is not None:
            if plan.usable(pool, self._epoch):
                return plan
            self._plan = None
            return None
        if self._run_count >= 1 and self._epoch == self._settled_epoch:
            from .replay import compile_plan, replay_eligible

            if replay_eligible(pool):
                self._plan = compile_plan(self, pool)
                return self._plan
        return None

    def as_future(self, pool, *, replay: bool = True) -> "Future":  # noqa: F821
        """Submit the whole graph and return a :class:`~repro.core.Future`.

        The future resolves to ``None`` when every task has completed, or to
        the first task exception if the graph failed. ``future.cancel()``
        cooperatively cancels every task that has not started yet (running
        bodies finish; dependencies still drain so the pool stays clean).

        One hidden completion task is kept per graph; sink membership is
        *tracked* across calls — a task that gains a real successor after a
        previous round is unwired from the completion task, and new sinks
        are wired in — so build-once / ``as_future``-per-round submission
        neither accumulates bookkeeping nor retires on stale edges. Rounds
        must be sequential (task state is shared across submissions, as
        with plain ``submit``).

        A graph containing **condition tasks** switches to counted
        completion (DESIGN.md §10): branches legitimately never run and
        weak cycles re-run tasks, so "every sink finished" is not a
        termination signal — instead the run resolves when its in-flight
        task count drains to zero.

        **Replay (DESIGN.md §12)** is on by default: once the graph's
        structure has settled over one live run, subsequent calls dispatch
        from the compiled :class:`~repro.core.ReplayPlan` — skipping the
        per-task reset walk, sink reconciliation and live fan-out. Any
        divergence (mutation, cancellation, a failed pass, a different
        pool) transparently falls back to live dispatch and recompiles on
        the next settled run. ``replay=False`` forces live dispatch for
        one call without dropping the plan.
        """
        from .pool import Future  # local import: graph.py must not cycle

        if self._num_conditions:
            return self._as_future_counted(pool, replay=replay)
        plan = self._usable_plan(pool) if replay else None
        if plan is not None:
            return self._replay_dag(pool, plan)
        if self._fin is None:
            # Priority 0.0, deliberately: the completion task is only ever
            # ready once every sink has finished, so boosting it buys
            # nothing — while any non-zero priority would permanently
            # promote the pool's deques to banded mode and forfeit the
            # single-band fast path (DESIGN.md §9) for priority-free
            # dataflow graphs. When it is the lone newly-ready successor
            # the fused fan-out runs it inline regardless.
            self._fin = _FinTask(name=f"{self.name or 'graph'}::done")
            self._fin.propagate_errors = False
        fin = self._fin
        # Reconcile tracked sink membership with the current topology. A
        # completion task of ANY graph (type check, not identity) is never
        # a real successor — a stale one from a previous wrapper graph
        # must not hide a sink (it would resolve the future at submit).
        current = {
            id(t): t
            for t in self.tasks
            if not any(not isinstance(s, _FinTask) for s in t.successors)
        }
        # Fin edges are submission bookkeeping, not user structure: wiring
        # them must not move the §12/§15 epoch fingerprint (a first-run
        # bump would force one spurious re-verify and re-settle per graph).
        epoch0 = self._epoch
        for tid, t in list(self._sinks.items()):
            if tid not in current:  # gained a real successor since last round
                t.successors.remove(fin)
                fin.num_predecessors -= 1
                del self._sinks[tid]
        for tid, t in current.items():
            if tid not in self._sinks:
                fin.after(t)
                self._sinks[tid] = t
        self._epoch = epoch0
        graph_tasks = list(self.tasks)

        def _canceller() -> bool:
            # cancellation consumes claims mid-run: any compiled plan is
            # state-divergent now and must fall back to live dispatch
            self._mark_plan_diverged()
            won = fin.cancel()
            for t in graph_tasks:
                t.cancel()
                for st in t._spawned or ():  # in-flight subflow tasks too
                    st.cancel()
            return won

        fut = Future(canceller=_canceller)

        def _resolve(_t: Task) -> None:
            cancelled_exc: Optional[BaseException] = None
            for t in graph_tasks:
                if t.exception is not None:
                    if not isinstance(t.exception, CancelledError):
                        fut.set_exception(t.exception)
                        return
                    # Explicit cancel OR a body skipped because the pool was
                    # poisoned by an unrelated failure — either way the graph
                    # did not run; never report success.
                    cancelled_exc = t.exception
            if cancelled_exc is not None or any(t.cancelled for t in graph_tasks):
                fut.set_exception(cancelled_exc or CancelledError("task graph cancelled"))
                return
            fut.set_result(None)

        fin.on_done = _resolve
        pool.submit(list(self.tasks) + [fin])
        self._run_count += 1
        self._settled_epoch = self._epoch  # structure settled: §12 may compile
        return fut

    def _replay_dag(self, pool, plan) -> "Future":  # noqa: F821 - forward ref
        """Replay submission for plain-DAG graphs (DESIGN.md §12): fresh
        future + resolver, plan re-arm instead of the O(n) reset walk,
        pre-bound roots instead of source discovery. Topology is unchanged
        by fingerprint, so sink reconciliation is skipped entirely."""
        from .pool import Future  # local import: graph.py must not cycle

        fin = self._fin
        graph_tasks = plan.scan_tasks

        def _canceller() -> bool:
            plan.diverged = True  # claims consumed mid-run: next pass is live
            won = fin.cancel()
            for t in graph_tasks:
                t.cancel()
                for st in t._spawned or ():
                    st.cancel()
            return won

        fut = Future(canceller=_canceller)

        def _resolve(_t: Task) -> None:
            cancelled_exc: Optional[BaseException] = None
            for t in graph_tasks:
                if t.exception is not None:
                    if not isinstance(t.exception, CancelledError):
                        plan.diverged = True
                        fut.set_exception(t.exception)
                        return
                    cancelled_exc = t.exception
            if cancelled_exc is not None or any(t.cancelled for t in graph_tasks):
                plan.diverged = True
                fut.set_exception(cancelled_exc or CancelledError("task graph cancelled"))
                return
            fut.set_result(None)

        fin.on_done = _resolve
        plan.rearm()
        self._run_count += 1
        plan.schedule(pool)
        return fut

    def _as_future_counted(self, pool, *, replay: bool = True) -> "Future":  # noqa: F821
        """Counted-completion submission (condition graphs, DESIGN.md §10).

        A :class:`~repro.core.pool.RunContext` counts scheduled-but-
        unfinished tasks of this run; the worker that drains the count to
        zero resolves the future. Subflow tasks spawned during the run are
        counted (and cancelled) through the same context.

        Replay (§12) composes: condition branch targets are pre-bound weak
        meta-edges, so a loop that branches *differently* between passes
        (serve ticks, prefetch lanes) keeps one plan — the context simply
        counts meta nodes instead of member tasks, and loop members
        self-re-arm inside their segment.
        """
        from .pool import Future, RunContext  # local import: no cycle

        plan = self._usable_plan(pool) if replay else None
        if plan is not None:
            graph_tasks = plan.scan_tasks

            def _plan_canceller() -> bool:
                plan.diverged = True  # claims consumed mid-run: next pass live
                won = False
                for t in graph_tasks:
                    if t.cancel():
                        won = True
                    for st in t._spawned or ():
                        if st.cancel():
                            won = True
                return won

            fut = Future(canceller=_plan_canceller)

            def _resolve_replayed() -> None:
                cancelled_exc: Optional[BaseException] = None
                saw_cancel = False
                for t in graph_tasks:
                    spawned = t._spawned or ()
                    for x in (t, *spawned):
                        if x.exception is not None:
                            if not isinstance(x.exception, CancelledError):
                                plan.diverged = True
                                fut.set_exception(x.exception)
                                return
                            cancelled_exc = x.exception
                        saw_cancel = saw_cancel or x.cancelled
                if cancelled_exc is not None or saw_cancel:
                    plan.diverged = True
                    fut.set_exception(
                        cancelled_exc or CancelledError("task graph cancelled")
                    )
                    return
                fut.set_result(None)

            ctx = RunContext(_resolve_replayed)
            plan.rearm()
            self._run_count += 1
            ctx.update(len(plan.roots))
            plan.schedule(pool, ctx)
            return fut

        graph_tasks = list(self.tasks)

        def _canceller() -> bool:
            self._mark_plan_diverged()  # claims consumed: plan is stale now
            won = False
            for t in graph_tasks:
                if t.cancel():
                    won = True
                for st in t._spawned or ():
                    if st.cancel():
                        won = True
            return won

        fut = Future(canceller=_canceller)

        def _resolve_counted() -> None:
            cancelled_exc: Optional[BaseException] = None
            saw_cancel = False
            for t in graph_tasks:
                spawned = t._spawned or ()
                for x in (t, *spawned):
                    if x.exception is not None:
                        if not isinstance(x.exception, CancelledError):
                            fut.set_exception(x.exception)
                            return
                        cancelled_exc = x.exception
                    saw_cancel = saw_cancel or x.cancelled
            if cancelled_exc is not None or saw_cancel:
                fut.set_exception(cancelled_exc or CancelledError("task graph cancelled"))
                return
            fut.set_result(None)

        ctx = RunContext(_resolve_counted)
        self._run_count += 1
        submitted = pool._submit_with_context(graph_tasks, ctx)
        self._settled_epoch = self._epoch  # structure settled: §12 may compile
        if not submitted:
            _resolve_counted()  # nothing to run: resolve immediately
        return fut

    # -- inspection ---------------------------------------------------------------

    def roots(self) -> list[Task]:
        """Source tasks: no in-edges of either strength (weak in-edges
        are excluded too — a weak-only target is released by its condition
        at runtime, never at submission)."""
        return [t for t in self.tasks if t.is_source]

    def edges(self) -> list[tuple[Task, Task, bool]]:
        """Every edge as ``(pred, succ, strong)`` in declaration order.

        The strength column encodes the §10 rule the scheduler itself
        uses: *all* out-edges of a condition task are weak (no countdown
        token; successor position is the branch index), all out-edges of
        any other task are strong. Edges to another graph's hidden
        completion task are omitted — bookkeeping, not structure. This is
        the introspection surface the :mod:`repro.analysis` verifier walks
        so lint rules never reimplement edge-strength semantics.
        """
        out: list[tuple[Task, Task, bool]] = []
        for t in self.tasks:
            strong = not t.is_condition
            for s in t.successors:
                if isinstance(s, _FinTask):
                    continue
                out.append((t, s, strong))
        return out

    def find_strong_cycle(self) -> Optional[list[Task]]:
        """Return one cycle of **strong** edges as a task path (first task
        repeated at the end), or ``None`` when every cycle is closed only
        by weak condition branches.

        This is the analysis companion to :meth:`validate`: the same
        Kahn-on-strong-in-degrees walk, but instead of a count it names
        the offending tasks. The cycle found is walked from an arbitrary
        unfinished task along strong successors, so for tangled graphs it
        is *a* witness cycle, not necessarily the only one.
        """
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        remaining = {id(t): t for t in self.tasks}
        while q:
            t = q.popleft()
            remaining.pop(id(t), None)
            if t.is_condition:
                continue  # weak out-edges never contributed to in-degrees
            for s in t.successors:
                if id(s) not in indeg:
                    continue
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        # Every task left has an unfinished strong predecessor, so following
        # strong in-edges inside `remaining` must revisit a node: a cycle.
        for start in remaining.values():
            path: list[Task] = []
            seen: dict[int, int] = {}
            t: Optional[Task] = start
            while t is not None and id(t) not in seen:
                seen[id(t)] = len(path)
                path.append(t)
                t = next(
                    (
                        p
                        for p in remaining.values()
                        if not p.is_condition and t in p.successors
                    ),
                    None,
                )
            if t is not None:  # closed a strong cycle
                cyc = path[seen[id(t)] :]
                cyc.reverse()  # we walked in-edges; report in edge direction
                # rotate to start at the earliest-declared member, so the
                # reported path is deterministic for a given build order
                order = {id(x): i for i, x in enumerate(self.tasks)}
                k = min(range(len(cyc)), key=lambda i: order[id(cyc[i])])
                cyc = cyc[k:] + cyc[:k]
                return cyc + [cyc[0]]
        return None

    def validate(self) -> None:
        """Raise :class:`CycleError` unless every cycle is condition-closed.

        Tasks reachable through successor edges but missing from the
        container are first collected, then adopted explicitly via
        :meth:`adopt` *before* the Kahn walk — validation never mutates
        ``self.tasks`` mid-iteration (the hidden ``as_future`` completion
        task is exempt: it is bookkeeping, not part of the user's graph).

        The Kahn walk counts **strong** in-degrees only; a condition
        task's out-edges are weak (no countdown contribution), so a cycle
        closed by a weak back-edge — the §10 retry/convergence loop — is
        legal, while a cycle of strong edges still fails.
        """
        known = {id(t) for t in self.tasks}
        externals: list[Task] = []
        stack = list(self.tasks)
        while stack:
            t = stack.pop()
            for s in t.successors:
                if isinstance(s, _FinTask) or id(s) in known:
                    continue
                known.add(id(s))
                externals.append(s)
                stack.append(s)
        if externals:
            self.adopt(*externals)
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        visited = 0
        while q:
            t = q.popleft()
            visited += 1
            if t.is_condition:
                continue  # weak out-edges never contributed to in-degrees
            for s in t.successors:
                if id(s) not in indeg:  # hidden completion task
                    continue
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        if visited != len(self.tasks):
            cycle = self.find_strong_cycle()
            path = (
                " -> ".join(t.name or f"t{i}" for i, t in enumerate(cycle))
                if cycle
                else "<no witness cycle found>"
            )
            raise CycleError(
                f"task graph {self.name!r}: {len(self.tasks) - visited} task(s) "
                f"unreachable from roots — strong dependency cycle: {path}"
            )

    def critical_path(self, cost: Callable[[Task], float] = lambda _t: 1.0) -> float:
        """Length of the longest dependency chain (lower bound on makespan)."""
        self.validate()
        order = self._topo_order()
        dist = {id(t): cost(t) for t in order}
        for t in order:
            for s in t.successors:
                if id(s) in dist:
                    dist[id(s)] = max(dist[id(s)], dist[id(t)] + cost(s))
        return max(dist.values(), default=0.0)

    def _topo_order(self) -> list[Task]:
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        order: list[Task] = []
        while q:
            t = q.popleft()
            order.append(t)
            if t.is_condition:
                continue  # weak edges carry no in-degree
            for s in t.successors:
                if id(s) not in indeg:
                    continue
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        return order

    def to_dot(self) -> str:
        """DOT export. Condition tasks render as diamonds with **dashed**
        branch edges (labelled by branch index); each ``takes_runtime``
        task's last-observed subflow renders as a ``cluster`` subgraph
        hanging off its spawner by a dotted edge — so a trace of a
        branching, dynamically-fanned run stays readable."""
        lines = [f'digraph "{self.name or "taskgraph"}" {{']
        idx = {id(t): i for i, t in enumerate(self.tasks)}
        next_id = len(self.tasks)
        clusters: list[tuple[Task, list[Task]]] = []
        for t in self.tasks:
            shape = ', shape=diamond' if t.is_condition else ""
            lines.append(f'  n{idx[id(t)]} [label="{t.name}"{shape}];')
            if t._spawned:
                clusters.append((t, t._spawned))
        for spawner, spawned in clusters:
            lines.append(f'  subgraph "cluster_{idx[id(spawner)]}" {{')
            lines.append(f'    label="{spawner.name}::subflow"; style=dashed;')
            for st in spawned:
                if id(st) not in idx:
                    idx[id(st)] = next_id
                    next_id += 1
                lines.append(f'    n{idx[id(st)]} [label="{st.name}"];')
            lines.append("  }")
            for st in spawned:
                if st.is_source:
                    lines.append(
                        f"  n{idx[id(spawner)]} -> n{idx[id(st)]} [style=dotted];"
                    )
        for t in list(self.tasks) + [st for _, sp in clusters for st in sp]:
            style = ' [style=dashed, label="{}"]' if t.is_condition else ""
            for branch, s in enumerate(t.successors):
                if id(s) not in idx:
                    continue
                attr = style.format(branch) if style else ""
                lines.append(f"  n{idx[id(t)]} -> n{idx[id(s)]}{attr};")
        lines.append("}")
        return "\n".join(lines)

    # -- protocol ------------------------------------------------------------------

    def __iter__(self) -> Iterator[Task]:
        return iter(self.tasks)

    def __len__(self) -> int:
        return len(self.tasks)
