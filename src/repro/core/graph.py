"""TaskGraph convenience container on top of ``task.py``.

The paper's API works on any iterable of ``Task`` objects;
:class:`TaskGraph` adds the bookkeeping a framework wants: named task
creation, cycle validation (Kahn), root discovery, DOT export, and
helpers to build common shapes (map/reduce, wavefronts) used by the data
pipeline, checkpointing and benchmarks.
"""
from __future__ import annotations

from collections import deque as _pydeque
from typing import Any, Callable, Iterator, Optional, Sequence

from .task import CancelledError, Task

__all__ = ["TaskGraph", "CycleError"]


class CycleError(ValueError):
    """The task graph contains a dependency cycle."""


class TaskGraph:
    def __init__(self, name: str = "") -> None:
        self.name = name
        self.tasks: list[Task] = []
        self._fin: Optional[Task] = None  # hidden as_future completion task
        self._fin_pred_ids: set[int] = set()  # tasks already wired into _fin

    # -- construction -----------------------------------------------------------

    def add(
        self,
        fn: Optional[Callable[[], Any]] = None,
        *,
        name: str = "",
        priority: float = 0.0,
    ) -> Task:
        t = Task(fn, name=name or f"t{len(self.tasks)}", priority=priority)
        self.tasks.append(t)
        return t

    def emplace_back(self, fn: Optional[Callable[[], Any]] = None) -> Task:
        """Paper-style alias (``tasks.emplace_back([...])``)."""
        return self.add(fn)

    def map_reduce(
        self,
        map_fns: Sequence[Callable[[], Any]],
        reduce_fn: Callable[[], Any],
        *,
        name: str = "reduce",
    ) -> Task:
        """Fan-out/fan-in: ``reduce_fn`` runs after every mapped task."""
        mapped = [self.add(fn, name=f"map{i}") for i, fn in enumerate(map_fns)]
        red = self.add(reduce_fn, name=name)
        red.succeed(*mapped)
        return red

    def chain(self, fns: Sequence[Callable[[], Any]], *, name: str = "chain") -> list[Task]:
        """Sequential chain of tasks."""
        out: list[Task] = []
        for i, fn in enumerate(fns):
            t = self.add(fn, name=f"{name}{i}")
            if out:
                t.succeed(out[-1])
            out.append(t)
        return out

    # -- execution ----------------------------------------------------------------

    def as_future(self, pool) -> "Future":  # noqa: F821 - forward ref (pool.py)
        """Submit the whole graph and return a :class:`~repro.core.Future`.

        The future resolves to ``None`` when every task has completed, or to
        the first task exception if the graph failed. ``future.cancel()``
        cooperatively cancels every task that has not started yet (running
        bodies finish; dependencies still drain so the pool stays clean).

        One hidden completion task is kept per graph and re-wired as sinks
        change, so build-once / ``as_future``-per-round submission does not
        accumulate bookkeeping. Rounds must be sequential (task state is
        shared across submissions, as with plain ``submit``).
        """
        from .pool import Future  # local import: graph.py must not cycle

        if self._fin is None:
            self._fin = Task(name=f"{self.name or 'graph'}::done", priority=float("inf"))
            self._fin.propagate_errors = False
        fin = self._fin
        new_sinks = [
            t
            for t in self.tasks
            if id(t) not in self._fin_pred_ids
            and all(s is fin for s in t.successors)
        ]
        if new_sinks:
            fin.succeed(*new_sinks)
            self._fin_pred_ids.update(id(t) for t in new_sinks)
        graph_tasks = list(self.tasks)

        def _canceller() -> bool:
            won = fin.cancel()
            for t in graph_tasks:
                t.cancel()
            return won

        fut = Future(canceller=_canceller)

        def _resolve(_t: Task) -> None:
            for t in graph_tasks:
                if t.exception is not None and not isinstance(t.exception, CancelledError):
                    fut.set_exception(t.exception)
                    return
            if any(t.cancelled for t in graph_tasks):
                fut.set_exception(CancelledError("task graph cancelled"))
                return
            fut.set_result(None)

        fin.on_done = _resolve
        pool.submit(list(self.tasks) + [fin])
        return fut

    # -- inspection ---------------------------------------------------------------

    def roots(self) -> list[Task]:
        return [t for t in self.tasks if t.num_predecessors == 0]

    def validate(self) -> None:
        """Raise :class:`CycleError` unless the graph is a DAG (Kahn)."""
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        known = set(indeg)
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        visited = 0
        while q:
            t = q.popleft()
            visited += 1
            for s in t.successors:
                if id(s) not in known:  # successor outside this container
                    known.add(id(s))
                    indeg[id(s)] = s.num_predecessors
                    self.tasks.append(s)
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        if visited != len(self.tasks):
            raise CycleError(
                f"task graph {self.name!r}: {len(self.tasks) - visited} task(s) "
                "unreachable from roots — dependency cycle"
            )

    def critical_path(self, cost: Callable[[Task], float] = lambda _t: 1.0) -> float:
        """Length of the longest dependency chain (lower bound on makespan)."""
        self.validate()
        order = self._topo_order()
        dist = {id(t): cost(t) for t in order}
        for t in order:
            for s in t.successors:
                if id(s) in dist:
                    dist[id(s)] = max(dist[id(s)], dist[id(t)] + cost(s))
        return max(dist.values(), default=0.0)

    def _topo_order(self) -> list[Task]:
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        order: list[Task] = []
        while q:
            t = q.popleft()
            order.append(t)
            for s in t.successors:
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        return order

    def to_dot(self) -> str:
        lines = [f'digraph "{self.name or "taskgraph"}" {{']
        idx = {id(t): i for i, t in enumerate(self.tasks)}
        for t in self.tasks:
            lines.append(f'  n{idx[id(t)]} [label="{t.name}"];')
        for t in self.tasks:
            for s in t.successors:
                if id(s) in idx:
                    lines.append(f"  n{idx[id(t)]} -> n{idx[id(s)]};")
        lines.append("}")
        return "\n".join(lines)

    # -- protocol ------------------------------------------------------------------

    def __iter__(self) -> Iterator[Task]:
        return iter(self.tasks)

    def __len__(self) -> int:
        return len(self.tasks)
