"""TaskGraph convenience container on top of ``task.py``.

The paper's API works on any iterable of ``Task`` objects;
:class:`TaskGraph` adds the bookkeeping a framework wants: named task
creation, cycle validation (Kahn), root discovery, DOT export, and
helpers to build common shapes (map/reduce, wavefronts) used by the data
pipeline, checkpointing and benchmarks.

Beyond the container (DESIGN.md §8), a ``TaskGraph`` is the unit of the
*dataflow runtime*:

* **value-passing pipelines** via :meth:`then` / :meth:`gather` — results
  flow along edges as ordered arguments instead of through captured
  closures (``task.py`` docs);
* **composition** via :meth:`compose` — a whole subgraph embeds as a
  module behind source/sink boundary tasks, with the sink gathering the
  subgraph's sink results as a list;
* **re-runnable lifecycle** — results are per-run state; :meth:`reset`
  re-arms every task (counters, results, cancellation), ``run_count``
  tracks submissions, and each :meth:`as_future` call returns a fresh
  future for that run. Build once, run N times.
"""
from __future__ import annotations

from collections import deque as _pydeque
from typing import Any, Callable, Iterator, Optional, Sequence

from .task import CancelledError, Task

__all__ = ["TaskGraph", "Module", "CycleError"]


class CycleError(ValueError):
    """The task graph contains a dependency cycle."""


class Module:
    """Handle to a composed subgraph (see :meth:`TaskGraph.compose`).

    ``source`` runs before every root of the subgraph; ``sink`` runs after
    every sink of the subgraph and its *result* is the list of the
    subgraph sinks' results (in ``sub.tasks`` order). Wire the module into
    the outer graph through these two boundary tasks::

        m = outer.compose(sub)
        m.source.after(prepare)          # sub starts after `prepare`
        commit = outer.then(m.sink, fn)  # fn receives the gathered results
    """

    __slots__ = ("source", "sink", "sub")

    def __init__(self, source: Task, sink: Task, sub: "TaskGraph") -> None:
        self.source = source
        self.sink = sink
        self.sub = sub

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Module({self.sub.name!r}, tasks={len(self.sub)})"


class TaskGraph:
    def __init__(self, name: str = "") -> None:
        self.name = name
        self.tasks: list[Task] = []
        self._fin: Optional[Task] = None  # hidden as_future completion task
        self._sinks: dict[int, Task] = {}  # tasks currently wired into _fin
        self._run_count = 0

    # -- construction -----------------------------------------------------------

    def add(
        self,
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "",
        priority: float = 0.0,
        takes_inputs: bool = False,
    ) -> Task:
        t = Task(
            fn,
            name=name or f"t{len(self.tasks)}",
            priority=priority,
            takes_inputs=takes_inputs,
        )
        t.graph = self
        self.tasks.append(t)
        return t

    def emplace_back(self, fn: Optional[Callable[[], Any]] = None) -> Task:
        """Paper-style alias (``tasks.emplace_back([...])``)."""
        return self.add(fn)

    def adopt(self, *tasks: Task) -> None:
        """Explicitly take ownership of externally-created tasks."""
        for t in tasks:
            if t.graph is not self:
                t.graph = self
            self.tasks.append(t)

    def map_reduce(
        self,
        map_fns: Sequence[Callable[[], Any]],
        reduce_fn: Callable[[], Any],
        *,
        name: str = "reduce",
    ) -> Task:
        """Fan-out/fan-in: ``reduce_fn`` runs after every mapped task."""
        mapped = [self.add(fn, name=f"map{i}") for i, fn in enumerate(map_fns)]
        red = self.add(reduce_fn, name=name)
        red.succeed(*mapped)
        return red

    def chain(self, fns: Sequence[Callable[[], Any]], *, name: str = "chain") -> list[Task]:
        """Sequential chain of tasks."""
        out: list[Task] = []
        for i, fn in enumerate(fns):
            t = self.add(fn, name=f"{name}{i}")
            if out:
                t.succeed(out[-1])
            out.append(t)
        return out

    # -- dataflow combinators ------------------------------------------------------

    def then(
        self,
        predecessor: Task,
        fn: Callable[..., Any],
        *,
        name: str = "",
        priority: float = 0.0,
    ) -> Task:
        """A new task receiving ``predecessor``'s result as its argument."""
        t = self.add(fn, name=name, priority=priority, takes_inputs=True)
        t.succeed(predecessor)
        return t

    def gather(
        self,
        predecessors: Sequence[Task],
        fn: Optional[Callable[..., Any]] = None,
        *,
        name: str = "gather",
        priority: float = 0.0,
    ) -> Task:
        """Join: a task receiving every predecessor's result, in order.

        With no ``fn`` the task simply collects the results into a list —
        the dataflow analogue of ``asyncio.gather``.
        """
        collect = fn if fn is not None else (lambda *vs: list(vs))
        t = self.add(collect, name=name, priority=priority, takes_inputs=True)
        t.succeed(*predecessors)
        return t

    def compose(self, sub: "TaskGraph", *, name: str = "") -> Module:
        """Embed ``sub`` as a module with source/sink boundary tasks.

        The subgraph's tasks are adopted into this graph (they run, reset
        and cancel with it — do not submit ``sub`` separately afterwards).
        The boundary source precedes every root of ``sub`` with an
        ordering-only edge; the boundary sink gathers the results of every
        sink of ``sub`` as a list, so a composed module participates in
        value-passing like a single task.
        """
        label = name or sub.name or "sub"
        src = self.add(None, name=f"{label}::src")
        roots = sub.roots()
        sinks = [t for t in sub.tasks if not t.successors]
        for r in roots:
            r.after(src)
        self.adopt(*sub.tasks)
        snk = self.gather(sinks, name=f"{label}::sink")
        # sink > source even when `sub` is empty, so downstream consumers
        # can never overtake the module's upstream ordering edges
        snk.after(src)
        return Module(src, snk, sub)

    # -- execution ----------------------------------------------------------------

    @property
    def run_count(self) -> int:
        """How many times this graph has been submitted (``as_future`` or
        ``ThreadPool.submit``)."""
        return self._run_count

    def reset(self) -> None:
        """Re-arm every task (and the hidden completion task) for a fresh
        run: counters, per-run results/exceptions and cancellation flags.

        ``ThreadPool.submit`` re-arms counters itself; explicit ``reset``
        exists so a partially-cancelled or failed graph can be returned to
        a clean slate before resubmission.
        """
        for t in self.tasks:
            t.reset()
        if self._fin is not None:
            self._fin.reset()

    def _notify_submitted(self) -> None:
        """Called by ``ThreadPool.submit`` when the graph is submitted."""
        self._run_count += 1

    def as_future(self, pool) -> "Future":  # noqa: F821 - forward ref (pool.py)
        """Submit the whole graph and return a :class:`~repro.core.Future`.

        The future resolves to ``None`` when every task has completed, or to
        the first task exception if the graph failed. ``future.cancel()``
        cooperatively cancels every task that has not started yet (running
        bodies finish; dependencies still drain so the pool stays clean).

        One hidden completion task is kept per graph; sink membership is
        *tracked* across calls — a task that gains a real successor after a
        previous round is unwired from the completion task, and new sinks
        are wired in — so build-once / ``as_future``-per-round submission
        neither accumulates bookkeeping nor retires on stale edges. Rounds
        must be sequential (task state is shared across submissions, as
        with plain ``submit``).
        """
        from .pool import Future  # local import: graph.py must not cycle

        if self._fin is None:
            # Priority 0.0, deliberately: the completion task is only ever
            # ready once every sink has finished, so boosting it buys
            # nothing — while any non-zero priority would permanently
            # promote the pool's deques to banded mode and forfeit the
            # single-band fast path (DESIGN.md §9) for priority-free
            # dataflow graphs. When it is the lone newly-ready successor
            # the fused fan-out runs it inline regardless.
            self._fin = Task(name=f"{self.name or 'graph'}::done")
            self._fin.propagate_errors = False
        fin = self._fin
        # Reconcile tracked sink membership with the current topology.
        current = {
            id(t): t
            for t in self.tasks
            if not any(s is not fin for s in t.successors)
        }
        for tid, t in list(self._sinks.items()):
            if tid not in current:  # gained a real successor since last round
                t.successors.remove(fin)
                fin.num_predecessors -= 1
                del self._sinks[tid]
        for tid, t in current.items():
            if tid not in self._sinks:
                fin.after(t)
                self._sinks[tid] = t
        graph_tasks = list(self.tasks)

        def _canceller() -> bool:
            won = fin.cancel()
            for t in graph_tasks:
                t.cancel()
            return won

        fut = Future(canceller=_canceller)

        def _resolve(_t: Task) -> None:
            cancelled_exc: Optional[BaseException] = None
            for t in graph_tasks:
                if t.exception is not None:
                    if not isinstance(t.exception, CancelledError):
                        fut.set_exception(t.exception)
                        return
                    # Explicit cancel OR a body skipped because the pool was
                    # poisoned by an unrelated failure — either way the graph
                    # did not run; never report success.
                    cancelled_exc = t.exception
            if cancelled_exc is not None or any(t.cancelled for t in graph_tasks):
                fut.set_exception(cancelled_exc or CancelledError("task graph cancelled"))
                return
            fut.set_result(None)

        fin.on_done = _resolve
        pool.submit(list(self.tasks) + [fin])
        self._run_count += 1
        return fut

    # -- inspection ---------------------------------------------------------------

    def roots(self) -> list[Task]:
        return [t for t in self.tasks if t.num_predecessors == 0]

    def validate(self) -> None:
        """Raise :class:`CycleError` unless the graph is a DAG (Kahn).

        Tasks reachable through successor edges but missing from the
        container are first collected, then adopted explicitly via
        :meth:`adopt` *before* the Kahn walk — validation never mutates
        ``self.tasks`` mid-iteration (the hidden ``as_future`` completion
        task is exempt: it is bookkeeping, not part of the user's graph).
        """
        fin = self._fin
        known = {id(t) for t in self.tasks}
        externals: list[Task] = []
        stack = list(self.tasks)
        while stack:
            t = stack.pop()
            for s in t.successors:
                if s is fin or id(s) in known:
                    continue
                known.add(id(s))
                externals.append(s)
                stack.append(s)
        if externals:
            self.adopt(*externals)
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        visited = 0
        while q:
            t = q.popleft()
            visited += 1
            for s in t.successors:
                if id(s) not in indeg:  # hidden completion task
                    continue
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        if visited != len(self.tasks):
            raise CycleError(
                f"task graph {self.name!r}: {len(self.tasks) - visited} task(s) "
                "unreachable from roots — dependency cycle"
            )

    def critical_path(self, cost: Callable[[Task], float] = lambda _t: 1.0) -> float:
        """Length of the longest dependency chain (lower bound on makespan)."""
        self.validate()
        order = self._topo_order()
        dist = {id(t): cost(t) for t in order}
        for t in order:
            for s in t.successors:
                if id(s) in dist:
                    dist[id(s)] = max(dist[id(s)], dist[id(t)] + cost(s))
        return max(dist.values(), default=0.0)

    def _topo_order(self) -> list[Task]:
        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = _pydeque(t for t in self.tasks if t.num_predecessors == 0)
        order: list[Task] = []
        while q:
            t = q.popleft()
            order.append(t)
            for s in t.successors:
                if id(s) not in indeg:
                    continue
                indeg[id(s)] -= 1
                if indeg[id(s)] == 0:
                    q.append(s)
        return order

    def to_dot(self) -> str:
        lines = [f'digraph "{self.name or "taskgraph"}" {{']
        idx = {id(t): i for i, t in enumerate(self.tasks)}
        for t in self.tasks:
            lines.append(f'  n{idx[id(t)]} [label="{t.name}"];')
        for t in self.tasks:
            for s in t.successors:
                if id(s) in idx:
                    lines.append(f"  n{idx[id(t)]} -> n{idx[id(s)]};")
        lines.append("}")
        return "\n".join(lines)

    # -- protocol ------------------------------------------------------------------

    def __iter__(self) -> Iterator[Task]:
        return iter(self.tasks)

    def __len__(self) -> int:
        return len(self.tasks)
