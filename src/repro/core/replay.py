"""Graph capture & replay: compiled steady-state dispatch (DESIGN.md §12).

The §9 scheduler pays full dependency-counting dispatch on every pass —
per-task claim, fan-out decrement, inline pick, idle check — yet the
dominant workloads (serve decode ticks, prefetch lanes, checkpoint shards,
training steps) re-run the *same* graph shape thousands of times. This
module compiles a settled :class:`~repro.core.TaskGraph` into a
:class:`ReplayPlan`: a **shadow meta-graph** of :class:`_SegTask` nodes,
each wrapping a maximal fused chain of member tasks, wired among
themselves with the ordinary countdown machinery. Replaying a pass then
dispatches O(#segments) scheduler events instead of O(#tasks) — a
chain(8192) collapses to a single meta node whose body is one tight
member loop.

Design rules (the ones that make this safe, in order of importance):

* **User tasks are never rewired.** The plan wraps; it does not mutate
  ``successors``/``inputs``/``num_predecessors`` of any member. Live
  dispatch of the same graph therefore stays valid at all times — a
  dropped plan falls back to ``ThreadPool.submit``'s ordinary walk with
  zero repair work, and plan compilation may even overlap a running pass
  (it only reads structure).

* **Fusion is structural, not trace-based.** Member ``v`` fuses behind
  ``u`` iff ``u`` is static, not a spawner, and has exactly one successor
  ``v`` whose only in-edge is that one (no weak in-edges), with equal
  ``priority`` and ``propagate_errors``. A condition task may terminate a
  segment (the meta node becomes ``kind="condition"`` and copies the
  tail's integer verdict, so ``select_branch`` picks among *pre-bound*
  weak meta-edges); ``takes_runtime`` spawners are forced singletons.
  Because branch targets are ordinary meta successors, a condition that
  **branches differently** between passes replays natively — the branch
  table subsumes outcome matching, which is what lets the serve tick and
  prefetch lanes (whose loop counts change every pass) keep one plan.

* **The countdown flattens.** Interior members (in-degree 1 by the fusion
  rule) are never decremented under replay; only meta nodes carry live
  countdowns, re-armed from per-plan prototype tuples. Plan re-arm —
  the replacement for ``TaskGraph.reset()``'s O(n) walk plus per-task
  ``reset()`` at submit — is a slim slice-assign loop: members get claim
  + flags only (``run()`` clears stale results/exceptions itself), metas
  get the prototype refill.

* **Segments run through the ordinary pool.** ``_SegTask`` goes through
  ``_schedule``/``_execute``/``_finish_slow`` unchanged; its ``run()``
  override executes the member protocol inline: claim race, poison
  check, §11 ``_offload`` per member, observer ``on_start``/``on_finish``
  per member, ``on_done`` callbacks, loop-mode ``rearm()``. Observer
  streams therefore stay truthful per *member* (the pool routes queue
  events to ``seg.first`` and suppresses seg-level start/finish).

* **Divergence falls back, then self-heals.** The fingerprint is the
  graph's ``_epoch`` counter (bumped by every ``add``/``adopt``/
  ``succeed``/``after``) plus pool identity plus a divergence flag set by
  cancellation or a failed pass. An unusable plan is dropped at
  submission: that pass dispatches live (whose full reset clears stale
  exceptions/claims), and the next settled pass recompiles.

* **§11 composition.** On a process backend, plan re-arm refreshes the
  members' body wires through the pool's ``_wire_tasks`` seam every pass
  — identical placement semantics to live submission (rebinding
  ``task.fn`` between passes stays correct on both backends). Spawner
  members replay as live singleton islands: the meta proxies the member
  body, the subflow splices fresh each pass (runtime-sized shape changes
  are absorbed, not invalidated), and the hidden join releases the
  spawner's *meta* successors.
"""
from __future__ import annotations

import time
from typing import Any, Optional

from .pool import ThreadPool, _current, _Retry
from .task import CancelledError, Task, TaskTimeoutError, iter_graph

__all__ = ["ReplayPlan", "compile_plan", "replay_eligible"]

_CLAIM = (0,)


class _SegTask(Task):
    """One replay meta node: a fused run of 1..k member tasks.

    Scheduled and fanned out by the ordinary pool machinery; ``run()``
    executes every member inline (module docs). ``first`` is the head
    member — the pool substitutes it in queue-side observer events so
    traces and counters name real tasks, never plan internals.
    """

    __slots__ = ("steps", "first", "_pool", "_rearm_members", "_resume_at")

    _seg = True

    def __init__(self, steps: list, pool: "ThreadPool", *, loop_mode: bool) -> None:
        head, tail = steps[0], steps[-1]
        super().__init__(
            None,
            name=f"replay:{head.name or 'seg'}",
            priority=head.priority,
            kind="condition" if tail.kind == "condition" else "static",
            takes_runtime=head.takes_runtime,
        )
        self._explicit_pr = head._explicit_pr
        self.propagate_errors = head.propagate_errors
        self.steps = steps
        self.first = head
        self._pool = pool
        # loop mode (counted/condition graphs): members self-rearm after
        # each pass so a weak meta back-edge finds them armed, and the meta
        # re-arms through the ordinary auto_rearm protocol in _finish_slow.
        self._rearm_members = loop_mode
        self.auto_rearm = loop_mode
        if loop_mode:
            self._slow = True
        # §14: after a retriable member failure the segment requeues itself
        # and resumes at the failed member — earlier members never re-run.
        self._resume_at = 0

    def run(self, runtime: Any = None, invoke: Any = None) -> None:
        if runtime is not None:
            # spawner proxy (singleton segment): the wrapped member runs
            # with the Runtime so results/exceptions/_spawned land where
            # dataflow consumers and the graph resolver read them; the
            # verdict is mirrored onto the meta because _finish_slow's
            # splice guard and the hidden join's unwrap operate on the
            # dispatched task (this node).
            inner = self.first
            inner._spawned = runtime.sub.tasks
            try:
                inner.run(runtime)
            except BaseException as exc:
                if inner.exception is None:
                    inner.exception = exc
                raise
            finally:
                self.result = inner.result
                self.exception = inner.exception
            return
        try:
            self._claim.pop()
        except IndexError:  # defensive: mirrors Task.run's cancel arm
            self.exception = CancelledError("task cancelled")
            self._done = True
            return
        self._started = True
        self.exception = None
        pool = self._pool
        index = pool._tls.index
        off = pool._offload
        observers = pool._observers
        rearm = self._rearm_members
        steps = self.steps
        start = self._resume_at
        if start:  # resuming a §14 retried pass mid-segment
            self._resume_at = 0
            steps_iter = steps[start:]
        else:
            steps_iter = steps
        for t in steps_iter:
            if observers:
                pool._notify("on_start", t, index)
            if t.timeout is not None:  # §14 member deadline (rare branch)
                _current.task = t
                _current.deadline = time.monotonic() + t.timeout
            try:
                if pool._first_error is not None and t.propagate_errors:
                    # fail-fast parity with _execute: skip bodies once the
                    # graph is poisoned, keep draining so waiters unblock
                    t.exception = CancelledError("predecessor failed")
                    t._done = True
                elif off is not None:
                    off(t, index)  # §11 seam: per-member placement
                else:
                    t.run()
            except BaseException as exc:  # noqa: BLE001 - recorded, pool-funneled
                if isinstance(exc, TaskTimeoutError):
                    pool._timeouts[index] += 1
                    if observers:
                        pool._notify("on_timeout", t, index)
                pol = pool._retry_policy_for(t, exc)
                if (
                    pol is not None
                    and not (getattr(exc, "started", False) and not t.idempotent)
                    and t._attempt + 1 < pol.max_attempts
                ):
                    # §14 member retry: re-arm the member and the segment,
                    # record the resume point, and signal _execute to
                    # requeue this node whole. Members before `t` stay
                    # completed; a retried-to-success pass leaves no trace
                    # (the plan stays valid, no divergence).
                    t._attempt += 1
                    if exc.__context__ is None and t._last_exc is not None:
                        exc.__context__ = t._last_exc
                    t._last_exc = exc
                    t._claim[:] = _CLAIM
                    t._started = False
                    t._timed_out = False
                    t.exception = None
                    self._resume_at = steps.index(t)
                    self._claim[:] = _CLAIM
                    self._started = False
                    pool._retries[index] += 1
                    if observers:
                        pool._notify("on_retry", t, t._attempt, index)
                    pool._executed[index] += steps.index(t) - start
                    raise _Retry(pol.delay(t._attempt)) from None
                if (
                    t._last_exc is not None
                    and exc.__context__ is None
                    and exc is not t._last_exc
                ):  # exhausted retries surface the whole attempt chain
                    exc.__context__ = t._last_exc
                t.exception = exc
                if t.propagate_errors:
                    with pool._err_lock:
                        if pool._first_error is None:
                            pool._first_error = exc
            if observers:
                pool._notify("on_finish", t, index)
            cb = t.on_done
            if cb is not None:
                try:
                    cb(t)
                except BaseException:  # noqa: BLE001 - callback errors dropped
                    pass
            if rearm:
                t.rearm()
        # the pool's _execute adds 1 for this node; members make up the rest
        pool._executed[index] += len(steps_iter) - 1
        if self.kind == "condition":
            # select_branch reads the dispatched task: surface the tail's
            # integer verdict (None on a failed/cancelled pass — no branch)
            tail = steps[-1]
            self.result = None if tail.exception is not None else tail.result
        self._done = True


class ReplayPlan:
    """Compiled replay schedule for one (graph, pool) pairing.

    ``usable`` gates every submission: same pool, same structure epoch,
    never diverged. ``rearm`` + ``schedule`` replace the live path's
    O(n) reset walk and source discovery. ``replays`` counts completed
    arm/schedule cycles — tests and consumers use it to *demonstrate*
    that a pass replayed (or fell back).
    """

    __slots__ = (
        "pool",
        "epoch",
        "metas",
        "roots",
        "members",
        "scan_tasks",
        "counted",
        "diverged",
        "replays",
        "_arm",
    )

    def __init__(
        self,
        pool: "ThreadPool",
        epoch: int,
        metas: list,
        roots: list,
        members: list,
        scan_tasks: list,
        counted: bool,
    ) -> None:
        self.pool = pool
        self.epoch = epoch
        self.metas = metas
        self.roots = roots
        self.members = members  # every live task the plan re-arms (incl. fin)
        self.scan_tasks = scan_tasks  # resolver scan set (= graph.tasks snapshot)
        self.counted = counted
        self.diverged = False
        self.replays = 0
        self._arm = [(m, tuple(range(m.num_predecessors))) for m in metas]

    @property
    def segments(self) -> int:
        return len(self.metas)

    @property
    def fused(self) -> int:
        """Members that cost no scheduler dispatch under replay."""
        return len(self.members) - len(self.metas)

    def usable(self, pool: Any, epoch: int) -> bool:
        return not self.diverged and pool is self.pool and epoch == self.epoch

    def rearm(self) -> None:
        """Re-arm every member and meta for the next pass (module docs).

        Members get claim + flags only — ``run()`` clears stale
        results/exceptions at body start, and interior countdowns are
        never popped under replay. On a §11 backend the members' body
        wires are refreshed first, so replay keeps live submission's
        placement semantics exactly.
        """
        wire = self.pool._wire_tasks
        if wire is not None:
            wire(self.members)
        for t in self.members:
            t._claim[:] = _CLAIM
            t._done = False
            t._started = False
            t._cancelled = False
            if t._attempt:  # §14: fresh retry budget per pass (rare branch)
                t._attempt = 0
                t._last_exc = None
        for m, proto in self._arm:
            m._pending[:] = proto
            m._claim[:] = _CLAIM
            m._done = False
            m._started = False
            m._resume_at = 0  # §14 invariant: consumed by pass end; defensive

    def schedule(self, pool: "ThreadPool", ctx: Any = None) -> None:
        """Dispatch the pre-bound roots (counted runs bind ``ctx`` to the
        metas first; the caller has already counted the roots in)."""
        self.replays += 1
        if ctx is not None:
            for m in self.metas:
                m.ctx = ctx
        for r in self.roots:
            pool._schedule(r)


def replay_eligible(pool: Any) -> bool:
    """Plans dispatch through the §9 worker protocol: any ``ThreadPool``
    (the §11 ``ProcessPool`` included), never the serial baselines."""
    return isinstance(pool, ThreadPool) and not pool._stop


def compile_plan(graph: Any, pool: "ThreadPool") -> Optional[ReplayPlan]:
    """Compile ``graph``'s settled structure into a :class:`ReplayPlan`.

    Works over the same reachable closure live submission walks (the
    hidden ``as_future`` completion task included), so plan and live
    dispatch agree on exactly which tasks a pass runs. Returns ``None``
    for shapes that cannot replay (empty graph, wiring that escapes the
    closure, no sources).
    """
    nodes = iter_graph(list(graph.tasks))
    if not nodes:
        return None
    loop_mode = graph._num_conditions > 0
    node_ids = {id(t) for t in nodes}

    # -- chain contraction: mark every fusable edge u -> v ------------------
    absorbed: set = set()
    fused_next: dict = {}
    for u in nodes:
        if u.kind != "static" or u.takes_runtime or len(u.successors) != 1:
            continue
        v = u.successors[0]
        if (
            v is u
            or id(v) not in node_ids
            or v.takes_runtime
            or v.num_predecessors != 1
            or v.num_weak_predecessors != 0
            or v.propagate_errors != u.propagate_errors
            or v.priority != u.priority
        ):
            continue
        absorbed.add(id(v))
        fused_next[id(u)] = v

    # -- build segments from every unabsorbed head --------------------------
    head_meta: dict = {}
    metas: list = []
    for t in nodes:
        if id(t) in absorbed:
            continue
        steps = [t]
        cur = t
        while True:
            nxt = fused_next.get(id(cur))
            if nxt is None:
                break
            steps.append(nxt)
            cur = nxt
        m = _SegTask(steps, pool, loop_mode=loop_mode)
        head_meta[id(t)] = m
        metas.append(m)

    # -- wire the shadow graph: every tail out-edge targets a head ----------
    # (an interior member's single out-edge is its own fusion edge, so a
    # tail's successors are heads by construction; edge multiplicity and
    # branch-index order are preserved verbatim)
    for m in metas:
        tail = m.steps[-1]
        weak = m.kind == "condition"
        for s in tail.successors:
            n = head_meta.get(id(s))
            if n is None:
                return None  # wiring escapes the captured closure
            m.successors.append(n)
            if weak:
                n.num_weak_predecessors += 1
            else:
                n.num_predecessors += 1

    roots = [m for m in metas if m.is_source]
    if not roots:
        return None
    return ReplayPlan(
        pool=pool,
        epoch=graph._epoch,
        metas=metas,
        roots=roots,
        members=nodes,
        scan_tasks=list(graph.tasks),
        counted=loop_mode,
    )
