"""Deterministic fault injection for the §14 fault-tolerance layer.

:class:`FaultInjector` hooks the same backend seams ``ProcessPool`` uses
(DESIGN.md §11): it wraps the pool's ``_offload`` body-dispatch hook to
perturb body execution, and chains ``_wire_tasks`` so injection composes
with process-backend wiring. Faults are decided by a **stable keyed hash**
— ``blake2b(f"{seed}:{task.name}:{occurrence}")`` mapped to a uniform
float in [0, 1) — not by Python's per-process-salted ``hash()`` and not by
shared-stream ``random.Random`` draws, so the schedule of injected faults
for a given seed is identical across runs, across backends, and across
interleavings: the *k*-th execution of task ``"load:3"`` either always
faults or never does, no matter which worker runs it or in what order.

Three fault kinds, each gated by an independent rate:

* **fail** — raise :class:`ChaosError` at the dispatch seam, *before* the
  body runs (the body never partially executes, so injected failures are
  always safe to retry);
* **delay** — sleep ``delay_s`` at the seam, then run the body normally
  (exercises timeout deadlines and backoff-vs-progress interleavings);
* **kill** — on a ``ProcessPool``, kill the worker process about to run
  the body (the real broken-pipe → respawn → ``WorkerDiedError`` path);
  on thread/serial backends, raise a synthetic pre-start
  ``WorkerDiedError(started=False)`` so the same retry semantics are
  exercised without a process to kill.

The injector records every decision in :meth:`schedule` — chaos tests
assert that two runs with the same seed produce byte-identical schedules
— and doubles as a pool observer counting the retries/timeouts its faults
provoked. Use :meth:`install` / :meth:`uninstall` (or the context manager
form) around a run::

    inj = FaultInjector(seed=7, fail_rate=0.2)
    with inj.on(pool):
        pool.run(graph)
    assert inj.schedule() == expected
"""
from __future__ import annotations

import hashlib
import threading
import time
from typing import Any, Callable, Optional

from .task import Task

__all__ = ["ChaosError", "FaultInjector"]

_DENOM = float(1 << 64)


class ChaosError(RuntimeError):
    """An injected (synthetic) body failure from :class:`FaultInjector`."""


def _roll(seed: int, name: str, occ: int, salt: str) -> float:
    """Deterministic uniform [0,1) draw keyed on (seed, task, occurrence).

    Stable across processes and backends — unlike ``hash()`` (per-process
    salt) or a shared ``random.Random`` stream (interleaving-dependent).
    """
    h = hashlib.blake2b(
        f"{seed}:{salt}:{name}:{occ}".encode(), digest_size=8
    ).digest()
    return int.from_bytes(h, "big") / _DENOM


class FaultInjector:
    """Seeded, deterministic fault injection through the §11 pool seams.

    Parameters
    ----------
    seed:
        Keys every fault decision; same seed ⇒ same schedule, everywhere.
    fail_rate, delay_rate, kill_rate:
        Independent per-body-execution probabilities (evaluated in that
        order; at most one fault fires per execution).
    delay_s:
        Sleep injected by a **delay** fault.
    match:
        Optional predicate ``fn(task) -> bool`` restricting injection
        (e.g. only ``name.startswith("flaky:")``). Control-flow bodies
        (conditions, spawners) are never injected — they drive the
        scheduler itself, and ``ProcessPool`` never offloads them either.
    """

    def __init__(
        self,
        seed: int,
        *,
        fail_rate: float = 0.0,
        delay_rate: float = 0.0,
        kill_rate: float = 0.0,
        delay_s: float = 0.005,
        match: Optional[Callable[[Task], bool]] = None,
    ) -> None:
        self.seed = seed
        self.fail_rate = fail_rate
        self.delay_rate = delay_rate
        self.kill_rate = kill_rate
        self.delay_s = delay_s
        self.match = match
        self._lock = threading.Lock()
        self._occ: dict[str, int] = {}
        self._log: list[tuple[str, int, str]] = []
        self._pool: Any = None
        self._inner: Any = None
        # observer side: §14 events provoked (or not) by the injection
        self.retries = 0
        self.timeouts = 0

    # -- install / uninstall --------------------------------------------------

    def install(self, pool: Any) -> None:
        """Wrap ``pool._offload`` (keeping any inner backend offload, e.g.
        ``ProcessPool._offload_body``) and attach as an observer."""
        if self._pool is not None:
            raise RuntimeError("FaultInjector is already installed on a pool")
        self._pool = pool
        self._inner = pool._offload
        pool._offload = self._offload
        pool.add_observer(self)

    def uninstall(self) -> None:
        """Restore the wrapped seams (no-op if not installed)."""
        pool, self._pool = self._pool, None
        if pool is None:
            return
        pool._offload = self._inner
        self._inner = None
        pool.remove_observer(self)

    class _On:
        def __init__(self, inj: "FaultInjector", pool: Any) -> None:
            self._inj, self._pool = inj, pool

        def __enter__(self) -> "FaultInjector":
            self._inj.install(self._pool)
            return self._inj

        def __exit__(self, *exc: object) -> None:
            self._inj.uninstall()

    def on(self, pool: Any) -> "FaultInjector._On":
        """Context-manager form: ``with inj.on(pool): ...``."""
        return self._On(self, pool)

    # -- the dispatch seam ----------------------------------------------------

    def _decide(self, name: str) -> tuple[Optional[str], int]:
        """One decision per body execution, keyed on the per-name
        occurrence counter (the only mutable state, under a lock)."""
        with self._lock:
            occ = self._occ.get(name, 0)
            self._occ[name] = occ + 1
        kind: Optional[str] = None
        if self.fail_rate and _roll(self.seed, name, occ, "fail") < self.fail_rate:
            kind = "fail"
        elif self.delay_rate and _roll(self.seed, name, occ, "delay") < self.delay_rate:
            kind = "delay"
        elif self.kill_rate and _roll(self.seed, name, occ, "kill") < self.kill_rate:
            kind = "kill"
        if kind is not None:
            with self._lock:
                self._log.append((name, occ, kind))
        return kind, occ

    def _offload(self, task: Task, index: int) -> None:
        inner = self._inner
        if task._slow and (task.is_condition or task.takes_runtime):
            # control-flow bodies are never injected (module docs)
            if inner is not None:
                inner(task, index)
            else:
                task.run()
            return
        if self.match is not None and not self.match(task):
            kind = None
        else:
            kind, _occ = self._decide(task.name or repr(task))
        if kind == "fail":
            raise ChaosError(f"injected failure in {task.name!r}")
        if kind == "delay":
            time.sleep(self.delay_s)
        elif kind == "kill":
            self._kill(task, index)
        if inner is not None:
            inner(task, index)
        else:
            task.run()

    def _kill(self, task: Task, index: int) -> None:
        """Worker loss: real process kill on the process/socket backends
        (the body's dispatch then hits a dead transport), synthetic
        pre-start ``WorkerDiedError`` elsewhere — including socket slots
        bound to *remote* workers (``_procs[index] is None``), where there
        is no local process to kill — same §14 retry semantics every way."""
        from repro.dist.process_pool import WorkerDiedError  # lazy: no dist dep

        pool = self._pool
        procs = getattr(pool, "_procs", None)
        if (
            procs is not None
            and index is not None
            and 0 <= index < len(procs)
            and procs[index] is not None
        ):
            try:
                procs[index].kill()
                procs[index].join()  # transport dead before dispatch: the
                return  # offload below deterministically fails pre-start
            except ValueError:  # the pool retired this process under us
                pass
        raise WorkerDiedError(
            f"injected worker loss before {task.name!r} started", started=False
        )

    # -- observability --------------------------------------------------------

    def schedule(self) -> list[tuple[str, int, str]]:
        """The injected-fault log: ``(task name, occurrence, kind)``,
        sorted by (name, occurrence). The *decisions* are deterministic
        per (seed, name, occurrence); the order workers reach them is not
        — sorting makes the schedule comparable across runs, backends and
        interleavings."""
        with self._lock:
            return sorted(self._log)

    def counts(self) -> dict[str, int]:
        """Injected-fault totals by kind."""
        out = {"fail": 0, "delay": 0, "kill": 0}
        for _name, _occ, kind in self.schedule():
            out[kind] += 1
        return out

    # observer protocol (§8): count the fault handling we provoked
    def on_submit(self, task: Task) -> None:  # pragma: no cover - no-op
        pass

    def on_start(self, task: Task, worker: int) -> None:  # pragma: no cover
        pass

    def on_finish(self, task: Task, worker: int) -> None:  # pragma: no cover
        pass

    def on_steal(self, task: Task, thief: int, victim: int) -> None:  # pragma: no cover
        pass

    def on_retry(self, task: Task, attempt: int, worker: int) -> None:
        self.retries += 1

    def on_timeout(self, task: Task, worker: int) -> None:
        self.timeouts += 1
