"""Work-stealing thread pool capable of running task graphs (paper §2).

Faithful Python adaptation of the paper's C++ design:

* one work-stealing deque per worker thread (``deque.py``);
* the current worker's deque is found through a **thread-local** variable
  (the paper's replacement for thread-ID→index maps, §2.1);
* a task submitted *from* a worker thread is pushed to that worker's own
  deque (depth-first, cache-friendly); tasks submitted from outside land in a
  shared MPMC inbox (Chase-Lev deques are single-producer — see deque.py);
* idle workers first pop their own deque, then drain the inbox, then sweep
  the other workers' deques stealing from the top, then park;
* task-graph execution by dependency counting (§2.2): when a task body
  completes, every successor's pending-predecessor counter is decremented;
  **one** newly-ready successor is executed inline on the same worker
  (continuation passing), the others are pushed.

Beyond the paper (DESIGN.md §3): task **priorities** — own-deque pops, inbox
draining, steals and the inline-continuation pick are all priority-aware
(highest band first; LIFO within a band on the owner's side, FIFO on the
thief/inbox side), the same ready-key the schedule simulator uses — and
**cooperative cancellation** surfaced through :class:`Future` and
``TaskGraph.as_future``. Both exist for the serving engine: decode ticks run
at high priority, speculative prefills at low priority, and aborted requests
cancel their in-flight work.

Also beyond the paper (DESIGN.md §8): an **observer layer**. Attached
observers (``core/observer.py``) see submit/start/finish/steal lifecycle
events, which is how the aggregate-stats and Chrome-trace exporters watch a
run without the pool knowing about either.

**Hot-path discipline (DESIGN.md §9).** The task path takes no locks:

* *idle accounting* is GIL-atomic per-worker claimed/completed cells summed
  only when an idle check is actually needed — ``wait_idle`` waiters pay
  for quiescence detection, the task path pays one falsy flag check;
* *wakeups are targeted*: idle workers spin briefly then park on a
  per-worker event after registering in a parked-worker deque; a submitter
  pops **one** sleeper and sets its event (no condition-variable notify
  storm, no poll tax), woken workers chain further wakeups while surplus
  work remains, and ``close()`` sets every event so shutdown is prompt;
* *fan-out is allocation-free*: a fused decrement-and-pick loop over
  ``task.successors`` keeps the running max-priority successor as the
  inline continuation and pushes the rest directly onto the worker's own
  deque — no ready list, no ``max(..., key=...)``, one batch wakeup.

**Control flow (DESIGN.md §10).** Condition tasks, weak-edge cycles, and
runtime-spawned subflows dispatch through a *slow fan-out* path selected by
one per-task flag check (``task._slow``); plain DAG tasks keep the fused
§9 loop untouched. Slow-path tasks re-arm themselves **before** releasing
any successor (so a weak back-edge can legally re-trigger them), a
condition's integer result picks exactly one weak successor, a spawner's
subflow is spliced in behind a hidden join task that inherits the
spawner's successors, and a per-run :class:`RunContext` counts in-flight
tasks so graphs whose branches never run (or that loop) still terminate
their futures deterministically.

**Fault tolerance (DESIGN.md §14).** A task carrying a
:class:`~repro.core.RetryPolicy` whose body fails with a matching exception
is re-armed and re-scheduled through the same §9 fast path — backoff is a
pool-timed deferred requeue on a lazy timer thread, so no worker ever
sleeps it off. Per-task ``timeout=`` deadlines are cooperative here
(bodies observe them at :func:`checkpoint`); ``ProcessPool`` escalates to
a hard worker kill. Retried-then-succeeded passes never poison the pool
(``_first_error``) or diverge a §12 replay plan — only the *final* failure
surfaces, carrying earlier attempts on its ``__context__`` chain.

Differences from the C++ original are documented in DESIGN.md §2.1.
"""
from __future__ import annotations

import heapq
import os
import threading
import time
from collections import deque as _pydeque
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .deque import EMPTY, ChaseLevDeque, FastDeque, PriorityDeque
from .graph import Runtime, select_branch, splice_subflow
from .task import CancelledError, Task, TaskTimeoutError, iter_graph

__all__ = ["ThreadPool", "Future", "RunContext", "checkpoint"]

_SPIN_SWEEPS = 2  # extra full sweeps (with GIL yields) before parking
_PARK_BACKSTOP_S = 0.5  # safety net only; targeted wakeups are the fast path

# Cooperative checkpoint state: the executing worker publishes its current
# task (and the attempt's absolute deadline) here around every body call,
# on every backend. Two plain stores — no tuple allocation on the hot path.
_current = threading.local()


def checkpoint() -> None:
    """Cooperative cancellation / timeout checkpoint (DESIGN.md §14).

    Long-running task bodies call this periodically. It raises
    :class:`~repro.core.CancelledError` if the task was cancelled after it
    started, and :class:`~repro.core.TaskTimeoutError` once the attempt's
    ``timeout=`` deadline has passed. Outside a task body (or inside a
    ``ProcessPool`` worker process, where the parent-side deadline is not
    visible) it is a no-op — bodies stay portable across backends.
    """
    task = getattr(_current, "task", None)
    if task is None:
        return
    if task._cancel_req:
        raise CancelledError(f"task {task.name!r} cancelled at checkpoint")
    deadline = _current.deadline
    if deadline is not None and time.monotonic() >= deadline:
        task._timed_out = True
        raise TaskTimeoutError(
            f"task {task.name!r} exceeded its {task.timeout}s timeout"
        )


class _Retry(BaseException):
    """Internal §14 signal: a §12 segment member failed retriably; the
    segment has re-armed itself (``_resume_at`` set) and must be requeued
    after ``delay`` seconds. ``BaseException`` so body-level ``except
    Exception`` handlers can never swallow it."""

    __slots__ = ("delay",)

    def __init__(self, delay: float) -> None:
        self.delay = delay


class _Timer:
    """Lazy pool timer: one daemon thread draining a monotonic-deadline heap.

    Serves both §14 uses — deferred retry requeues (backoff without a
    sleeping worker) and hard-timeout watchdog callbacks (``ProcessPool``).
    Created on first use, so pools that never retry or time out pay
    nothing. Entries are ``(when, seq, fn)``; cancellation is lazy — an
    expired callback re-checks whether its target is still relevant.
    """

    def __init__(self, name: str) -> None:
        self._cv = threading.Condition()
        self._heap: list = []
        self._seq = 0
        self._stop = False
        self._thread = threading.Thread(
            target=self._run, name=f"{name}-timer", daemon=True
        )
        self._thread.start()

    def add(self, when: float, fn: Callable[[], None]) -> None:
        with self._cv:
            self._seq += 1
            heapq.heappush(self._heap, (when, self._seq, fn))
            self._cv.notify()

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify()
        self._thread.join()

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._stop:
                    if self._heap:
                        delay = self._heap[0][0] - time.monotonic()
                        if delay <= 0:
                            break
                        self._cv.wait(delay)
                    else:
                        self._cv.wait()
                if self._stop:
                    return
                _, _, fn = heapq.heappop(self._heap)
            try:
                fn()
            except BaseException:  # noqa: BLE001 - timer callbacks never die
                pass


class RunContext:
    """Counted completion for one graph run (DESIGN.md §10).

    ``active`` is the number of scheduled-but-unfinished tasks of the run.
    A submitter counts every root *before* scheduling any of them; a worker
    finishing a task folds its whole fan-out into one ``update(delta)``
    with ``delta = successors_scheduled - 1`` — and crucially applies it
    *before* pushing those successors, so a successor completing on
    another worker can never observe a transiently-zero count. The caller
    that drains ``active`` to zero fires ``on_quiet`` exactly once.

    Only counted runs (condition graphs, executor-managed submissions) pay
    this lock; the plain DAG path never allocates a context.
    """

    __slots__ = ("_lock", "_active", "_on_quiet", "_fired")

    def __init__(self, on_quiet: Callable[[], None]) -> None:
        self._lock = threading.Lock()
        self._active = 0
        self._on_quiet = on_quiet
        self._fired = False

    def update(self, delta: int) -> None:
        with self._lock:
            self._active += delta
            fire = self._active == 0 and not self._fired
            if fire:
                self._fired = True
        if fire:
            try:
                self._on_quiet()
            except BaseException:  # noqa: BLE001 - completion cb never poisons a worker
                pass


class Future:
    """Completion handle: result/exception delivery plus cooperative cancel.

    ``canceller`` (when attached by ``submit_future`` / ``as_future``) is a
    nullary callable returning True if the underlying work was prevented
    from starting. A bare ``Future()`` has no producer to stop, so
    :meth:`cancel` simply resolves it with :class:`CancelledError`.
    Resolution is first-write-wins: a producer completing after a successful
    cancel is ignored.

    Futures bridge into ``asyncio``: ``await fut`` works inside any running
    event loop (:meth:`__await__` hands completion over via
    ``call_soon_threadsafe``), which is what ``Executor.co_run`` and
    ``ServeEngine.submit_async`` build on (DESIGN.md §10).

    Producer/consumer protocol in one glance::

        >>> from repro.core import Future
        >>> fut = Future()
        >>> fut.done()
        False
        >>> fut.set_result("ready")    # producer side, first write wins
        >>> fut.set_result("ignored")
        >>> fut.result(timeout=0)      # consumer side
        'ready'
    """

    __slots__ = (
        "_event",
        "_result",
        "_exception",
        "_lock",
        "_canceller",
        "_cancelled",
        "_callbacks",
    )

    def __init__(self, canceller: Optional[Callable[[], bool]] = None) -> None:
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._canceller = canceller
        self._cancelled = False
        self._callbacks: list[Callable[["Future"], None]] = []

    def _drain_callbacks(self) -> None:
        with self._lock:
            cbs, self._callbacks = self._callbacks, []
        for cb in cbs:
            try:
                cb(self)
            except BaseException:  # noqa: BLE001 - callback errors are dropped
                pass

    def add_done_callback(self, fn: Callable[["Future"], None]) -> None:
        """Run ``fn(self)`` once the future resolves (immediately if it
        already has). Callbacks fire on the resolving thread."""
        with self._lock:
            if not self._event.is_set():
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except BaseException:  # noqa: BLE001 - callback errors are dropped
            pass

    def set_result(self, value: Any) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._result = value
            self._event.set()
        self._drain_callbacks()

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._exception = exc
            self._event.set()
        self._drain_callbacks()

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Try to cancel. True iff the body was prevented from running.

        Already-completed futures and tasks that already started return
        False (cooperative semantics: a running body is never interrupted).
        The canceller's verdict is authoritative: if it won, this returns
        True even when the skipped task's completion callback resolved the
        future (with CancelledError) concurrently.
        """
        with self._lock:
            if self._event.is_set() and not self._cancelled:
                return False
        if self._canceller is not None:
            if not self._canceller():
                return False
            with self._lock:
                self._cancelled = True
                if not self._event.is_set():
                    self._exception = CancelledError("future cancelled")
                    self._event.set()
            self._drain_callbacks()
            return True
        with self._lock:
            if self._event.is_set():
                return self._cancelled
            self._cancelled = True
            self._exception = CancelledError("future cancelled")
            self._event.set()
        self._drain_callbacks()
        return True

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("future not completed within timeout")
        if self._exception is not None:
            raise self._exception
        return self._result

    def __await__(self):
        """Awaitable bridge: ``await fut`` inside a running asyncio loop.

        Completion is transferred onto the loop with
        ``call_soon_threadsafe`` from whichever worker thread resolves the
        future — the event loop never blocks on the pool.
        """
        import asyncio  # deferred: the pool itself never needs asyncio

        if self._event.is_set():
            if self._exception is not None:
                raise self._exception
            return self._result
        loop = asyncio.get_running_loop()
        afut: "asyncio.Future" = loop.create_future()

        def _transfer(f: "Future") -> None:
            def _apply() -> None:
                if afut.done():
                    return
                if f._exception is not None:
                    afut.set_exception(f._exception)
                else:
                    afut.set_result(f._result)

            try:
                loop.call_soon_threadsafe(_apply)
            except RuntimeError:  # loop already closed; nothing to deliver to
                pass

        self.add_done_callback(_transfer)
        return (yield from afut)


class ThreadPool:
    """Work-stealing thread pool running async tasks and task graphs.

    Parameters
    ----------
    num_threads:
        Worker count; defaults to ``os.cpu_count()`` — the analogue of the
        paper's ``std::thread::hardware_concurrency()`` default.
    deque_cls:
        ``FastDeque`` (default, GIL-atomic / fence-free analogue) or
        ``ChaseLevDeque`` (faithful structural port; used in tests). Each
        worker's deque and the shared inbox are priority-banded instances
        of this class (``PriorityDeque``); with only priority 0.0 in play
        they stay on the single-band fast path (DESIGN.md §9).
    observers:
        Initial observers (``core/observer.py`` protocol: on_submit /
        on_start / on_finish / on_steal). With no observers attached the
        hot path pays one falsy-list check per event site.

    Concurrency notes (DESIGN.md §9): worker ``i`` is the only writer of
    cell ``i`` in every counter list; cell ``n`` (external threads) is
    guarded by ``_ext_lock``. ``_outstanding()`` reads the completed cells
    *before* the claimed cells, so a zero result proves quiescence — every
    completion counted implies its claim was counted too.

    The paper's usage shape — submit async work and graphs, wait, close::

        >>> from repro.core import Task, ThreadPool
        >>> with ThreadPool(2) as pool:
        ...     fut = pool.submit_future(lambda: 6 * 7)
        ...     head = Task(lambda: 10)
        ...     tail = Task(lambda x: x + 1, takes_inputs=True).succeed(head)
        ...     pool.submit([head, tail])
        ...     _ = pool.wait_idle(10)
        >>> fut.result(10), tail.result
        (42, 11)
    """

    def __init__(
        self,
        num_threads: Optional[int] = None,
        *,
        deque_cls: type = FastDeque,
        name: str = "repro-pool",
        observers: Sequence[Any] = (),
    ) -> None:
        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_threads must be >= 1")
        self._deques = [PriorityDeque(deque_cls) for _ in range(n)]
        self._inbox = PriorityDeque(FastDeque)  # MPMC under the GIL
        self._tls = threading.local()
        self._stop = False
        # -- idle accounting: per-worker cells, slot n for external threads.
        self._claimed = [0] * (n + 1)  # tasks claimed (queued or inlined)
        self._completed = [0] * (n + 1)  # tasks fully processed
        self._ext_lock = threading.Lock()  # serializes slot-n increments
        # -- quiescence protocol: waiters register; the worker that drives
        # the outstanding count to zero notifies. Zero cost with no waiters.
        self._idle_cond = threading.Condition()
        self._idle_waiters = 0
        # -- error funnel (cold path)
        self._err_lock = threading.Lock()
        self._first_error: Optional[BaseException] = None
        # -- parked-worker registry: indices of sleeping workers; a
        # submitter pops one and sets its event (targeted wakeup).
        self._parked: _pydeque[int] = _pydeque()
        self._events = [threading.Event() for _ in range(n)]
        # -- process-backend seams (DESIGN.md §11). Both stay None on a
        # plain ThreadPool, so the thread backend pays one falsy check per
        # submission (`_wire_tasks`) and per executed body (`_offload`).
        # ``ProcessPool`` (repro.dist) binds them: `_wire_tasks` serializes
        # eligible bodies at submit, `_offload` ships a wired body to a
        # worker process instead of calling it in-thread.
        self._wire_tasks: Optional[Callable[..., None]] = None
        self._offload: Optional[Callable[[Task, int], None]] = None
        # -- per-worker statistic cells (slot n: non-worker threads)
        self._executed = [0] * (n + 1)
        self._steals = [0] * (n + 1)
        self._parked_ct = [0] * (n + 1)
        self._wakeups = [0] * (n + 1)
        # -- §14 fault tolerance: retry/timeout cells plus the lazy timer
        # (deferred requeues + watchdog); ProcessPool binds `_hard_timeout`.
        self._retries = [0] * (n + 1)
        self._timeouts = [0] * (n + 1)
        self._timer: Optional[_Timer] = None
        self._name = name
        self._observers: list[Any] = list(observers)
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), name=f"{name}-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    # -- public API -----------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return len(self._deques)

    def add_observer(self, observer: Any) -> None:
        """Attach a lifecycle observer (``core/observer.py`` protocol).

        Attach/detach are not synchronized against in-flight events: an
        observer attached mid-run may miss events already dispatched, which
        is fine for telemetry.
        """
        self._observers.append(observer)

    def remove_observer(self, observer: Any) -> None:
        """Detach a previously attached observer (no-op if absent)."""
        try:
            self._observers.remove(observer)
        except ValueError:
            pass

    def _notify(self, method: str, *args: Any) -> None:
        for obs in self._observers:
            try:
                getattr(obs, method)(*args)
            except BaseException:  # noqa: BLE001 - telemetry never poisons the pool
                pass

    def submit(
        self,
        work: Union[Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
    ) -> None:
        """Submit a callable, a single Task, or a task graph (iterable).

        Graph submission mirrors the paper: counters of every task reachable
        from the collection are re-armed, then all sources (tasks with no
        in-edges of either strength) are scheduled. ``priority`` (when
        given) overrides the priority of a callable/single-task submission
        *and* propagates to reachable continuation tasks that never chose
        an explicit priority of their own — a prioritized chain no longer
        silently falls back to band 0.0 past its first task. Graph
        (iterable) submissions keep per-task priorities.
        """
        if isinstance(work, Task):
            if priority is not None or self._wire_tasks is not None:
                graph = iter_graph([work])  # one traversal serves both steps
                if priority is not None:
                    for t in graph:
                        if t is work or not t._explicit_pr:
                            t.priority = priority
                if self._wire_tasks is not None:
                    self._wire_tasks(graph)
            self._schedule(work)
        elif callable(work):
            task = Task(work, priority=priority)
            if self._wire_tasks is not None:
                self._wire_tasks((task,))
            self._schedule(task)
        else:
            notify = getattr(work, "_notify_submitted", None)
            if notify is not None:  # a TaskGraph: run_count + §12 replay
                plan = work._usable_plan(self)
                if plan is not None:
                    # replay (DESIGN.md §12): plan re-arm folds reset() in,
                    # pre-bound roots replace source discovery; completion
                    # is wait_idle-observable exactly like live dispatch.
                    notify()
                    fin = work._fin
                    if fin is not None:
                        fin.on_done = None  # no future this round: stale
                        # as_future resolvers must not fire on old futures
                    plan.rearm()
                    plan.schedule(self)
                    return
                notify()
            tasks = list(work)
            graph = iter_graph(tasks)
            has_cond = False
            for t in graph:
                t.reset()
                if t._slow:  # recompute: a prior counted/condition run may linger
                    t.ctx = None
                    t.auto_rearm = False
                    t._slow = t.kind == "condition" or t.takes_runtime
                if t.kind == "condition":
                    has_cond = True
            if has_cond:
                # every member of a condition graph re-arms after running,
                # so weak back-edges can re-trigger it within this run
                for t in graph:
                    t.auto_rearm = True
                    t._slow = True
            if self._wire_tasks is not None:
                self._wire_tasks(graph)
            roots = [t for t in graph if t.is_source]
            if not roots and graph:
                raise ValueError("task graph has no sources (dependency cycle?)")
            for t in roots:
                self._schedule(t)

    # paper-style alias
    Submit = submit

    def submit_future(self, fn: Callable[[], Any], *, priority: float = 0.0) -> Future:
        """Submit a callable and get a :class:`Future` for its result.

        The future supports cooperative :meth:`Future.cancel`; exceptions
        from ``fn`` are delivered via the future only and do not poison the
        pool.
        """
        task = Task(fn, priority=priority)
        task.propagate_errors = False
        fut = Future(canceller=task.cancel)

        def _resolve(t: Task) -> None:
            if t.exception is not None:
                fut.set_exception(t.exception)
            else:
                fut.set_result(t.result)

        task.on_done = _resolve
        if self._wire_tasks is not None:
            self._wire_tasks((task,))
        self._schedule(task)
        return fut

    def _submit_with_context(self, tasks: Sequence[Task], ctx: RunContext) -> bool:
        """Submit a graph under counted completion (DESIGN.md §10).

        Every reachable task is reset, attached to ``ctx`` and routed
        through the slow fan-out; condition membership additionally arms
        the whole graph for weak re-triggering. All sources are counted
        into the context *before* the first one is scheduled, so an early
        completion can never drain the count to zero mid-submission.
        Returns False when there is nothing to schedule (the caller
        resolves the run itself).
        """
        graph = iter_graph(list(tasks))
        has_cond = False
        for t in graph:
            t.reset()
            t.ctx = ctx
            t._slow = True
            t.auto_rearm = False
            if t.kind == "condition":
                has_cond = True
        if has_cond:
            for t in graph:
                t.auto_rearm = True
        if self._wire_tasks is not None:
            self._wire_tasks(graph)
        roots = [t for t in graph if t.is_source]
        if not roots:
            if graph:
                raise ValueError("task graph has no sources (dependency cycle?)")
            return False
        ctx.update(len(roots))
        for t in roots:
            self._schedule(t)
        return True

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Block until every claimed task has completed.

        Returns True once idle; **False on timeout** (the pool is still
        busy) — callers that must not proceed on a non-quiescent pool
        raise their own ``TimeoutError`` (``CheckpointManager.wait``,
        ``Executor.wait_idle`` callers). Pre-§10 this raised from here,
        which made "timed out" and "a task failed" the same control path;
        now only a genuine task failure raises: once idle, the first task
        exception (if any) is re-raised and cleared. On timeout the error
        marker is left in place for the eventual successful wait.

        Waiters register on ``_idle_cond`` so the task path can skip the
        quiescence check entirely while nobody is waiting (DESIGN.md §9).
        """
        with self._idle_cond:
            self._idle_waiters += 1
            try:
                if not self._idle_cond.wait_for(lambda: self._outstanding() == 0, timeout):
                    return False
            finally:
                self._idle_waiters -= 1
        with self._err_lock:
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err
        return True

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        """``submit`` + ``wait_idle`` convenience."""
        self.submit(work)
        self.wait_idle()

    def close(self) -> None:
        """Stop the workers (idempotent). Pending tasks are abandoned.

        Every parked worker is woken through its event, so close returns
        after at most the in-flight task bodies — no park-tick wait.
        """
        if self._stop:
            return
        self._stop = True
        for ev in self._events:
            ev.set()
        for t in self._threads:
            t.join()
        timer = self._timer
        if timer is not None:
            timer.close()

    def stats(self) -> dict[str, Any]:
        """Execution statistics, summed over the per-worker counters.

        Each worker increments only its own cell, so reads race at worst
        with a single in-flight increment per cell — the sum is exact for
        any quiesced pool and monotonically consistent for a live one.
        ``parked`` counts park events (a worker going to sleep); ``wakeups``
        counts targeted wakeups issued by submitters and the wake chain.
        ``band_depths`` sums the per-band queue depth across the inbox and
        every worker deque (DESIGN.md §13): on a prioritized workload it
        shows where waiting work sits — e.g. near-deadline prefills piling
        up in their promoted band while decode drains band 1.0 first.
        §14 adds ``retries`` (re-scheduled failed attempts, including §12
        segment members) and ``timeouts`` (attempts that exceeded their
        ``timeout=`` deadline).
        """
        depths: dict[float, int] = {}
        for dq in (self._inbox, *self._deques):
            for pr, n in dq.depths().items():
                depths[pr] = depths.get(pr, 0) + n
        return {
            "executed": sum(self._executed),
            "steals": sum(self._steals),
            "parked": sum(self._parked_ct),
            "wakeups": sum(self._wakeups),
            "retries": sum(self._retries),
            "timeouts": sum(self._timeouts),
            "band_depths": dict(sorted(depths.items(), reverse=True)),
        }

    def __enter__(self) -> "ThreadPool":
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - interpreter shutdown path
        try:
            self.close()
        except Exception:
            pass

    # -- fault tolerance (DESIGN.md §14) ----------------------------------------

    # Hard-timeout escalation hook: None on thread/serial backends (the
    # deadline is cooperative — `checkpoint()`); ProcessPool overrides with
    # a kill-the-stuck-worker callback registered on the pool timer.
    _hard_timeout: Optional[Callable[..., None]] = None

    def _timer_get(self) -> _Timer:
        """The pool's lazy timer thread (created on first §14 use)."""
        timer = self._timer
        if timer is None:
            with self._ext_lock:
                timer = self._timer
                if timer is None:
                    timer = self._timer = _Timer(self._name)
        return timer

    def _retry_policy_for(self, task: Task, exc: BaseException) -> Any:
        """The policy governing this failure, or None (no retry).

        Base pools consult only the task's own :class:`RetryPolicy`;
        ``ProcessPool`` also supplies an implicit single retry for
        transport-level worker loss (DESIGN.md §11/§14).
        """
        pol = task.retry_policy
        if pol is not None and pol.matches(exc):
            return pol
        return None

    def _maybe_retry(self, task: Task, exc: BaseException, index: int) -> bool:
        """Re-arm and re-schedule a retriable failed attempt.

        Returns True when a retry was scheduled (the failure must not
        surface). The retry instance is *claimed before* the failed
        attempt's completion cell is bumped, so ``_outstanding()`` can
        never transiently hit zero while a backoff is pending — waiters
        stay blocked until the retried task truly completes.

        At-most-once gate: an exception flagged ``started=True`` (the body
        began executing and was lost — ``WorkerDiedError`` from a §11 hard
        kill) is retried only for ``idempotent`` tasks.
        """
        pol = self._retry_policy_for(task, exc)
        if pol is None:
            return False
        if getattr(exc, "started", False) and not task.idempotent:
            return False
        attempt = task._attempt + 1
        if attempt >= pol.max_attempts:
            return False
        task._attempt = attempt
        if exc.__context__ is None and task._last_exc is not None:
            exc.__context__ = task._last_exc  # chain attempt N-1 behind N
        task._last_exc = exc
        # re-arm just this task: claim refilled, started cleared so a
        # cancel() landing between attempts wins the refilled claim and
        # the requeued dispatch skips the body.
        task._claim[:] = (0,)
        task._started = False
        task._timed_out = False
        task.exception = None
        self._retries[index] += 1
        if self._observers:
            self._notify("on_retry", task.first if task._seg else task, attempt, index)
        self._requeue(task, pol.delay(attempt), index)
        return True

    def _requeue(self, task: Task, delay: float, index: int) -> None:
        """Schedule an already-claimed retry: now (own deque) or deferred
        through the pool timer — no worker sleeps off the backoff."""
        self._claimed[index] += 1
        if delay <= 0:
            if self._observers:
                self._notify("on_submit", task.first if task._seg else task)
            self._deques[index].push(task)
            if self._parked:
                self._wake_one(index)
        else:
            self._timer_get().add(
                time.monotonic() + delay, lambda: self._requeue_now(task)
            )

    def _requeue_now(self, task: Task) -> None:
        """Timer-thread side of a deferred requeue (claim already counted)."""
        if self._observers:
            self._notify("on_submit", task.first if task._seg else task)
        with self._ext_lock:
            self._inbox.push_external(task)
            if self._parked:
                self._wake_one(-1)

    # -- scheduling internals ---------------------------------------------------

    def _outstanding(self) -> int:
        """Claimed-but-not-completed task count.

        Completed cells are summed *first*: every completion counted here
        had its claim recorded earlier (program order under the GIL), so
        the later claimed-sum includes it and the difference never goes
        negative — and a zero difference proves the pool is quiet.
        """
        done = sum(self._completed)
        return sum(self._claimed) - done

    def _wake_one(self, slot: int) -> None:
        """Targeted wakeup: pop one parked worker, set its event, and
        attribute the wakeup to the caller's counter cell.

        Call sites guard with ``if self._parked`` so the saturated hot
        path (nobody parked) never pays the method call.
        """
        try:
            idx = self._parked.popleft()
        except IndexError:
            return
        self._events[idx].set()
        self._wakeups[slot] += 1

    def _schedule(self, task: Task) -> None:
        """Claim ``task`` (one per-cell increment) and enqueue it.

        From a worker thread: push to the worker's own deque, found through
        the thread-local variable (paper §2.1) — lock-free. Otherwise:
        shared inbox (priority-banded FIFO) with the slot-n claim guarded
        by ``_ext_lock``. Either way, at most one parked worker is woken.
        """
        if self._observers:
            # §12 replay meta nodes report as their head member, so queue
            # events always name real tasks (observer parity with live)
            self._notify("on_submit", task.first if task._seg else task)
        idx = getattr(self._tls, "index", None)
        if idx is not None:
            self._claimed[idx] += 1
            self._deques[idx].push(task)
            if self._parked:
                self._wake_one(idx)
        else:
            with self._ext_lock:
                self._claimed[-1] += 1
                self._inbox.push_external(task)
                if self._parked:
                    self._wake_one(-1)

    def _worker(self, index: int) -> None:
        self._tls.index = index
        own = self._deques[index]
        n = len(self._deques)
        ev = self._events[index]
        spins = 0
        while True:
            if self._stop:
                return
            task = self._next_task(index, own, n)
            if task is not EMPTY:
                spins = 0
                self._execute(task, index)
                continue
            if spins < _SPIN_SWEEPS:
                spins += 1
                time.sleep(0)  # yield the GIL so a producer can publish
                continue
            spins = 0
            # Park protocol: clear our event, *register*, then re-sweep.
            # Submitters push the task before scanning the registry, so any
            # push racing our failed sweep is re-observed here; any wakeup
            # aimed at us after registration leaves the event set, making
            # the wait below a no-op. One acquisition-free pass — the old
            # design's double condition-variable lock is gone.
            ev.clear()
            self._parked.append(index)
            self._parked_ct[index] += 1
            task = self._next_task(index, own, n)
            if task is not EMPTY:
                try:
                    self._parked.remove(index)
                except ValueError:
                    pass  # a submitter popped us; its wakeup is consumed below
                self._execute(task, index)
                continue
            if self._stop:  # close() may have raced our registration
                return
            ev.wait(_PARK_BACKSTOP_S)  # backstop only: wakeups are targeted
            try:
                self._parked.remove(index)
            except ValueError:
                pass

    def _next_task(self, index: int, own: Any, n: int) -> Any:
        # 1. own deque: highest priority band, LIFO (depth-first) within it
        task = own.pop()
        if task is not EMPTY:
            return task
        # 2. shared inbox (external submissions): highest band, FIFO within
        task = self._inbox.steal()
        if task is not EMPTY:
            # wake chain: surplus inbox work -> recruit one more sleeper
            if self._parked and len(self._inbox):
                self._wake_one(index)
            return task
        # 3. sweep victims, stealing from the top (highest band, FIFO)
        for k in range(1, n):
            victim = (index + k) % n
            vd = self._deques[victim]
            task = vd.steal()
            if task is not EMPTY:
                self._steals[index] += 1
                if self._parked and len(vd):
                    self._wake_one(index)
                if self._observers:
                    self._notify(
                        "on_steal", task.first if task._seg else task, index, victim
                    )
                return task
        return EMPTY

    def _execute(self, first: Task, index: int) -> None:
        """Run a task, then its ready successors via continuation passing.

        The fan-out (paper §2.2) is a fused decrement-and-pick loop: the
        running maximum-priority ready successor is kept as the inline
        continuation, every other ready successor is pushed straight onto
        this worker's own deque, and one batch wakeup recruits a sleeper.
        No intermediate ready list, no key-function allocation. Inline
        continuations are claimed *before* the finished task's completion
        cell is bumped, so the outstanding count never transiently hits
        zero mid-chain — the quiescence check runs only at chain end.
        """
        claimed = self._claimed
        own = self._deques[index]
        task: Optional[Task] = first
        while task is not None:
            if self._observers and not task._seg:
                # §12 segments fire per-member start/finish from their own
                # run loop; a seg-level pair would double-count
                self._notify("on_start", task, index)
            slow = task._slow
            rt: Optional[Runtime] = None
            # §14 cooperative checkpoint state: two plain stores per task
            _current.task = task
            _current.deadline = (
                None if task.timeout is None else time.monotonic() + task.timeout
            )
            try:
                if self._first_error is not None and task.propagate_errors:
                    # fail-fast: skip bodies once the graph is poisoned, but
                    # keep draining dependencies so waiters unblock.
                    task.exception = CancelledError("predecessor failed")
                    task._done = True  # noqa: SLF001 - internal protocol
                elif slow and task.takes_runtime:
                    rt = Runtime(task)
                    # publish the live (growing) subflow list before the body
                    # runs: a graph canceller sweeping mid-body sees tasks as
                    # they are spawned and can cancel them before they start
                    task._spawned = rt.sub.tasks
                    task.run(rt)
                elif self._offload is not None:
                    self._offload(task, index)
                else:
                    task.run()
            except _Retry as sig:
                # §14 via §12: a segment member failed retriably; the
                # segment re-armed itself (resume point saved) — requeue
                # it whole and end this dispatch without surfacing.
                self._requeue(task, sig.delay, index)
                self._executed[index] += 1
                self._completed[index] += 1
                task = None
                continue
            except BaseException as exc:  # noqa: BLE001 - recorded + re-raised in wait
                if isinstance(exc, TaskTimeoutError):
                    self._timeouts[index] += 1
                    if self._observers:
                        self._notify("on_timeout", task, index)
                if self._maybe_retry(task, exc, index):
                    self._executed[index] += 1
                    self._completed[index] += 1
                    task = None
                    continue
                if (
                    task._last_exc is not None
                    and exc.__context__ is None
                    and exc is not task._last_exc
                ):  # exhausted retries surface the whole attempt chain
                    exc.__context__ = task._last_exc
                task.exception = exc
                if task.propagate_errors:
                    with self._err_lock:
                        if self._first_error is None:
                            self._first_error = exc
            self._executed[index] += 1
            if self._observers and not task._seg:
                self._notify("on_finish", task, index)
            cb = task.on_done
            if cb is not None:
                try:
                    cb(task)
                except BaseException:  # noqa: BLE001 - callback errors are dropped
                    pass
            if slow:
                # conditions / subflows / re-armable loops / counted runs
                task = self._finish_slow(task, index, rt)
                self._completed[index] += 1
                continue
            # Fused fan-out: decrement successors, keep the max-priority
            # ready one inline, push the rest (claimed as they are pushed).
            inline: Optional[Task] = None
            inline_pr = 0.0
            pushed = 0
            for s in task.successors:
                if not s.decrement():
                    continue
                claimed[index] += 1
                if inline is None:
                    inline = s
                    inline_pr = s.priority
                elif s.priority > inline_pr:
                    if self._observers:
                        self._notify("on_submit", inline.first if inline._seg else inline)
                    own.push(inline)
                    pushed += 1
                    inline = s
                    inline_pr = s.priority
                else:
                    if self._observers:
                        self._notify("on_submit", s.first if s._seg else s)
                    own.push(s)
                    pushed += 1
            if pushed and self._parked:
                self._wake_one(index)  # the woken worker chains further
            self._completed[index] += 1
            task = inline
        # chain over: if anyone is waiting for quiescence, check and notify
        if self._idle_waiters and self._outstanding() == 0:
            with self._idle_cond:
                self._idle_cond.notify_all()

    def _finish_slow(
        self, task: Task, index: int, rt: Optional[Runtime]
    ) -> Optional[Task]:
        """Full-featured fan-out for §10 task kinds; returns the inline
        continuation (or None).

        Invariants this path maintains, in order:

        1. **Re-arm before release** (``auto_rearm``): the task refills its
           own countdown/claim *before* any successor becomes runnable, so
           a condition's weak back-edge — causally downstream of this
           task's own fan-out — always finds it armed. Re-triggering a
           task from a branch not downstream of it is a data race by
           construction (same rule as Taskflow) and unsupported.
        2. **Selection**: a subflow splices in behind a hidden join task
           that inherits the spawner's successors; a condition schedules
           exactly the branch its integer result names (weak edges carry
           no countdown, so nothing is decremented — also on failure,
           where no branch runs at all); plain tasks decrement strong
           successors as usual.
        3. **Count before publish**: the whole fan-out folds into one
           ``RunContext.update`` applied *before* any successor is pushed.
        """
        ctx = task.ctx
        if task.auto_rearm:
            task.rearm()
        scheduled: list[Task] = []
        if rt is not None and rt.sub.tasks and task.exception is None:
            # dynamic subflow: [sources ... sinks] -> join -> successors
            # (join wiring + unwrap + failure adoption live in graph.py,
            # shared with SerialExecutor)
            sub, join = splice_subflow(task, rt.sub)
            for st in sub + [join]:
                st.ctx = ctx
                st._slow = ctx is not None or st._slow
                if not task.propagate_errors:
                    st.propagate_errors = False
            if self._wire_tasks is not None:
                # runtime-spawned tasks are wired on the worker: a body
                # that cannot serialize surfaces when that task runs
                # (defer) instead of raising inside the scheduler loop
                self._wire_tasks(sub, defer=True)
            task._spawned = sub
            if task._seg:
                # §12 replay spawner proxy: the splice operated on the meta
                # (so the hidden join releases *meta* successors), but
                # results and failure adoption must land on the wrapped
                # member, where dataflow consumers and the graph resolver
                # read them — mirror the join's verdict back.
                inner = task.first
                inner._spawned = sub

                def _mirror(j, _fj=join.on_done, _meta=task, _inner=inner):
                    _fj(j)
                    _inner.result = _meta.result
                    _inner.exception = _meta.exception

                join.on_done = _mirror
            scheduled = [t for t in sub if t.is_source]
            if join.num_predecessors == 0:  # empty-sink degenerate case
                scheduled.append(join)
        elif task.kind == "condition":
            # weak fan-out: a failed/cancelled condition releases nothing
            # (weak edges contributed no countdown tokens — nothing drains)
            branch = select_branch(task)
            if branch is not None:
                scheduled.append(branch)
        else:
            for s in task.successors:
                if s.decrement():
                    scheduled.append(s)
        if ctx is not None:
            delta = len(scheduled) - 1
            if delta:
                ctx.update(delta)
        # publish: twin of the fused block in _execute (which interleaves the
        # decrement with the pick and must stay allocation-free — keep any
        # change to the inline-pick / push / wakeup policy in sync there)
        inline: Optional[Task] = None
        inline_pr = 0.0
        pushed = 0
        own = self._deques[index]
        for s in scheduled:
            self._claimed[index] += 1
            if inline is None:
                inline = s
                inline_pr = s.priority
            elif s.priority > inline_pr:
                if self._observers:
                    self._notify("on_submit", inline.first if inline._seg else inline)
                own.push(inline)
                pushed += 1
                inline = s
                inline_pr = s.priority
            else:
                if self._observers:
                    self._notify("on_submit", s.first if s._seg else s)
                own.push(s)
                pushed += 1
        if pushed and self._parked:
            self._wake_one(index)
        return inline
