"""Work-stealing thread pool capable of running task graphs (paper §2).

Faithful Python adaptation of the paper's C++ design:

* one work-stealing deque per worker thread (``deque.py``);
* the current worker's deque is found through a **thread-local** variable
  (the paper's replacement for thread-ID→index maps, §2.1);
* a task submitted *from* a worker thread is pushed to that worker's own
  deque (depth-first, cache-friendly); tasks submitted from outside land in a
  shared MPMC inbox (Chase-Lev deques are single-producer — see deque.py);
* idle workers first pop their own deque, then drain the inbox, then sweep
  the other workers' deques stealing from the top, then park;
* task-graph execution by dependency counting (§2.2): when a task body
  completes, every successor's pending-predecessor counter is decremented;
  **one** newly-ready successor is executed inline on the same worker
  (continuation passing), the others are pushed.

Differences from the C++ original are documented in DESIGN.md §2.1.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .deque import EMPTY, ChaseLevDeque, FastDeque
from .task import CancelledError, Task, iter_graph

__all__ = ["ThreadPool", "Future"]

_PARK_TIMEOUT_S = 0.05  # bounded park: robust against missed wakeups


class Future:
    """Minimal completion handle for ``ThreadPool.submit_future``."""

    __slots__ = ("_event", "_result", "_exception")

    def __init__(self) -> None:
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None

    def set_result(self, value: Any) -> None:
        self._result = value
        self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("future not completed within timeout")
        if self._exception is not None:
            raise self._exception
        return self._result


class ThreadPool:
    """Work-stealing thread pool running async tasks and task graphs.

    Parameters
    ----------
    num_threads:
        Worker count; defaults to ``os.cpu_count()`` — the analogue of the
        paper's ``std::thread::hardware_concurrency()`` default.
    deque_cls:
        ``FastDeque`` (default, GIL-atomic / fence-free analogue) or
        ``ChaseLevDeque`` (faithful structural port; used in tests).
    """

    def __init__(
        self,
        num_threads: Optional[int] = None,
        *,
        deque_cls: type = FastDeque,
        name: str = "repro-pool",
    ) -> None:
        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_threads must be >= 1")
        self._deques = [deque_cls() for _ in range(n)]
        self._inbox = FastDeque()  # MPMC under the GIL
        self._tls = threading.local()
        self._cond = threading.Condition()
        self._unfinished = 0  # tasks claimed but not yet completed
        self._stop = False
        self._first_error: Optional[BaseException] = None
        self._executed = 0  # statistics (approximate across threads)
        self._steals = 0
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), name=f"{name}-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    # -- public API -----------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return len(self._deques)

    def submit(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        """Submit a callable, a single Task, or a task graph (iterable).

        Graph submission mirrors the paper: counters of every task reachable
        from the collection are re-armed, then all roots (tasks with no
        predecessors) are scheduled.
        """
        if isinstance(work, Task):
            self._schedule(work)
        elif callable(work):
            self._schedule(Task(work))
        else:
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            roots = [t for t in graph if t.num_predecessors == 0]
            if not roots and graph:
                raise ValueError("task graph has no roots (dependency cycle?)")
            for t in roots:
                self._schedule(t)

    # paper-style alias
    Submit = submit

    def submit_future(self, fn: Callable[[], Any]) -> Future:
        """Submit a callable and get a :class:`Future` for its result."""
        fut = Future()

        def body() -> None:
            try:
                fut.set_result(fn())
            except BaseException as exc:  # noqa: BLE001 - delivered via the
                fut.set_exception(exc)  # future only; does not poison the pool

        self._schedule(Task(body))
        return fut

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        """Block until every claimed task has completed.

        Re-raises the first task exception, if any (then clears it).
        """
        with self._cond:
            if not self._cond.wait_for(lambda: self._unfinished == 0, timeout):
                raise TimeoutError("pool did not become idle within timeout")
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        """``submit`` + ``wait_idle`` convenience."""
        self.submit(work)
        self.wait_idle()

    def close(self) -> None:
        """Stop the workers (idempotent). Pending tasks are abandoned."""
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()

    def stats(self) -> dict[str, int]:
        return {"executed": self._executed, "steals": self._steals}

    def __enter__(self) -> "ThreadPool":
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - interpreter shutdown path
        try:
            self.close()
        except Exception:
            pass

    # -- scheduling internals ---------------------------------------------------

    def _schedule(self, task: Task) -> None:
        """Claim ``task`` (+1 unfinished) and enqueue it.

        From a worker thread: push to the worker's own deque, found through
        the thread-local variable (paper §2.1). Otherwise: shared inbox.
        """
        with self._cond:
            self._unfinished += 1
            self._cond.notify()
        idx = getattr(self._tls, "index", None)
        if idx is not None:
            self._deques[idx].push(task)
        else:
            self._inbox.push_external(task)

    def _worker(self, index: int) -> None:
        self._tls.index = index
        own = self._deques[index]
        n = len(self._deques)
        while True:
            task = self._next_task(index, own, n)
            if task is EMPTY:
                with self._cond:
                    if self._stop:
                        return
                # Bounded park instead of a racy empty-recheck protocol: a
                # submit between our sweep and the wait costs at most one
                # timeout tick.
                with self._cond:
                    self._cond.wait(_PARK_TIMEOUT_S)
            else:
                self._execute(task)

    def _next_task(self, index: int, own: Any, n: int) -> Any:
        # 1. own deque, bottom (LIFO depth-first)
        task = own.pop()
        if task is not EMPTY:
            return task
        # 2. shared inbox (external submissions), FIFO
        task = self._inbox.steal()
        if task is not EMPTY:
            return task
        # 3. sweep victims, stealing from the top (FIFO)
        for k in range(1, n):
            task = self._deques[(index + k) % n].steal()
            if task is not EMPTY:
                self._steals += 1
                return task
        return EMPTY

    def _execute(self, first: Task) -> None:
        """Run a task, then its ready successors via continuation passing."""
        task: Optional[Task] = first
        while task is not None:
            try:
                if self._first_error is not None:
                    # fail-fast: skip bodies once the graph is poisoned, but
                    # keep draining dependencies so waiters unblock.
                    task.exception = CancelledError("predecessor failed")
                    task._done = True  # noqa: SLF001 - internal protocol
                else:
                    task.run()
            except BaseException as exc:  # noqa: BLE001 - recorded + re-raised in wait
                task.exception = exc
                with self._cond:
                    if self._first_error is None:
                        self._first_error = exc
            self._executed += 1
            # Fan out (paper §2.2): decrement successors; run ONE newly-ready
            # successor inline, push the rest.
            inline: Optional[Task] = None
            for s in task.successors:
                if s.decrement():
                    if inline is None:
                        with self._cond:
                            self._unfinished += 1
                        inline = s
                    else:
                        self._schedule(s)
            with self._cond:
                self._unfinished -= 1
                if self._unfinished == 0:
                    self._cond.notify_all()
            task = inline
