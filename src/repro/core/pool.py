"""Work-stealing thread pool capable of running task graphs (paper §2).

Faithful Python adaptation of the paper's C++ design:

* one work-stealing deque per worker thread (``deque.py``);
* the current worker's deque is found through a **thread-local** variable
  (the paper's replacement for thread-ID→index maps, §2.1);
* a task submitted *from* a worker thread is pushed to that worker's own
  deque (depth-first, cache-friendly); tasks submitted from outside land in a
  shared MPMC inbox (Chase-Lev deques are single-producer — see deque.py);
* idle workers first pop their own deque, then drain the inbox, then sweep
  the other workers' deques stealing from the top, then park;
* task-graph execution by dependency counting (§2.2): when a task body
  completes, every successor's pending-predecessor counter is decremented;
  **one** newly-ready successor is executed inline on the same worker
  (continuation passing), the others are pushed.

Beyond the paper (DESIGN.md §3): task **priorities** — own-deque pops, inbox
draining, steals and the inline-continuation pick are all priority-aware
(highest band first; LIFO within a band on the owner's side, FIFO on the
thief/inbox side), the same ready-key the schedule simulator uses — and
**cooperative cancellation** surfaced through :class:`Future` and
``TaskGraph.as_future``. Both exist for the serving engine: decode ticks run
at high priority, speculative prefills at low priority, and aborted requests
cancel their in-flight work.

Also beyond the paper (DESIGN.md §8): an **observer layer**. Attached
observers (``core/observer.py``) see submit/start/finish/steal lifecycle
events, which is how the aggregate-stats and Chrome-trace exporters watch a
run without the pool knowing about either.

**Hot-path discipline (DESIGN.md §9).** The task path takes no locks:

* *idle accounting* is GIL-atomic per-worker claimed/completed cells summed
  only when an idle check is actually needed — ``wait_idle`` waiters pay
  for quiescence detection, the task path pays one falsy flag check;
* *wakeups are targeted*: idle workers spin briefly then park on a
  per-worker event after registering in a parked-worker deque; a submitter
  pops **one** sleeper and sets its event (no condition-variable notify
  storm, no poll tax), woken workers chain further wakeups while surplus
  work remains, and ``close()`` sets every event so shutdown is prompt;
* *fan-out is allocation-free*: a fused decrement-and-pick loop over
  ``task.successors`` keeps the running max-priority successor as the
  inline continuation and pushes the rest directly onto the worker's own
  deque — no ready list, no ``max(..., key=...)``, one batch wakeup.

Differences from the C++ original are documented in DESIGN.md §2.1.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque as _pydeque
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .deque import EMPTY, ChaseLevDeque, FastDeque, PriorityDeque
from .task import CancelledError, Task, iter_graph

__all__ = ["ThreadPool", "Future"]

_SPIN_SWEEPS = 2  # extra full sweeps (with GIL yields) before parking
_PARK_BACKSTOP_S = 0.5  # safety net only; targeted wakeups are the fast path


class Future:
    """Completion handle: result/exception delivery plus cooperative cancel.

    ``canceller`` (when attached by ``submit_future`` / ``as_future``) is a
    nullary callable returning True if the underlying work was prevented
    from starting. A bare ``Future()`` has no producer to stop, so
    :meth:`cancel` simply resolves it with :class:`CancelledError`.
    Resolution is first-write-wins: a producer completing after a successful
    cancel is ignored.
    """

    __slots__ = ("_event", "_result", "_exception", "_lock", "_canceller", "_cancelled")

    def __init__(self, canceller: Optional[Callable[[], bool]] = None) -> None:
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._canceller = canceller
        self._cancelled = False

    def set_result(self, value: Any) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._result = value
            self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._exception = exc
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Try to cancel. True iff the body was prevented from running.

        Already-completed futures and tasks that already started return
        False (cooperative semantics: a running body is never interrupted).
        The canceller's verdict is authoritative: if it won, this returns
        True even when the skipped task's completion callback resolved the
        future (with CancelledError) concurrently.
        """
        with self._lock:
            if self._event.is_set() and not self._cancelled:
                return False
        if self._canceller is not None:
            if not self._canceller():
                return False
            with self._lock:
                self._cancelled = True
                if not self._event.is_set():
                    self._exception = CancelledError("future cancelled")
                    self._event.set()
            return True
        with self._lock:
            if self._event.is_set():
                return self._cancelled
            self._cancelled = True
            self._exception = CancelledError("future cancelled")
            self._event.set()
        return True

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("future not completed within timeout")
        if self._exception is not None:
            raise self._exception
        return self._result


class ThreadPool:
    """Work-stealing thread pool running async tasks and task graphs.

    Parameters
    ----------
    num_threads:
        Worker count; defaults to ``os.cpu_count()`` — the analogue of the
        paper's ``std::thread::hardware_concurrency()`` default.
    deque_cls:
        ``FastDeque`` (default, GIL-atomic / fence-free analogue) or
        ``ChaseLevDeque`` (faithful structural port; used in tests). Each
        worker's deque and the shared inbox are priority-banded instances
        of this class (``PriorityDeque``); with only priority 0.0 in play
        they stay on the single-band fast path (DESIGN.md §9).
    observers:
        Initial observers (``core/observer.py`` protocol: on_submit /
        on_start / on_finish / on_steal). With no observers attached the
        hot path pays one falsy-list check per event site.

    Concurrency notes (DESIGN.md §9): worker ``i`` is the only writer of
    cell ``i`` in every counter list; cell ``n`` (external threads) is
    guarded by ``_ext_lock``. ``_outstanding()`` reads the completed cells
    *before* the claimed cells, so a zero result proves quiescence — every
    completion counted implies its claim was counted too.
    """

    def __init__(
        self,
        num_threads: Optional[int] = None,
        *,
        deque_cls: type = FastDeque,
        name: str = "repro-pool",
        observers: Sequence[Any] = (),
    ) -> None:
        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_threads must be >= 1")
        self._deques = [PriorityDeque(deque_cls) for _ in range(n)]
        self._inbox = PriorityDeque(FastDeque)  # MPMC under the GIL
        self._tls = threading.local()
        self._stop = False
        # -- idle accounting: per-worker cells, slot n for external threads.
        self._claimed = [0] * (n + 1)  # tasks claimed (queued or inlined)
        self._completed = [0] * (n + 1)  # tasks fully processed
        self._ext_lock = threading.Lock()  # serializes slot-n increments
        # -- quiescence protocol: waiters register; the worker that drives
        # the outstanding count to zero notifies. Zero cost with no waiters.
        self._idle_cond = threading.Condition()
        self._idle_waiters = 0
        # -- error funnel (cold path)
        self._err_lock = threading.Lock()
        self._first_error: Optional[BaseException] = None
        # -- parked-worker registry: indices of sleeping workers; a
        # submitter pops one and sets its event (targeted wakeup).
        self._parked: _pydeque[int] = _pydeque()
        self._events = [threading.Event() for _ in range(n)]
        # -- per-worker statistic cells (slot n: non-worker threads)
        self._executed = [0] * (n + 1)
        self._steals = [0] * (n + 1)
        self._parked_ct = [0] * (n + 1)
        self._wakeups = [0] * (n + 1)
        self._observers: list[Any] = list(observers)
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), name=f"{name}-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    # -- public API -----------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return len(self._deques)

    def add_observer(self, observer: Any) -> None:
        """Attach a lifecycle observer (``core/observer.py`` protocol).

        Attach/detach are not synchronized against in-flight events: an
        observer attached mid-run may miss events already dispatched, which
        is fine for telemetry.
        """
        self._observers.append(observer)

    def remove_observer(self, observer: Any) -> None:
        """Detach a previously attached observer (no-op if absent)."""
        try:
            self._observers.remove(observer)
        except ValueError:
            pass

    def _notify(self, method: str, *args: Any) -> None:
        for obs in self._observers:
            try:
                getattr(obs, method)(*args)
            except BaseException:  # noqa: BLE001 - telemetry never poisons the pool
                pass

    def submit(
        self,
        work: Union[Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
    ) -> None:
        """Submit a callable, a single Task, or a task graph (iterable).

        Graph submission mirrors the paper: counters of every task reachable
        from the collection are re-armed, then all roots (tasks with no
        predecessors) are scheduled. ``priority`` (when given) overrides the
        priority of a callable/single-task submission; graph tasks keep
        their own per-task priorities.
        """
        if isinstance(work, Task):
            if priority is not None:
                work.priority = priority
            self._schedule(work)
        elif callable(work):
            self._schedule(Task(work, priority=priority or 0.0))
        else:
            notify = getattr(work, "_notify_submitted", None)
            if notify is not None:  # TaskGraph bumps its run_count
                notify()
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            roots = [t for t in graph if t.num_predecessors == 0]
            if not roots and graph:
                raise ValueError("task graph has no roots (dependency cycle?)")
            for t in roots:
                self._schedule(t)

    # paper-style alias
    Submit = submit

    def submit_future(self, fn: Callable[[], Any], *, priority: float = 0.0) -> Future:
        """Submit a callable and get a :class:`Future` for its result.

        The future supports cooperative :meth:`Future.cancel`; exceptions
        from ``fn`` are delivered via the future only and do not poison the
        pool.
        """
        task = Task(fn, priority=priority)
        task.propagate_errors = False
        fut = Future(canceller=task.cancel)

        def _resolve(t: Task) -> None:
            if t.exception is not None:
                fut.set_exception(t.exception)
            else:
                fut.set_result(t.result)

        task.on_done = _resolve
        self._schedule(task)
        return fut

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        """Block until every claimed task has completed.

        Re-raises the first task exception, if any (then clears it).
        Waiters register on ``_idle_cond`` so the task path can skip the
        quiescence check entirely while nobody is waiting (DESIGN.md §9).
        """
        with self._idle_cond:
            self._idle_waiters += 1
            try:
                if not self._idle_cond.wait_for(lambda: self._outstanding() == 0, timeout):
                    raise TimeoutError("pool did not become idle within timeout")
            finally:
                self._idle_waiters -= 1
        with self._err_lock:
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        """``submit`` + ``wait_idle`` convenience."""
        self.submit(work)
        self.wait_idle()

    def close(self) -> None:
        """Stop the workers (idempotent). Pending tasks are abandoned.

        Every parked worker is woken through its event, so close returns
        after at most the in-flight task bodies — no park-tick wait.
        """
        if self._stop:
            return
        self._stop = True
        for ev in self._events:
            ev.set()
        for t in self._threads:
            t.join()

    def stats(self) -> dict[str, int]:
        """Execution statistics, summed over the per-worker counters.

        Each worker increments only its own cell, so reads race at worst
        with a single in-flight increment per cell — the sum is exact for
        any quiesced pool and monotonically consistent for a live one.
        ``parked`` counts park events (a worker going to sleep); ``wakeups``
        counts targeted wakeups issued by submitters and the wake chain.
        """
        return {
            "executed": sum(self._executed),
            "steals": sum(self._steals),
            "parked": sum(self._parked_ct),
            "wakeups": sum(self._wakeups),
        }

    def __enter__(self) -> "ThreadPool":
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - interpreter shutdown path
        try:
            self.close()
        except Exception:
            pass

    # -- scheduling internals ---------------------------------------------------

    def _outstanding(self) -> int:
        """Claimed-but-not-completed task count.

        Completed cells are summed *first*: every completion counted here
        had its claim recorded earlier (program order under the GIL), so
        the later claimed-sum includes it and the difference never goes
        negative — and a zero difference proves the pool is quiet.
        """
        done = sum(self._completed)
        return sum(self._claimed) - done

    def _wake_one(self, slot: int) -> None:
        """Targeted wakeup: pop one parked worker, set its event, and
        attribute the wakeup to the caller's counter cell.

        Call sites guard with ``if self._parked`` so the saturated hot
        path (nobody parked) never pays the method call.
        """
        try:
            idx = self._parked.popleft()
        except IndexError:
            return
        self._events[idx].set()
        self._wakeups[slot] += 1

    def _schedule(self, task: Task) -> None:
        """Claim ``task`` (one per-cell increment) and enqueue it.

        From a worker thread: push to the worker's own deque, found through
        the thread-local variable (paper §2.1) — lock-free. Otherwise:
        shared inbox (priority-banded FIFO) with the slot-n claim guarded
        by ``_ext_lock``. Either way, at most one parked worker is woken.
        """
        if self._observers:
            self._notify("on_submit", task)
        idx = getattr(self._tls, "index", None)
        if idx is not None:
            self._claimed[idx] += 1
            self._deques[idx].push(task)
            if self._parked:
                self._wake_one(idx)
        else:
            with self._ext_lock:
                self._claimed[-1] += 1
                self._inbox.push_external(task)
                if self._parked:
                    self._wake_one(-1)

    def _worker(self, index: int) -> None:
        self._tls.index = index
        own = self._deques[index]
        n = len(self._deques)
        ev = self._events[index]
        spins = 0
        while True:
            if self._stop:
                return
            task = self._next_task(index, own, n)
            if task is not EMPTY:
                spins = 0
                self._execute(task, index)
                continue
            if spins < _SPIN_SWEEPS:
                spins += 1
                time.sleep(0)  # yield the GIL so a producer can publish
                continue
            spins = 0
            # Park protocol: clear our event, *register*, then re-sweep.
            # Submitters push the task before scanning the registry, so any
            # push racing our failed sweep is re-observed here; any wakeup
            # aimed at us after registration leaves the event set, making
            # the wait below a no-op. One acquisition-free pass — the old
            # design's double condition-variable lock is gone.
            ev.clear()
            self._parked.append(index)
            self._parked_ct[index] += 1
            task = self._next_task(index, own, n)
            if task is not EMPTY:
                try:
                    self._parked.remove(index)
                except ValueError:
                    pass  # a submitter popped us; its wakeup is consumed below
                self._execute(task, index)
                continue
            if self._stop:  # close() may have raced our registration
                return
            ev.wait(_PARK_BACKSTOP_S)  # backstop only: wakeups are targeted
            try:
                self._parked.remove(index)
            except ValueError:
                pass

    def _next_task(self, index: int, own: Any, n: int) -> Any:
        # 1. own deque: highest priority band, LIFO (depth-first) within it
        task = own.pop()
        if task is not EMPTY:
            return task
        # 2. shared inbox (external submissions): highest band, FIFO within
        task = self._inbox.steal()
        if task is not EMPTY:
            # wake chain: surplus inbox work -> recruit one more sleeper
            if self._parked and len(self._inbox):
                self._wake_one(index)
            return task
        # 3. sweep victims, stealing from the top (highest band, FIFO)
        for k in range(1, n):
            victim = (index + k) % n
            vd = self._deques[victim]
            task = vd.steal()
            if task is not EMPTY:
                self._steals[index] += 1
                if self._parked and len(vd):
                    self._wake_one(index)
                if self._observers:
                    self._notify("on_steal", task, index, victim)
                return task
        return EMPTY

    def _execute(self, first: Task, index: int) -> None:
        """Run a task, then its ready successors via continuation passing.

        The fan-out (paper §2.2) is a fused decrement-and-pick loop: the
        running maximum-priority ready successor is kept as the inline
        continuation, every other ready successor is pushed straight onto
        this worker's own deque, and one batch wakeup recruits a sleeper.
        No intermediate ready list, no key-function allocation. Inline
        continuations are claimed *before* the finished task's completion
        cell is bumped, so the outstanding count never transiently hits
        zero mid-chain — the quiescence check runs only at chain end.
        """
        claimed = self._claimed
        own = self._deques[index]
        task: Optional[Task] = first
        while task is not None:
            if self._observers:
                self._notify("on_start", task, index)
            try:
                if self._first_error is not None and task.propagate_errors:
                    # fail-fast: skip bodies once the graph is poisoned, but
                    # keep draining dependencies so waiters unblock.
                    task.exception = CancelledError("predecessor failed")
                    task._done = True  # noqa: SLF001 - internal protocol
                else:
                    task.run()
            except BaseException as exc:  # noqa: BLE001 - recorded + re-raised in wait
                task.exception = exc
                if task.propagate_errors:
                    with self._err_lock:
                        if self._first_error is None:
                            self._first_error = exc
            self._executed[index] += 1
            if self._observers:
                self._notify("on_finish", task, index)
            cb = task.on_done
            if cb is not None:
                try:
                    cb(task)
                except BaseException:  # noqa: BLE001 - callback errors are dropped
                    pass
            # Fused fan-out: decrement successors, keep the max-priority
            # ready one inline, push the rest (claimed as they are pushed).
            inline: Optional[Task] = None
            inline_pr = 0.0
            pushed = 0
            for s in task.successors:
                if not s.decrement():
                    continue
                claimed[index] += 1
                if inline is None:
                    inline = s
                    inline_pr = s.priority
                elif s.priority > inline_pr:
                    if self._observers:
                        self._notify("on_submit", inline)
                    own.push(inline)
                    pushed += 1
                    inline = s
                    inline_pr = s.priority
                else:
                    if self._observers:
                        self._notify("on_submit", s)
                    own.push(s)
                    pushed += 1
            if pushed and self._parked:
                self._wake_one(index)  # the woken worker chains further
            self._completed[index] += 1
            task = inline
        # chain over: if anyone is waiting for quiescence, check and notify
        if self._idle_waiters and self._outstanding() == 0:
            with self._idle_cond:
                self._idle_cond.notify_all()
