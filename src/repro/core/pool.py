"""Work-stealing thread pool capable of running task graphs (paper §2).

Faithful Python adaptation of the paper's C++ design:

* one work-stealing deque per worker thread (``deque.py``);
* the current worker's deque is found through a **thread-local** variable
  (the paper's replacement for thread-ID→index maps, §2.1);
* a task submitted *from* a worker thread is pushed to that worker's own
  deque (depth-first, cache-friendly); tasks submitted from outside land in a
  shared MPMC inbox (Chase-Lev deques are single-producer — see deque.py);
* idle workers first pop their own deque, then drain the inbox, then sweep
  the other workers' deques stealing from the top, then park;
* task-graph execution by dependency counting (§2.2): when a task body
  completes, every successor's pending-predecessor counter is decremented;
  **one** newly-ready successor is executed inline on the same worker
  (continuation passing), the others are pushed.

Beyond the paper (DESIGN.md §3): task **priorities** — own-deque pops, inbox
draining, steals and the inline-continuation pick are all priority-aware
(highest band first; LIFO within a band on the owner's side, FIFO on the
thief/inbox side), the same ready-key the schedule simulator uses — and
**cooperative cancellation** surfaced through :class:`Future` and
``TaskGraph.as_future``. Both exist for the serving engine: decode ticks run
at high priority, speculative prefills at low priority, and aborted requests
cancel their in-flight work.

Also beyond the paper (DESIGN.md §8): an **observer layer**. Attached
observers (``core/observer.py``) see submit/start/finish/steal lifecycle
events, which is how the aggregate-stats and Chrome-trace exporters watch a
run without the pool knowing about either.

Differences from the C++ original are documented in DESIGN.md §2.1.
"""
from __future__ import annotations

import os
import threading
from typing import Any, Callable, Iterable, Optional, Sequence, Union

from .deque import EMPTY, ChaseLevDeque, FastDeque, PriorityDeque
from .task import CancelledError, Task, iter_graph

__all__ = ["ThreadPool", "Future"]

_PARK_TIMEOUT_S = 0.05  # bounded park: robust against missed wakeups


class Future:
    """Completion handle: result/exception delivery plus cooperative cancel.

    ``canceller`` (when attached by ``submit_future`` / ``as_future``) is a
    nullary callable returning True if the underlying work was prevented
    from starting. A bare ``Future()`` has no producer to stop, so
    :meth:`cancel` simply resolves it with :class:`CancelledError`.
    Resolution is first-write-wins: a producer completing after a successful
    cancel is ignored.
    """

    __slots__ = ("_event", "_result", "_exception", "_lock", "_canceller", "_cancelled")

    def __init__(self, canceller: Optional[Callable[[], bool]] = None) -> None:
        self._event = threading.Event()
        self._result: Any = None
        self._exception: Optional[BaseException] = None
        self._lock = threading.Lock()
        self._canceller = canceller
        self._cancelled = False

    def set_result(self, value: Any) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._result = value
            self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        with self._lock:
            if self._event.is_set():
                return
            self._exception = exc
            self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Try to cancel. True iff the body was prevented from running.

        Already-completed futures and tasks that already started return
        False (cooperative semantics: a running body is never interrupted).
        The canceller's verdict is authoritative: if it won, this returns
        True even when the skipped task's completion callback resolved the
        future (with CancelledError) concurrently.
        """
        with self._lock:
            if self._event.is_set() and not self._cancelled:
                return False
        if self._canceller is not None:
            if not self._canceller():
                return False
            with self._lock:
                self._cancelled = True
                if not self._event.is_set():
                    self._exception = CancelledError("future cancelled")
                    self._event.set()
            return True
        with self._lock:
            if self._event.is_set():
                return self._cancelled
            self._cancelled = True
            self._exception = CancelledError("future cancelled")
            self._event.set()
        return True

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._event.wait(timeout):
            raise TimeoutError("future not completed within timeout")
        if self._exception is not None:
            raise self._exception
        return self._result


class ThreadPool:
    """Work-stealing thread pool running async tasks and task graphs.

    Parameters
    ----------
    num_threads:
        Worker count; defaults to ``os.cpu_count()`` — the analogue of the
        paper's ``std::thread::hardware_concurrency()`` default.
    deque_cls:
        ``FastDeque`` (default, GIL-atomic / fence-free analogue) or
        ``ChaseLevDeque`` (faithful structural port; used in tests). Each
        worker's deque and the shared inbox are priority-banded instances
        of this class (``PriorityDeque``).
    observers:
        Initial observers (``core/observer.py`` protocol: on_submit /
        on_start / on_finish / on_steal). With no observers attached the
        hot path pays one falsy-list check per event site.
    """

    def __init__(
        self,
        num_threads: Optional[int] = None,
        *,
        deque_cls: type = FastDeque,
        name: str = "repro-pool",
        observers: Sequence[Any] = (),
    ) -> None:
        n = num_threads if num_threads is not None else (os.cpu_count() or 1)
        if n < 1:
            raise ValueError("num_threads must be >= 1")
        self._deques = [PriorityDeque(deque_cls) for _ in range(n)]
        self._inbox = PriorityDeque(FastDeque)  # MPMC under the GIL
        self._tls = threading.local()
        self._cond = threading.Condition()
        self._unfinished = 0  # tasks claimed but not yet completed
        self._stop = False
        self._first_error: Optional[BaseException] = None
        # Per-worker statistic cells (satellite fix: no cross-thread
        # increments; each worker owns one slot, stats() sums on read).
        # Slot n is for increments from non-worker threads (none today).
        self._executed = [0] * (n + 1)
        self._steals = [0] * (n + 1)
        self._observers: list[Any] = list(observers)
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), name=f"{name}-{i}", daemon=True)
            for i in range(n)
        ]
        for t in self._threads:
            t.start()

    # -- public API -----------------------------------------------------------

    @property
    def num_threads(self) -> int:
        return len(self._deques)

    def add_observer(self, observer: Any) -> None:
        """Attach a lifecycle observer (``core/observer.py`` protocol).

        Attach/detach are not synchronized against in-flight events: an
        observer attached mid-run may miss events already dispatched, which
        is fine for telemetry.
        """
        self._observers.append(observer)

    def remove_observer(self, observer: Any) -> None:
        """Detach a previously attached observer (no-op if absent)."""
        try:
            self._observers.remove(observer)
        except ValueError:
            pass

    def _notify(self, method: str, *args: Any) -> None:
        for obs in self._observers:
            try:
                getattr(obs, method)(*args)
            except BaseException:  # noqa: BLE001 - telemetry never poisons the pool
                pass

    def submit(
        self,
        work: Union[Task, Callable[[], Any], Iterable[Task]],
        *,
        priority: Optional[float] = None,
    ) -> None:
        """Submit a callable, a single Task, or a task graph (iterable).

        Graph submission mirrors the paper: counters of every task reachable
        from the collection are re-armed, then all roots (tasks with no
        predecessors) are scheduled. ``priority`` (when given) overrides the
        priority of a callable/single-task submission; graph tasks keep
        their own per-task priorities.
        """
        if isinstance(work, Task):
            if priority is not None:
                work.priority = priority
            self._schedule(work)
        elif callable(work):
            self._schedule(Task(work, priority=priority or 0.0))
        else:
            notify = getattr(work, "_notify_submitted", None)
            if notify is not None:  # TaskGraph bumps its run_count
                notify()
            tasks = list(work)
            graph = iter_graph(tasks)
            for t in graph:
                t.reset()
            roots = [t for t in graph if t.num_predecessors == 0]
            if not roots and graph:
                raise ValueError("task graph has no roots (dependency cycle?)")
            for t in roots:
                self._schedule(t)

    # paper-style alias
    Submit = submit

    def submit_future(self, fn: Callable[[], Any], *, priority: float = 0.0) -> Future:
        """Submit a callable and get a :class:`Future` for its result.

        The future supports cooperative :meth:`Future.cancel`; exceptions
        from ``fn`` are delivered via the future only and do not poison the
        pool.
        """
        task = Task(fn, priority=priority)
        task.propagate_errors = False
        fut = Future(canceller=task.cancel)

        def _resolve(t: Task) -> None:
            if t.exception is not None:
                fut.set_exception(t.exception)
            else:
                fut.set_result(t.result)

        task.on_done = _resolve
        self._schedule(task)
        return fut

    def wait_idle(self, timeout: Optional[float] = None) -> None:
        """Block until every claimed task has completed.

        Re-raises the first task exception, if any (then clears it).
        """
        with self._cond:
            if not self._cond.wait_for(lambda: self._unfinished == 0, timeout):
                raise TimeoutError("pool did not become idle within timeout")
            err, self._first_error = self._first_error, None
        if err is not None:
            raise err

    def run(self, work: Union[Task, Callable[[], Any], Iterable[Task]]) -> None:
        """``submit`` + ``wait_idle`` convenience."""
        self.submit(work)
        self.wait_idle()

    def close(self) -> None:
        """Stop the workers (idempotent). Pending tasks are abandoned."""
        with self._cond:
            if self._stop:
                return
            self._stop = True
            self._cond.notify_all()
        for t in self._threads:
            t.join()

    def stats(self) -> dict[str, int]:
        """Execution statistics, summed over the per-worker counters.

        Each worker increments only its own cell, so reads race at worst
        with a single in-flight increment per cell — the sum is exact for
        any quiesced pool and monotonically consistent for a live one.
        """
        return {"executed": sum(self._executed), "steals": sum(self._steals)}

    def __enter__(self) -> "ThreadPool":
        return self

    def __exit__(self, *exc_info: Any) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - interpreter shutdown path
        try:
            self.close()
        except Exception:
            pass

    # -- scheduling internals ---------------------------------------------------

    def _schedule(self, task: Task) -> None:
        """Claim ``task`` (+1 unfinished) and enqueue it.

        From a worker thread: push to the worker's own deque, found through
        the thread-local variable (paper §2.1). Otherwise: shared inbox
        (priority-banded FIFO).
        """
        with self._cond:
            self._unfinished += 1
            self._cond.notify()
        if self._observers:
            self._notify("on_submit", task)
        idx = getattr(self._tls, "index", None)
        if idx is not None:
            self._deques[idx].push(task)
        else:
            self._inbox.push_external(task)

    def _worker(self, index: int) -> None:
        self._tls.index = index
        own = self._deques[index]
        n = len(self._deques)
        while True:
            task = self._next_task(index, own, n)
            if task is EMPTY:
                with self._cond:
                    if self._stop:
                        return
                # Bounded park instead of a racy empty-recheck protocol: a
                # submit between our sweep and the wait costs at most one
                # timeout tick.
                with self._cond:
                    self._cond.wait(_PARK_TIMEOUT_S)
            else:
                self._execute(task, index)

    def _next_task(self, index: int, own: Any, n: int) -> Any:
        # 1. own deque: highest priority band, LIFO (depth-first) within it
        task = own.pop()
        if task is not EMPTY:
            return task
        # 2. shared inbox (external submissions): highest band, FIFO within
        task = self._inbox.steal()
        if task is not EMPTY:
            return task
        # 3. sweep victims, stealing from the top (highest band, FIFO)
        for k in range(1, n):
            victim = (index + k) % n
            task = self._deques[victim].steal()
            if task is not EMPTY:
                self._steals[index] += 1
                if self._observers:
                    self._notify("on_steal", task, index, victim)
                return task
        return EMPTY

    def _complete(self, task: Task) -> None:
        """Fire the task's completion callback (never poisons the pool)."""
        cb = task.on_done
        if cb is not None:
            try:
                cb(task)
            except BaseException:  # noqa: BLE001 - observer errors are dropped
                pass

    def _execute(self, first: Task, index: int) -> None:
        """Run a task, then its ready successors via continuation passing."""
        task: Optional[Task] = first
        while task is not None:
            if self._observers:
                self._notify("on_start", task, index)
            try:
                if self._first_error is not None and task.propagate_errors:
                    # fail-fast: skip bodies once the graph is poisoned, but
                    # keep draining dependencies so waiters unblock.
                    task.exception = CancelledError("predecessor failed")
                    task._done = True  # noqa: SLF001 - internal protocol
                else:
                    task.run()
            except BaseException as exc:  # noqa: BLE001 - recorded + re-raised in wait
                task.exception = exc
                if task.propagate_errors:
                    with self._cond:
                        if self._first_error is None:
                            self._first_error = exc
            self._executed[index] += 1
            if self._observers:
                self._notify("on_finish", task, index)
            self._complete(task)
            # Fan out (paper §2.2): decrement successors; run ONE newly-ready
            # successor inline — the highest-priority one, matching the
            # simulator's ready key — and push the rest.
            inline: Optional[Task] = None
            ready = [s for s in task.successors if s.decrement()]
            if ready:
                inline = max(ready, key=lambda s: s.priority)
                with self._cond:
                    self._unfinished += 1
                for s in ready:
                    if s is not inline:
                        self._schedule(s)
            with self._cond:
                self._unfinished -= 1
                if self._unfinished == 0:
                    self._cond.notify_all()
            task = inline
