"""Work-stealing deques — Python adaptations of the Chase-Lev deque.

The paper (Puyda 2024, §2.1) builds its thread pool on the Chase-Lev
work-stealing deque [Chase & Lev, SPAA'05; Le et al., PPoPP'13]: each worker
owns one deque, pushes and pops at the *bottom*, and thieves steal at the
*top*. The C/C++ implementations need careful atomics and memory fences; the
paper discusses sanitizer false positives around ``std::atomic_thread_fence``
and adopts the fence-free Google Filament variant.

CPython gives us a different memory model: the GIL serializes bytecodes, so a
single ``collections.deque`` operation is atomic and sequentially consistent.
Two adaptations are provided:

* :class:`FastDeque` — the production deque. ``collections.deque`` with the
  owner operating on the right end and thieves on the left end. Under the GIL
  every operation is atomic, so this is the moral equivalent of the fence-free
  Filament implementation: no locks on any path.

* :class:`ChaseLevDeque` — a faithful *structural* port of the Chase-Lev
  ring-buffer algorithm (explicit ``top``/``bottom`` indices, growable ring).
  CPython exposes no CAS, so the single compare-and-swap that guards the
  one-element owner/thief race is replaced by a lock acquired **only** on the
  steal path and on the owner's last-element path — exactly the race the CAS
  guards in C11. The common owner push/pop path takes no lock, mirroring the
  lock-free fast path of the original.

Both classes expose ``push`` (owner, bottom), ``pop`` (owner, bottom, LIFO)
and ``steal`` (any thread, top, FIFO); ``pop``/``steal`` return :data:`EMPTY`
when nothing was taken, allowing ``None`` payloads. Chase-Lev deques are
single-producer, so non-worker submissions go through the pool's shared MPMC
inbox (a :class:`FastDeque`, whose every op is GIL-atomic) rather than into a
worker's deque — see ``pool.py``.

:class:`PriorityDeque` layers task priorities on top (DESIGN.md §3): one
inner deque per distinct priority value ("band"), scanned highest-first.
Within a band the owner still pops LIFO and thieves steal FIFO, so the
pool's policy matches the schedule simulator's ``(-priority, -recency)``
ready key exactly. Most workloads use a single band (priority 0.0), for
which there is a **single-band fast path** (DESIGN.md §9): until the first
non-zero priority is pushed, push/pop/steal devolve to the bare inner
deque — no dict lookups, no band scan. The first non-zero priority
promotes the instance to banded mode permanently.
"""
from __future__ import annotations

import threading
from collections import deque as _pydeque
from typing import Any, Callable

__all__ = ["EMPTY", "FastDeque", "ChaseLevDeque", "PriorityDeque"]


class _Empty:
    """Sentinel distinguishing 'nothing taken' from a ``None`` payload."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<EMPTY>"

    def __bool__(self) -> bool:
        return False


EMPTY = _Empty()


class FastDeque:
    """GIL-atomic work-stealing deque (the default, fence-free analogue).

    Owner pushes/pops at the right end (LIFO — depth-first execution order,
    which is what makes recursive task graphs cache-friendly); thieves steal
    at the left end (FIFO — stealing the *oldest*, typically largest, task).
    ``collections.deque.append/pop/popleft`` are each a single bytecode in
    CPython, hence atomic under the GIL, so no fences or locks are needed —
    the GIL plays the role the memory-model proofs play for the C11 code.
    """

    __slots__ = ("_q",)

    def __init__(self) -> None:
        self._q: _pydeque[Any] = _pydeque()

    def push(self, item: Any) -> None:
        """Owner-side push at the bottom (right)."""
        self._q.append(item)

    def push_external(self, item: Any) -> None:
        """Submission from a non-owner thread.

        Pushed at the *top* (left) so external work is stolen/obtained in FIFO
        order and the owner's LIFO hot path is undisturbed. Atomic under GIL.
        """
        self._q.appendleft(item)

    def pop(self) -> Any:
        """Owner-side pop at the bottom (right). Returns EMPTY if none."""
        try:
            return self._q.pop()
        except IndexError:
            return EMPTY

    def steal(self) -> Any:
        """Thief-side steal at the top (left). Returns EMPTY if none."""
        try:
            return self._q.popleft()
        except IndexError:
            return EMPTY

    def __len__(self) -> int:
        return len(self._q)


class ChaseLevDeque:
    """Structural port of the Chase-Lev growable ring-buffer deque.

    Layout follows Le et al. (PPoPP'13): ``_top`` and ``_bottom`` are
    monotonically increasing 64-bit-style indices into a power-of-two ring.
    The owner manipulates ``_bottom``; thieves advance ``_top``.

    The C11 version resolves the owner/thief race on the *last* element with a
    CAS on ``top``. CPython has no CAS, so ``_lock`` protects exactly that
    race: every steal holds it, and the owner takes it only when it observes
    ``bottom - 1 == top`` (one element left). The owner's multi-element
    push/pop path is lock-free, as in the original.
    """

    __slots__ = ("_buf", "_mask", "_top", "_bottom", "_lock")

    def __init__(self, capacity: int = 64) -> None:
        if capacity & (capacity - 1):
            raise ValueError("capacity must be a power of two")
        self._buf: list[Any] = [None] * capacity
        self._mask = capacity - 1
        self._top = 0
        self._bottom = 0
        self._lock = threading.Lock()

    # -- owner side ---------------------------------------------------------

    def push(self, item: Any) -> None:
        b = self._bottom
        t = self._top
        if b - t > self._mask:  # full: grow (rare; lock so thieves see a
            with self._lock:  # consistent buffer during the copy)
                self._grow()
        self._buf[b & self._mask] = item
        # Publication point. In C11 this is a release store of `bottom`;
        # under the GIL a plain store is sequentially consistent.
        self._bottom = b + 1

    def pop(self) -> Any:
        b = self._bottom - 1
        self._bottom = b  # reserve slot b (C11: relaxed store + SC fence)
        t = self._top
        if b < t:  # deque was empty
            self._bottom = t
            return EMPTY
        if b > t:  # more than one element: no race possible on slot b
            item = self._buf[b & self._mask]
            self._buf[b & self._mask] = None
            return item
        # exactly one element left: the CAS-guarded race
        with self._lock:
            t = self._top
            if t <= b:  # we won: claim it by advancing top past it
                item = self._buf[b & self._mask]
                self._buf[b & self._mask] = None
                self._top = t + 1
                self._bottom = t + 1
                return item
            self._bottom = t  # lost to a thief
            return EMPTY

    # -- thief side ----------------------------------------------------------

    def steal(self) -> Any:
        with self._lock:
            t = self._top
            if t >= self._bottom:
                return EMPTY
            item = self._buf[t & self._mask]
            self._buf[t & self._mask] = None
            self._top = t + 1
            return item

    # -- internals -----------------------------------------------------------

    def _grow(self) -> None:
        """Double the ring. Caller holds ``_lock``."""
        old, mask = self._buf, self._mask
        cap = (mask + 1) * 2
        buf = [None] * cap
        for i in range(self._top, self._bottom):
            buf[i & (cap - 1)] = old[i & mask]
        self._buf = buf
        self._mask = cap - 1

    def __len__(self) -> int:
        return max(0, self._bottom - self._top)


class PriorityDeque:
    """Priority-banded work-stealing deque with a single-band fast path.

    Items are routed to an inner deque per ``item.priority`` (items without
    the attribute land in band 0.0). ``pop``/``steal`` scan bands from the
    highest priority down; within a band the usual deque discipline applies
    (owner LIFO at the bottom, thieves FIFO at the top), reproducing the
    simulator's max-heap-on-(priority, recency) ready queue.

    **Single-band fast path (DESIGN.md §9).** Band 0.0 exists from birth
    (``_fast``) and the instance starts un-banded: while only priority 0.0
    has ever been pushed, every operation is exactly one attribute check on
    top of the bare inner deque — no dict lookup, no band scan. The first
    non-zero priority *promotes* the instance to banded mode (a one-way
    transition, taken under ``_lock``). ``_fast`` *is* band 0.0 in the
    band map, so a racing fast-path push lands in the correct band no
    matter when the promotion flag becomes visible to it.

    Concurrency: the band map only ever grows. Creating a band takes a lock;
    ``_order`` is then *replaced* (never mutated) with a freshly sorted list,
    so readers iterating a stale snapshot miss at most a band created after
    their scan began — the same transient under-observation any thief has
    against a concurrent push, and the next scan sees it. All per-band
    operations inherit the inner deque's lock-free/GIL-atomic guarantees.
    """

    __slots__ = ("_deque_cls", "_fast", "_banded", "_bands", "_order", "_lock")

    def __init__(self, deque_cls: Callable[[], Any] = None) -> None:
        self._deque_cls = deque_cls or FastDeque
        self._fast = self._deque_cls()  # band 0.0, present from birth
        self._banded = False
        self._bands: dict[float, Any] = {0.0: self._fast}
        self._order: list[float] = [0.0]  # priorities, descending
        self._lock = threading.Lock()

    @property
    def banded(self) -> bool:
        """True once a non-zero priority has promoted this instance."""
        return self._banded

    def _band(self, priority: float) -> Any:
        band = self._bands.get(priority)
        if band is None:
            with self._lock:
                band = self._bands.get(priority)
                if band is None:
                    band = self._deque_cls()
                    self._bands[priority] = band
                    self._order = sorted(self._bands, reverse=True)
                self._banded = True  # only non-0.0 priorities reach here
        return band

    def push(self, item: Any) -> None:
        """Push at the bottom of the item's priority band.

        Combined with band-scanning ``steal`` this also gives the MPMC
        inbox priority-then-FIFO ordering (higher bands drain first, arrival
        order within a band), so the external-submission path is the same
        operation.
        """
        priority = getattr(item, "priority", 0.0)
        if priority == 0.0 and not self._banded:
            self._fast.push(item)
            return
        self._band(priority).push(item)

    push_external = push

    def pop(self) -> Any:
        """Owner-side pop: highest band first, LIFO within the band."""
        if not self._banded:
            return self._fast.pop()
        for pr in self._order:
            item = self._bands[pr].pop()
            if item is not EMPTY:
                return item
        return EMPTY

    def steal(self) -> Any:
        """Thief-side steal: highest band first, FIFO within the band."""
        if not self._banded:
            return self._fast.steal()
        for pr in self._order:
            item = self._bands[pr].steal()
            if item is not EMPTY:
                return item
        return EMPTY

    def __len__(self) -> int:
        if not self._banded:
            return len(self._fast)
        # iterate the _order snapshot, not the dict: a concurrent first push
        # to a new band may grow _bands mid-iteration
        return sum(len(self._bands[p]) for p in self._order)

    def depths(self) -> dict[float, int]:
        """Per-band queue depth, highest priority first (DESIGN.md §13).

        A monitoring snapshot with the same consistency as ``__len__``:
        exact when quiesced, transiently stale against concurrent pushes.
        Empty bands are reported too — a band that existed once can refill.
        """
        if not self._banded:
            return {0.0: len(self._fast)}
        return {p: len(self._bands[p]) for p in self._order}
