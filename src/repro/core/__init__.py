"""repro.core — the paper's contribution: a work-stealing thread pool capable
of running task graphs (Puyda 2024), plus the trace-time schedule simulator
that adapts its execution policy to statically-scheduled TPU programs."""
from .baseline import NaiveThreadPool, SerialExecutor
from .deque import EMPTY, ChaseLevDeque, FastDeque, PriorityDeque
from .graph import CycleError, Module, TaskGraph
from .observer import ChromeTraceObserver, PoolObserver, StatsObserver
from .pool import Future, ThreadPool
from .schedule import (
    PipelineOp,
    SimResult,
    SimTask,
    gpipe_schedule,
    peak_activation_buffers,
    pipeline_schedule,
    pipeline_task_graph,
    schedule_to_table,
    simulate,
)
from .task import CancelledError, Task, iter_graph

__all__ = [
    "NaiveThreadPool",
    "SerialExecutor",
    "EMPTY",
    "ChaseLevDeque",
    "FastDeque",
    "PriorityDeque",
    "CycleError",
    "Module",
    "TaskGraph",
    "Future",
    "ThreadPool",
    "PoolObserver",
    "StatsObserver",
    "ChromeTraceObserver",
    "CancelledError",
    "Task",
    "iter_graph",
    "PipelineOp",
    "SimResult",
    "SimTask",
    "simulate",
    "pipeline_task_graph",
    "pipeline_schedule",
    "gpipe_schedule",
    "schedule_to_table",
    "peak_activation_buffers",
]
