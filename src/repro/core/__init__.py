"""repro.core — the paper's contribution: a work-stealing thread pool capable
of running task graphs (Puyda 2024), plus the trace-time schedule simulator
that adapts its execution policy to statically-scheduled TPU programs.

The public front door is the :class:`Executor` facade (DESIGN.md §10):
condition tasks, dynamic subflows, futures and the asyncio bridge all hang
off it. The lower layers remain importable for drop-in paper fidelity."""
from .baseline import NaiveThreadPool, SerialExecutor, SerialPool
from .chaos import ChaosError, FaultInjector
from .deque import EMPTY, ChaseLevDeque, FastDeque, PriorityDeque
from .executor import Executor
from .graph import CycleError, Module, Runtime, TaskGraph
from .observer import ChromeTraceObserver, PoolObserver, StatsObserver
from .pool import Future, RunContext, ThreadPool, checkpoint
from .replay import ReplayPlan
from .schedule import (
    PipelineOp,
    SimResult,
    SimTask,
    gpipe_schedule,
    peak_activation_buffers,
    pipeline_schedule,
    pipeline_task_graph,
    schedule_to_table,
    simulate,
)
from .task import CancelledError, RetryPolicy, Task, TaskTimeoutError, iter_graph

__all__ = [
    "NaiveThreadPool",
    "SerialExecutor",
    "SerialPool",
    "ChaosError",
    "FaultInjector",
    "RetryPolicy",
    "TaskTimeoutError",
    "checkpoint",
    "EMPTY",
    "ChaseLevDeque",
    "FastDeque",
    "PriorityDeque",
    "CycleError",
    "Module",
    "Runtime",
    "TaskGraph",
    "Executor",
    "Future",
    "RunContext",
    "ThreadPool",
    "ReplayPlan",
    "PoolObserver",
    "StatsObserver",
    "ChromeTraceObserver",
    "CancelledError",
    "Task",
    "iter_graph",
    "PipelineOp",
    "SimResult",
    "SimTask",
    "simulate",
    "pipeline_task_graph",
    "pipeline_schedule",
    "gpipe_schedule",
    "schedule_to_table",
    "peak_activation_buffers",
]
