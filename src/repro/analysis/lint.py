"""Graph linter: rule-based static analysis over a :class:`TaskGraph`.

DESIGN.md §15. The scheduler executes whatever graph it is handed; after
conditions (§10), subflows, retries (§14) and cross-process placement
(§11) a misbuilt graph fails at *runtime* — or silently misbehaves. The
linter moves those failures to build time: each rule walks the
:meth:`TaskGraph.edges` introspection surface (never reimplementing
edge-strength semantics) and yields structured :class:`Finding` records.

Rule catalog (``rule_catalog()`` renders it):

========================  ========  =====================================
rule                      severity  fires when
========================  ========  =====================================
strong-cycle              error     a cycle of strong edges (deadlock —
                                    the §8 countdown can never drain)
unreachable-task          error     no path from any source task; or the
                                    task waits on predecessors outside
                                    the graph container
orphan-task               warning   ``fn=None`` placeholder with no edges
condition-branch-range    warning/  a condition provably returns an index
                          error     outside its declared successors (the
                                    loop-exit idiom is exempt inside a
                                    cycle); *error* when **no** return
                                    can ever select a branch
weak-loop-no-exit         error     every condition in a weak-edge loop
                                    provably re-enters the loop — no
                                    terminating branch is reachable
priority-inversion        warning   a strong edge where the successor's
                                    band outranks its predecessor's (the
                                    high-priority task queues behind
                                    low-priority work)
retry-non-idempotent      warning   retry policy on a non-idempotent body
                                    that can offload to a worker process
                                    (§14's at-most-once gate silently
                                    disables started-body retries)
remote-unpicklable        error     ``affinity="remote"`` body that fails
                                    the §11 wire probe
                                    (:func:`repro.dist.picklability_error`)
affinity-ignored          warning   ``affinity="remote"`` on a body that
                                    is parent-pinned by §10/§11 rules
                                    (condition / spawner / ``fn=None``)
timeout-control-flow      warning   ``timeout=`` on a parent-pinned
                                    control-flow task (the §14 watchdog
                                    cannot preempt the scheduler)
shared-state-race         error     (from :mod:`~repro.analysis.races`)
                                    two bodies write the same closure
                                    cell / global / object attribute with
                                    no happens-before path between them
========================  ========  =====================================

Analyses are conservative: a dynamically-computed condition return or an
opaque write target yields *no* finding rather than a guess, so a clean
report is meaningful and the shipped consumers (serve tick graph,
prefetch lanes, checkpoint subflows) lint clean by construction.

CLI: ``python -m repro.analysis.lint [--strict] script.py [args...]``
runs the script and lints every graph it builds (exit 1 on errors; with
``--strict`` on any finding).
"""
from __future__ import annotations

import dis
import types
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator, Optional

from repro.core.graph import TaskGraph
from repro.core.task import Task

__all__ = ["Finding", "LintContext", "lint_graph", "rule_catalog", "RULES", "main"]

ERROR = "error"
WARNING = "warning"


@dataclass(frozen=True)
class Finding:
    """One structured lint verdict.

    ``rule`` names the catalog entry, ``severity`` is ``"error"`` (the
    graph will fail or misbehave at runtime) or ``"warning"`` (legal but
    almost certainly not what the author meant), ``tasks`` names the
    offending tasks in path/discovery order, and ``graph`` labels the
    container so multi-graph reports stay attributable.
    """

    rule: str
    severity: str
    message: str
    tasks: tuple[str, ...] = ()
    graph: str = ""

    def __str__(self) -> str:
        where = f" [{', '.join(self.tasks)}]" if self.tasks else ""
        return f"{self.severity}[{self.rule}] graph {self.graph!r}: {self.message}{where}"


@dataclass(frozen=True)
class Rule:
    name: str
    severity: str
    doc: str
    fn: Callable[["LintContext"], Iterable[tuple]] = field(compare=False)


#: Registry of every lint rule, in registration (catalog) order.
RULES: dict[str, Rule] = {}


def _rule(name: str, severity: str) -> Callable:
    def deco(fn: Callable[["LintContext"], Iterable[tuple]]) -> Callable:
        RULES[name] = Rule(name, severity, (fn.__doc__ or "").strip(), fn)
        return fn

    return deco


def rule_catalog() -> str:
    """Human-readable rule listing (name, default severity, summary)."""
    lines = []
    for r in RULES.values():
        summary = r.doc.splitlines()[0] if r.doc else ""
        lines.append(f"{r.name:<24} {r.severity:<8} {summary}")
    return "\n".join(lines)


# -- bytecode helpers (shared with races.py) -----------------------------------


def unwrap_callable(fn: Any) -> tuple[Optional[types.FunctionType], Any]:
    """Peel a task body down to ``(plain function, bound self or None)``.

    Handles bound methods and ``functools.partial`` chains; anything else
    (C callables, callables with ``__call__``) returns ``(None, None)`` —
    bytecode analyses then decline to judge rather than guess.
    """
    import functools

    self_obj = None
    while isinstance(fn, functools.partial):
        fn = fn.func
    if isinstance(fn, types.MethodType):
        self_obj = fn.__self__
        fn = fn.__func__
    while isinstance(fn, functools.partial):
        fn = fn.func
    if isinstance(fn, types.FunctionType):
        return fn, self_obj
    return None, None


def const_returns(fn: Any) -> tuple[Optional[set], bool]:
    """``(constant return values, every return is constant)`` for a body.

    A ``dis`` scan collecting ``LOAD_CONST; RETURN_VALUE`` pairs (and
    3.12's ``RETURN_CONST``). ``(None, False)`` means the body could not
    be analyzed at all; a non-constant return path clears the second
    element so callers can tell "provably always constant" from "some
    constants observed". Returns inside ``with``/``try`` cleanup blocks
    read as non-constant — the analysis stays conservative.
    """
    func, _self = unwrap_callable(fn)
    if func is None:
        return None, False
    consts: set = set()
    all_const = True
    prev: Optional[dis.Instruction] = None
    for ins in dis.get_instructions(func.__code__):
        if ins.opname == "RETURN_VALUE":
            if prev is not None and prev.opname == "LOAD_CONST":
                try:
                    consts.add(prev.argval)
                except TypeError:  # unhashable const: treat as dynamic
                    all_const = False
            else:
                all_const = False
        elif ins.opname == "RETURN_CONST":  # pragma: no cover - 3.12+
            try:
                consts.add(ins.argval)
            except TypeError:
                all_const = False
        prev = ins
    return consts, all_const


def selects_branch(value: Any, num_successors: int) -> bool:
    """True iff :func:`repro.core.graph.select_branch` would release a
    successor for a condition returning ``value``."""
    if isinstance(value, bool):
        value = int(value)
    return isinstance(value, int) and 0 <= value < num_successors


# -- the analysis context ------------------------------------------------------


class LintContext:
    """Shared, lazily-computed graph facts handed to every rule.

    Wraps one :class:`TaskGraph` plus the optional backend the graph is
    about to run on (``"serial"``/``"thread"``/``"process"`` — placement
    rules sharpen when the backend is known). All derived structure
    (adjacency, SCCs, reachability) is computed once and memoized.
    """

    def __init__(self, graph: TaskGraph, *, backend: Optional[str] = None) -> None:
        self.graph = graph
        self.backend = backend
        self.tasks: list[Task] = list(graph.tasks)
        self.edges: list[tuple[Task, Task, bool]] = graph.edges()
        self._contained = {id(t) for t in self.tasks}
        self._succ_all: Optional[dict[int, list[Task]]] = None
        self._strong_cycle: Optional[list[Task]] = None
        self._strong_cycle_done = False
        self._sccs: Optional[list[list[Task]]] = None
        self._scc_of: dict[int, int] = {}
        self._cyclic_sccs: Optional[set[int]] = None

    def name(self, t: Task) -> str:
        return t.name or f"<task@{id(t):x}>"

    def contains(self, t: Task) -> bool:
        return id(t) in self._contained

    @property
    def succ_all(self) -> dict[int, list[Task]]:
        """In-container adjacency over *all* edges (strong and weak)."""
        if self._succ_all is None:
            adj: dict[int, list[Task]] = {id(t): [] for t in self.tasks}
            for u, v, _strong in self.edges:
                if id(v) in self._contained:
                    adj[id(u)].append(v)
            self._succ_all = adj
        return self._succ_all

    def internal_strong_indegree(self) -> dict[int, int]:
        indeg = {id(t): 0 for t in self.tasks}
        for _u, v, strong in self.edges:
            if strong and id(v) in indeg:
                indeg[id(v)] += 1
        return indeg

    def reachable_from_sources(self) -> set[int]:
        seen: set[int] = set()
        stack = [t for t in self.tasks if t.is_source]
        seen.update(id(t) for t in stack)
        while stack:
            for s in self.succ_all[id(stack.pop())]:
                if id(s) not in seen:
                    seen.add(id(s))
                    stack.append(s)
        return seen

    @property
    def strong_cycle(self) -> Optional[list[Task]]:
        """One witness strong cycle (path, first task repeated), or None."""
        if not self._strong_cycle_done:
            self._strong_cycle = self.graph.find_strong_cycle()
            self._strong_cycle_done = True
        return self._strong_cycle

    def strong_cycle_members(self) -> set[int]:
        """Ids of tasks whose strong in-degree never drains under Kahn —
        cycle members *and* everything strongly downstream of them."""
        from collections import deque

        indeg = {id(t): t.num_predecessors for t in self.tasks}
        q = deque(t for t in self.tasks if t.num_predecessors == 0)
        remaining = dict(indeg)
        while q:
            t = q.popleft()
            remaining.pop(id(t), None)
            if t.is_condition:
                continue
            for s in t.successors:
                if id(s) in indeg:
                    indeg[id(s)] -= 1
                    if indeg[id(s)] == 0:
                        q.append(s)
        return set(remaining)

    @property
    def sccs(self) -> list[list[Task]]:
        """Strongly-connected components over all edges (iterative Tarjan)."""
        if self._sccs is None:
            adj = self.succ_all
            index: dict[int, int] = {}
            low: dict[int, int] = {}
            on_stack: set[int] = set()
            stack: list[Task] = []
            sccs: list[list[Task]] = []
            counter = [0]

            for root in self.tasks:
                if id(root) in index:
                    continue
                work: list[tuple[Task, int]] = [(root, 0)]
                while work:
                    node, pi = work[-1]
                    nid = id(node)
                    if pi == 0:
                        index[nid] = low[nid] = counter[0]
                        counter[0] += 1
                        stack.append(node)
                        on_stack.add(nid)
                    advanced = False
                    succs = adj[nid]
                    while pi < len(succs):
                        s = succs[pi]
                        pi += 1
                        work[-1] = (node, pi)
                        if id(s) not in index:
                            work.append((s, 0))
                            advanced = True
                            break
                        if id(s) in on_stack:
                            low[nid] = min(low[nid], index[id(s)])
                    if advanced:
                        continue
                    work.pop()
                    if low[nid] == index[nid]:
                        comp: list[Task] = []
                        while True:
                            w = stack.pop()
                            on_stack.discard(id(w))
                            comp.append(w)
                            if w is node:
                                break
                        for t in comp:
                            self._scc_of[id(t)] = len(sccs)
                        sccs.append(comp)
                    if work:
                        parent, _ = work[-1]
                        low[id(parent)] = min(low[id(parent)], low[nid])
            self._sccs = sccs
        return self._sccs

    def scc_of(self, t: Task) -> int:
        _ = self.sccs
        return self._scc_of[id(t)]

    def cyclic_sccs(self) -> set[int]:
        """Indices of SCCs that contain a cycle (size > 1, or a self-loop)."""
        if self._cyclic_sccs is None:
            out: set[int] = set()
            for i, comp in enumerate(self.sccs):
                if len(comp) > 1:
                    out.add(i)
                else:
                    t = comp[0]
                    if any(s is t for s in self.succ_all[id(t)]):
                        out.add(i)
            self._cyclic_sccs = out
        return self._cyclic_sccs

    def in_cycle(self, t: Task) -> bool:
        return self.scc_of(t) in self.cyclic_sccs()


# -- rules ---------------------------------------------------------------------


@_rule("strong-cycle", ERROR)
def _r_strong_cycle(ctx: LintContext) -> Iterator[tuple]:
    """A cycle of strong edges: the countdown protocol deadlocks."""
    cyc = ctx.strong_cycle
    if cyc is not None:
        path = " -> ".join(ctx.name(t) for t in cyc)
        yield (
            "strong dependency cycle (deadlock — no task in it can ever become "
            f"ready): {path}",
            tuple(ctx.name(t) for t in cyc[:-1]),
        )


@_rule("unreachable-task", ERROR)
def _r_unreachable(ctx: LintContext) -> Iterator[tuple]:
    """No execution path from any source task reaches this task."""
    reach = ctx.reachable_from_sources()
    cycle_members = ctx.strong_cycle_members() if ctx.strong_cycle else set()
    internal = ctx.internal_strong_indegree()
    for t in ctx.tasks:
        if id(t) in reach or id(t) in cycle_members:
            continue  # cycle members are the strong-cycle rule's report
        if t.num_predecessors > internal[id(t)]:
            yield (
                f"task {ctx.name(t)!r} waits on {t.num_predecessors - internal[id(t)]} "
                "strong predecessor(s) outside this graph — it can never start from "
                "this graph's submission",
                (ctx.name(t),),
            )
        else:
            yield (
                f"task {ctx.name(t)!r} is unreachable from every source task",
                (ctx.name(t),),
            )


@_rule("orphan-task", WARNING)
def _r_orphan(ctx: LintContext) -> Iterator[tuple]:
    """A ``fn=None`` placeholder with no edges: runs, computes nothing."""
    if len(ctx.tasks) <= 1:
        return
    for t in ctx.tasks:
        if t.fn is None and not t.takes_runtime and t.is_source and not t.successors:
            yield (
                f"task {ctx.name(t)!r} has no body and no edges — a placeholder "
                "that was never wired in",
                (ctx.name(t),),
            )


@_rule("condition-branch-range", WARNING)
def _r_branch_range(ctx: LintContext) -> Iterator[tuple]:
    """A condition's constant return indexes outside its declared branches."""
    for t in ctx.tasks:
        if not t.is_condition:
            continue
        n = len(t.successors)
        if n == 0:
            yield (
                f"condition {ctx.name(t)!r} declares no successors — its result "
                "can never select a branch",
                (ctx.name(t),),
            )
            continue
        consts, all_const = const_returns(t.fn)
        if consts is None or not consts:
            continue  # dynamic body: decline to judge
        misses = sorted((c for c in consts if not selects_branch(c, n)), key=repr)
        if all_const and len(misses) == len(consts):
            yield (
                f"condition {ctx.name(t)!r} can only return {misses!r} — no return "
                f"value ever selects one of its {n} declared branch(es)",
                (ctx.name(t),),
                ERROR,
            )
            continue
        if misses and not ctx.in_cycle(t):
            yield (
                f"condition {ctx.name(t)!r} returns {misses!r}, outside declared "
                f"branches 0..{n - 1}; outside a cycle that selects nothing (the "
                "loop-exit idiom only makes sense inside a weak-edge loop)",
                (ctx.name(t),),
            )


@_rule("weak-loop-no-exit", ERROR)
def _r_weak_loop(ctx: LintContext) -> Iterator[tuple]:
    """A weak-edge loop in which no terminating branch is reachable."""
    strong_members = ctx.strong_cycle_members() if ctx.strong_cycle else set()
    for i in ctx.cyclic_sccs():
        comp = ctx.sccs[i]
        if any(id(t) in strong_members for t in comp):
            continue  # the strong-cycle rule owns this report
        conditions = [t for t in comp if t.is_condition]
        if not conditions:
            continue
        exit_possible = False
        for c in conditions:
            consts, all_const = const_returns(c.fn)
            if consts is None or not all_const or not consts:
                exit_possible = True  # dynamic return: cannot prove no exit
                break
            for r in consts:
                if not selects_branch(r, len(c.successors)):
                    exit_possible = True  # selects nothing: the loop drains
                    break
                target = c.successors[int(r)]
                if not ctx.contains(target) or ctx.scc_of(target) != i:
                    exit_possible = True  # branch leaves the loop
                    break
            if exit_possible:
                break
        if not exit_possible:
            names = tuple(ctx.name(t) for t in comp)
            yield (
                "weak-edge loop has no reachable terminating branch — every "
                f"condition in it provably re-enters the loop: {', '.join(names)}",
                names,
            )


@_rule("priority-inversion", WARNING)
def _r_priority_inversion(ctx: LintContext) -> Iterator[tuple]:
    """A strong edge whose successor outranks its predecessor's band."""
    for u, v, strong in ctx.edges:
        if not strong or not ctx.contains(v):
            continue
        if v.priority > u.priority:
            yield (
                f"strong edge {ctx.name(u)!r} (priority {u.priority:g}) -> "
                f"{ctx.name(v)!r} (priority {v.priority:g}): the high-priority "
                "successor queues behind lower-priority work it depends on",
                (ctx.name(u), ctx.name(v)),
            )


@_rule("retry-non-idempotent", WARNING)
def _r_retry_non_idempotent(ctx: LintContext) -> Iterator[tuple]:
    """Retry policy on a non-idempotent body that can offload to a worker."""
    for t in ctx.tasks:
        if t.retry_policy is None or t.idempotent:
            continue
        if t.is_condition or t.takes_runtime or t.fn is None:
            continue
        offloadable = t.affinity == "remote" or (
            ctx.backend == "process" and t.affinity == "any"
        )
        if offloadable:
            yield (
                f"task {ctx.name(t)!r} carries a retry policy but is not marked "
                "idempotent: on the process backend, §14's at-most-once gate "
                "refuses to re-run a started body, so worker loss mid-body is "
                "never retried — mark idempotent=True or pin affinity='local'",
                (ctx.name(t),),
            )


@_rule("remote-unpicklable", ERROR)
def _r_remote_unpicklable(ctx: LintContext) -> Iterator[tuple]:
    """An ``affinity="remote"`` body that cannot cross the §11 wire."""
    probe = None
    for t in ctx.tasks:
        if t.affinity != "remote" or t.fn is None or t.is_condition or t.takes_runtime:
            continue
        if probe is None:
            from repro.dist.wire import picklability_error as probe  # lazy: §11 opt-in
        err = probe(t.fn)
        if err is not None:
            yield (
                f"task {ctx.name(t)!r} demands affinity='remote' but its body "
                f"cannot be wired to a worker process: {err}",
                (ctx.name(t),),
            )


@_rule("affinity-ignored", WARNING)
def _r_affinity_ignored(ctx: LintContext) -> Iterator[tuple]:
    """``affinity="remote"`` on a body §10/§11 pin to the parent."""
    for t in ctx.tasks:
        if t.affinity != "remote":
            continue
        if t.is_condition or t.takes_runtime or t.fn is None:
            kind = (
                "a condition task"
                if t.is_condition
                else "a subflow spawner" if t.takes_runtime else "a bodyless task"
            )
            yield (
                f"task {ctx.name(t)!r} is {kind}, which always runs in the parent "
                "process — affinity='remote' can never be honored",
                (ctx.name(t),),
            )


@_rule("timeout-control-flow", WARNING)
def _r_timeout_control_flow(ctx: LintContext) -> Iterator[tuple]:
    """``timeout=`` on a parent-pinned control-flow task."""
    for t in ctx.tasks:
        if t.timeout is None or not (t.is_condition or t.takes_runtime):
            continue
        kind = "condition" if t.is_condition else "subflow spawner"
        yield (
            f"{kind} task {ctx.name(t)!r} declares timeout={t.timeout:g}: control "
            "flow runs inline in the scheduler, so the §14 watchdog can flag the "
            "deadline but never preempt or retry the body",
            (ctx.name(t),),
        )


# -- driver --------------------------------------------------------------------


def lint_graph(
    graph: TaskGraph,
    *,
    backend: Optional[str] = None,
    races: bool = True,
    rules: Optional[Iterable[str]] = None,
) -> list[Finding]:
    """Run the rule catalog (plus the §15 race detector) over ``graph``.

    ``backend`` sharpens placement rules when known; ``races=False``
    skips the bytecode write-race scan; ``rules`` restricts to a subset
    of catalog names (unknown names raise ``KeyError``). Findings come
    back in catalog order, races last.
    """
    ctx = LintContext(graph, backend=backend)
    selected = (
        list(RULES.values())
        if rules is None
        else [RULES[name] for name in rules if name != "shared-state-race"]
    )
    gname = graph.name or "<anonymous>"
    findings: list[Finding] = []
    for r in selected:
        for item in r.fn(ctx):
            message, tasks = item[0], item[1]
            severity = item[2] if len(item) > 2 else r.severity
            findings.append(Finding(r.name, severity, message, tuple(tasks), gname))
    if races and (rules is None or "shared-state-race" in set(rules)):
        from .races import detect_races  # sibling: no cycle at import time

        findings.extend(detect_races(graph, ctx=ctx))
    return findings


def format_findings(findings: Iterable[Finding]) -> str:
    return "\n".join(str(f) for f in findings)


# -- CLI -----------------------------------------------------------------------


def _lintable(graph: TaskGraph) -> bool:
    """Skip empty graphs and stale containers whose tasks were adopted
    elsewhere (``compose`` leaves the inner container behind)."""
    return len(graph.tasks) > 0 and all(t.graph is graph for t in graph.tasks)


def main(argv: Optional[list[str]] = None) -> int:
    import argparse
    import runpy
    import sys

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description=(
            "Run a script and lint every TaskGraph it builds. Exit 1 on "
            "error-severity findings (with --strict, on any finding)."
        ),
    )
    parser.add_argument("--strict", action="store_true", help="fail on warnings too")
    parser.add_argument("--no-races", action="store_true", help="skip the race scan")
    parser.add_argument(
        "--backend", default=None, help="assume this backend for placement rules"
    )
    parser.add_argument("--rules", action="store_true", help="print the rule catalog")
    parser.add_argument("script", nargs="?", help="script to execute and lint")
    parser.add_argument("args", nargs=argparse.REMAINDER, help="script arguments")
    opts = parser.parse_args(argv)

    if opts.rules:
        print(rule_catalog())
        return 0
    if opts.script is None:
        parser.error("a script to lint is required (or --rules)")

    registry: list[TaskGraph] = []
    orig_init = TaskGraph.__init__

    def tracking_init(self: TaskGraph, name: str = "") -> None:
        orig_init(self, name)
        if len(registry) < 1024:
            registry.append(self)

    TaskGraph.__init__ = tracking_init  # type: ignore[method-assign]
    saved_argv = sys.argv
    script_rc = 0
    try:
        sys.argv = [opts.script] + list(opts.args)
        runpy.run_path(opts.script, run_name="__main__")
    except SystemExit as exc:  # scripts exiting normally still get linted
        code = exc.code
        script_rc = code if isinstance(code, int) else (0 if code is None else 1)
    finally:
        TaskGraph.__init__ = orig_init  # type: ignore[method-assign]
        sys.argv = saved_argv
    if script_rc:
        print(
            f"repro.analysis.lint: script exited with status {script_rc}; "
            "linting the graphs it built anyway",
            file=sys.stderr,
        )

    all_findings: list[Finding] = []
    seen_names: set[str] = set()
    linted = 0
    for g in registry:
        if not _lintable(g):
            continue
        # steady-state loops rebuild identical subflow graphs per pass;
        # lint each distinct (name, size) shape once
        key = f"{g.name}:{len(g.tasks)}"
        if key in seen_names:
            continue
        seen_names.add(key)
        linted += 1
        all_findings.extend(
            lint_graph(g, backend=opts.backend, races=not opts.no_races)
        )
    errors = [f for f in all_findings if f.severity == ERROR]
    if all_findings:
        print(format_findings(all_findings), file=sys.stderr)
    print(
        f"repro.analysis.lint: {linted} graph(s) linted, "
        f"{len(errors)} error(s), {len(all_findings) - len(errors)} warning(s)",
        file=sys.stderr,
    )
    if errors or (opts.strict and all_findings):
        return 1
    return script_rc


if __name__ == "__main__":  # pragma: no cover - exercised via CLI in CI
    raise SystemExit(main())
