"""Three-term roofline model for TPU v5e (assignment constants).

  compute term    = HLO_FLOPs_per_device / peak_FLOPs
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = ICI_bytes_per_device / link_bw

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
module (per-device numbers); collective bytes from analysis/hlo.py.

MODEL_FLOPS is the analytic useful work: 6·N·D for a train step (2·N·D for
forward-only inference), N = active non-embedding params, D = tokens — plus
the causal-attention term which 6·N·D ignores but 32k-sequence cells are
dominated by. The ratio MODEL_FLOPS / HLO_FLOPs exposes remat/dispatch/
padding overheads in the compiled program.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig, param_count

# TPU v5e, per chip (assignment constants)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def dominant_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bound_s(self) -> float:
        """Perfect-overlap execution-time lower bound (max of the terms)."""
        return self.dominant_s

    def to_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "dominant_s": self.dominant_s,
        }


def terms_from_analysis(
    flops_per_device: float,
    bytes_per_device: float,
    collective_bytes_per_device: float,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / PEAK_FLOPS_BF16,
        memory_s=bytes_per_device / HBM_BW,
        collective_s=collective_bytes_per_device / ICI_BW,
    )


def model_flops(cfg: ModelConfig, seq_len: int, global_batch: int, kind: str) -> dict:
    """Analytic useful FLOPs for one step of a shape cell (whole job)."""
    counts = param_count(cfg)
    n_active = counts["active"] - cfg.vocab_size * cfg.d_model * (
        1 if cfg.tie_embeddings else 2
    )
    n_active = max(n_active, 1)
    # lm head is real compute even when embeddings are "excluded"
    head = 2 * cfg.d_model * cfg.vocab_size

    if kind == "train":
        tokens = seq_len * global_batch
        dense = (6 * n_active + 3 * head) * tokens
        attn = _attn_flops(cfg, seq_len, global_batch, backward=True)
    elif kind == "prefill":
        tokens = seq_len * global_batch
        dense = (2 * n_active + head) * tokens
        attn = _attn_flops(cfg, seq_len, global_batch, backward=False)
    else:  # decode: one token per sequence against a seq_len cache
        tokens = global_batch
        dense = (2 * n_active + head) * tokens
        attn = _decode_attn_flops(cfg, seq_len, global_batch)
    return {"dense": float(dense), "attention": float(attn), "total": float(dense + attn)}


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.attention == "none":
        return 0
    return cfg.num_layers + (cfg.encoder_layers if cfg.is_encdec else 0)


def _attn_flops(cfg: ModelConfig, S: int, B: int, *, backward: bool) -> float:
    L = _attn_layers(cfg)
    if L == 0:
        return 0.0
    H = cfg.num_heads
    Dh = cfg.head_dim or 0
    if cfg.attention == "mla":
        Dh = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim + cfg.v_head_dim
    # QK^T + AV: 4 * S^2 * Dh per head, halved by causality
    full = 4.0 * S * S * Dh * H * B
    causal = 0.5 if not cfg.is_encdec else 0.75  # enc is bidirectional
    window_frac = 1.0
    if cfg.window is not None and cfg.window < S:
        n_global = len(cfg.global_layers)
        frac_sw = cfg.window / S
        window_frac = (n_global + (cfg.num_layers - n_global) * frac_sw) / cfg.num_layers
    mult = 3.0 if backward else 1.0
    return full * causal * window_frac * L * mult


def _decode_attn_flops(cfg: ModelConfig, S_cache: int, B: int) -> float:
    L = _attn_layers(cfg)
    if L == 0:
        return 0.0
    if cfg.attention == "mla":
        # absorbed form: scores vs ckv (lora) + rope, values from ckv
        per_tok = 2.0 * cfg.num_heads * (
            2 * cfg.kv_lora_rank + cfg.qk_rope_head_dim
        ) * S_cache
    else:
        Dh = cfg.head_dim or 0
        per_tok = 4.0 * cfg.num_kv_heads * Dh * S_cache * (
            cfg.num_heads / max(cfg.num_kv_heads, 1)
        )
    window_frac = 1.0
    if cfg.window is not None and cfg.window < S_cache:
        n_global = len(cfg.global_layers)
        frac = cfg.window / S_cache
        window_frac = (n_global + (cfg.num_layers - n_global) * frac) / cfg.num_layers
    return per_tok * L * B * window_frac
