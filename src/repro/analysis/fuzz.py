"""Seeded random-interleaving schedule fuzzer (DESIGN.md §15).

A correct task graph produces the same results under *every* legal
execution order — that is what the dependency edges claim. This module
puts the claim on trial: it executes a graph serially many times, each
pass picking the next ready task from the frontier by a **stable keyed
draw** (the :mod:`repro.core.chaos` pattern —
``blake2b(f"{seed}:{schedule}:{step}")``, never Python's per-process
``hash()`` or a shared ``random.Random`` stream), and asserts that every
schedule yields identical per-task results. A divergence means some pair
of bodies communicates outside the edges — exactly the class of bug the
static race detector (:mod:`~repro.analysis.races`) hunts, witnessed
instead of inferred.

Full §10 semantics run in the loop (the same shared
:func:`~repro.core.graph.select_branch` / ``splice_subflow`` protocol as
``SerialExecutor``): condition branches, weak-edge loops, and
runtime-spawned subflows all fuzz. Schedule 0 is executed **twice**
first — a graph whose results differ between identical schedules is
rerun-nondeterministic (stateful bodies, unseeded randomness), and
cross-schedule comparison would only report noise; pass ``reset=`` to
restore external state between runs.

CLI: ``python -m repro.analysis.fuzz [--quick] [--seed N]`` fuzzes a
built-in corpus (diamond dataflow, condition loop, subflow fan-out,
wavefront) and exits non-zero on any divergence.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.graph import Runtime, TaskGraph, _FinTask, select_branch, splice_subflow
from repro.core.task import Task

from .lint import ERROR, Finding

__all__ = ["FuzzReport", "fuzz_schedules", "main"]


def _draw(seed: int, schedule: int, step: int) -> int:
    h = hashlib.blake2b(
        f"{seed}:{schedule}:{step}".encode(), digest_size=8
    ).digest()
    return int.from_bytes(h, "big")


def _fingerprint(value: Any) -> Any:
    """Stable, comparable digest of a task result (arrays by content hash)."""
    if isinstance(value, BaseException):
        return ("exception", type(value).__name__, str(value))
    try:
        import numpy as np
    except ImportError:  # pragma: no cover - numpy is a test/bench dep
        np = None
    if np is not None and hasattr(value, "shape") and hasattr(value, "dtype"):
        try:
            arr = np.asarray(value)
            digest = hashlib.blake2b(
                arr.tobytes(), digest_size=8
            ).hexdigest()
            return ("ndarray", tuple(arr.shape), str(arr.dtype), digest)
        except Exception:  # noqa: BLE001 - fall through to repr
            pass
    try:
        return repr(value)
    except Exception:  # noqa: BLE001 - unreprable results still compare by type
        return ("unreprable", type(value).__name__)


@dataclass
class FuzzReport:
    """Outcome of one :func:`fuzz_schedules` campaign."""

    graph: str
    schedules: int
    rerun_deterministic: bool
    baseline: dict[str, Any] = field(default_factory=dict)
    findings: list[Finding] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return self.rerun_deterministic and not self.findings

    def __str__(self) -> str:
        verdict = "ok" if self.ok else f"{len(self.findings)} finding(s)"
        rerun = "" if self.rerun_deterministic else " (rerun-nondeterministic)"
        return (
            f"fuzz[{self.graph}]: {self.schedules} schedule(s), {verdict}{rerun}"
        )


def _run_schedule(graph: TaskGraph, seed: int, schedule: int) -> dict[str, Any]:
    """Execute one keyed-draw schedule serially; return result fingerprints."""
    tasks = list(graph.tasks)
    has_cond = graph.has_conditions
    for t in tasks:
        t.reset()
    frontier = [t for t in tasks if t.is_source]
    step = 0
    limit = 1000 * (len(tasks) + 1)
    while frontier:
        step += 1
        if step > limit:
            raise RuntimeError(
                f"schedule fuzzer: {limit} steps without draining "
                f"{graph.name!r} — non-terminating loop? (run the "
                "weak-loop-no-exit lint rule)"
            )
        t = frontier.pop(_draw(seed, schedule, step) % len(frontier))
        rt = Runtime(t) if t.takes_runtime else None
        try:
            t.run(rt)
        except BaseException as exc:  # noqa: BLE001 - pool contract: record, continue
            t.exception = exc
            t._done = True
        if t.on_done is not None:
            try:
                t.on_done(t)
            except BaseException:  # noqa: BLE001 - callback errors dropped (§8)
                pass
        if has_cond:
            t.rearm()  # single-threaded: re-arm unconditionally, like SerialExecutor
        if rt is not None and rt.sub.tasks and t.exception is None:
            sub, join = splice_subflow(t, rt.sub)  # shared join protocol
            t._spawned = sub
            roots = [s for s in sub if s.is_source]
            frontier.extend(roots if roots else [join])
            continue
        if t.kind == "condition":
            branch = select_branch(t)  # shared §10 selection rule
            if branch is not None:
                frontier.append(branch)
            continue
        for s in t.successors:
            if isinstance(s, _FinTask):
                continue  # as_future bookkeeping of some previous live run
            if s.decrement():
                frontier.append(s)
    out: dict[str, Any] = {}
    for i, t in enumerate(tasks):
        key = t.name or f"t{i}"
        out[key] = _fingerprint(t.exception if t.exception is not None else t.result)
    return out


def fuzz_schedules(
    graph: TaskGraph,
    *,
    schedules: int = 8,
    seed: int = 0,
    reset: Optional[Callable[[], None]] = None,
    max_findings: int = 8,
) -> FuzzReport:
    """Assert result identity across ``schedules`` seeded interleavings.

    Runs schedule 0 twice to separate rerun-nondeterminism from schedule
    dependence (module docs), then compares every further schedule's
    per-task result fingerprints against the baseline. ``reset`` (when
    given) runs before every schedule to restore state *outside* the
    graph — bodies mutating external accumulators are otherwise reported
    as rerun-nondeterministic rather than racy. The graph is left reset
    but unharmed: build once, fuzz, then run for real.
    """
    gname = graph.name or "<anonymous>"
    if reset is not None:
        reset()
    baseline = _run_schedule(graph, seed, 0)
    if reset is not None:
        reset()
    again = _run_schedule(graph, seed, 0)
    if again != baseline:
        diff = sorted(k for k in baseline if baseline[k] != again.get(k))[:max_findings]
        return FuzzReport(
            gname,
            2,
            False,
            baseline,
            [
                Finding(
                    "rerun-nondeterministic",
                    ERROR,
                    "two runs of the *same* schedule diverged — bodies carry "
                    f"state across runs (tasks: {', '.join(diff)}); pass "
                    "reset= if that state is external and restorable",
                    tuple(diff),
                    gname,
                )
            ],
        )
    findings: list[Finding] = []
    for k in range(1, schedules):
        if reset is not None:
            reset()
        snap = _run_schedule(graph, seed, k)
        if snap == baseline:
            continue
        for key in sorted(baseline):
            if len(findings) >= max_findings:
                break
            if baseline[key] != snap.get(key):
                findings.append(
                    Finding(
                        "schedule-dependent-result",
                        ERROR,
                        f"task {key!r} produced {baseline[key]!r} under schedule 0 "
                        f"but {snap.get(key)!r} under schedule {k} (seed {seed}) — "
                        "its value depends on execution order, not on its edges",
                        (key,),
                        gname,
                    )
                )
        if len(findings) >= max_findings:
            break
    return FuzzReport(gname, schedules + 1, True, baseline, findings)


# -- CLI corpus ----------------------------------------------------------------


def _corpus() -> list[tuple[TaskGraph, Optional[Callable[[], None]]]]:
    """Built-in graphs covering every §10 shape (each with a reset fn)."""
    out: list[tuple[TaskGraph, Optional[Callable[[], None]]]] = []

    diamond = TaskGraph("fuzz-diamond")
    a = diamond.add(lambda: 3, name="a")
    b = diamond.then(a, lambda x: x * 2, name="b")
    c = diamond.then(a, lambda x: x + 10, name="c")
    diamond.gather([b, c], fn=lambda x, y: x * y, name="join")
    out.append((diamond, None))

    loop = TaskGraph("fuzz-loop")
    state = {"i": 0}

    def bump() -> int:
        state["i"] += 1
        return state["i"]

    entry = loop.add(None, name="entry")
    body = loop.add(bump, name="body")
    body.after(entry)  # a weak-pred target is not a source: loops need an entry
    cond = loop.add(lambda: 0 if state["i"] < 5 else 9, kind="condition", name="more?")
    cond.after(body)
    cond.precede(body)  # branch 0: loop; 9 is out of range -> exit idiom
    out.append((loop, lambda: state.update(i=0)))

    sub = TaskGraph("fuzz-subflow")

    def spawn(rt: Runtime) -> Any:
        parts = [rt.add(lambda j=j: j * j, name=f"part{j}") for j in range(4)]
        return rt.gather(parts, fn=lambda *vs: sum(vs), name="sum")

    sp = sub.add(spawn, takes_runtime=True, name="spawn")
    sub.then(sp, lambda total: total + 1, name="after")
    out.append((sub, None))

    wave = TaskGraph("fuzz-wavefront")
    n = 4
    cells: dict[tuple[int, int], Task] = {}
    for i in range(n):
        for j in range(i + 1):
            r, c_ = i - j, j
            cells[(r, c_)] = wave.add(lambda r=r, c=c_: r * n + c, name=f"cell{r},{c_}")
            if r > 0:
                cells[(r, c_)].after(cells[(r - 1, c_)])
            if c_ > 0:
                cells[(r, c_)].after(cells[(r, c_ - 1)])
    out.append((wave, None))
    return out


def main(argv: Optional[list[str]] = None) -> int:
    import argparse
    import sys

    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.fuzz",
        description="Fuzz the built-in graph corpus across seeded schedules.",
    )
    parser.add_argument("--quick", action="store_true", help="4 schedules per graph")
    parser.add_argument("--schedules", type=int, default=16)
    parser.add_argument("--seed", type=int, default=0)
    opts = parser.parse_args(argv)
    schedules = 4 if opts.quick else opts.schedules

    failed = False
    for graph, reset in _corpus():
        report = fuzz_schedules(graph, schedules=schedules, seed=opts.seed, reset=reset)
        print(report, file=sys.stderr)
        if not report.ok:
            failed = True
            for f in report.findings:
                print(f"  {f}", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":  # pragma: no cover - exercised via CLI in CI
    raise SystemExit(main())
