"""repro.analysis — static & dynamic analysis over the runtime (DESIGN.md §15).

Two halves live here:

* **model analysis** (:mod:`~repro.analysis.hlo`,
  :mod:`~repro.analysis.roofline`) — compiled-HLO inspection and roofline
  estimates for the jax side of the house;
* **graph verification** (:mod:`~repro.analysis.lint`,
  :mod:`~repro.analysis.races`, :mod:`~repro.analysis.fuzz`,
  :mod:`~repro.analysis.verify`) — the §15 pre-execution verifier for
  task graphs: a rule-based linter over :meth:`TaskGraph.edges`
  introspection, a bytecode-level closure/global/attribute write-race
  detector cross-checked at runtime by :class:`RaceObserver` vector
  clocks, and a seeded schedule fuzzer asserting result identity across
  interleavings. ``Executor(verify="warn"|"strict")`` runs the whole
  stack pre-submission; ``python -m repro.analysis.lint script.py`` lints
  every graph a script builds.

The verifier modules depend only on :mod:`repro.core` and the stdlib
(``dis``, ``hashlib``), so ``import repro.analysis`` never drags in jax
or the process backend. Submodule attributes resolve lazily (PEP 562) —
that keeps the package import instant *and* lets
``python -m repro.analysis.lint`` run the CLI module without a stale
copy already sitting in ``sys.modules``.
"""
from typing import Any

_EXPORTS = {
    "Finding": "lint",
    "LintContext": "lint",
    "lint_graph": "lint",
    "rule_catalog": "lint",
    "detect_races": "races",
    "task_writes": "races",
    "RaceObserver": "races",
    "fuzz_schedules": "fuzz",
    "FuzzReport": "fuzz",
    "verify_graph": "verify",
    "Report": "verify",
    "GraphVerificationError": "verify",
}

__all__ = [
    "Finding",
    "LintContext",
    "lint_graph",
    "rule_catalog",
    "detect_races",
    "task_writes",
    "RaceObserver",
    "fuzz_schedules",
    "FuzzReport",
    "verify_graph",
    "Report",
    "GraphVerificationError",
]


def __getattr__(name: str) -> Any:
    mod = _EXPORTS.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(__all__))
