"""Verification facade: one call running the whole §15 static stack.

:func:`verify_graph` wraps the linter (:mod:`~repro.analysis.lint`) and
the race detector (:mod:`~repro.analysis.races`) into a single
:class:`Report`; ``Executor(verify="warn"|"strict")`` calls it once per
graph structure before submission (re-verifying only when the graph's
§12 epoch fingerprint changes), and ``verify="strict"`` turns
error-severity findings into :class:`GraphVerificationError` *before*
any task runs. The dynamic checkers —
:class:`~repro.analysis.races.RaceObserver` and
:func:`~repro.analysis.fuzz.fuzz_schedules` — stay
explicit opt-ins: they execute the graph, which a pre-submission hook
must never do.
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.core.graph import TaskGraph

from .lint import ERROR, Finding, format_findings, lint_graph

__all__ = ["Report", "GraphVerificationError", "verify_graph"]


class Report:
    """Findings of one :func:`verify_graph` pass over one graph."""

    def __init__(self, graph_name: str, findings: Iterable[Finding]) -> None:
        self.graph_name = graph_name
        self.findings = list(findings)

    @property
    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == ERROR]

    @property
    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity != ERROR]

    @property
    def ok(self) -> bool:
        return not self.findings

    def raise_if_errors(self) -> None:
        if self.errors:
            raise GraphVerificationError(self)

    def __str__(self) -> str:
        if self.ok:
            return f"graph {self.graph_name!r}: verified clean"
        head = (
            f"graph {self.graph_name!r}: {len(self.errors)} error(s), "
            f"{len(self.warnings)} warning(s)"
        )
        return head + "\n" + format_findings(self.findings)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"Report({self.graph_name!r}, findings={len(self.findings)})"


class GraphVerificationError(RuntimeError):
    """Raised by ``Executor(verify="strict")`` for error-severity findings."""

    def __init__(self, report: Report) -> None:
        super().__init__(str(report))
        self.report = report


def verify_graph(
    graph: TaskGraph,
    *,
    backend: Optional[str] = None,
    races: bool = True,
    rules: Optional[Iterable[str]] = None,
) -> Report:
    """Run the full static stack over ``graph`` and return a :class:`Report`.

    ``backend`` sharpens the placement rules (it is what
    ``Executor(verify=...)`` passes); ``races``/``rules`` forward to
    :func:`~repro.analysis.lint.lint_graph`.
    """
    findings = lint_graph(graph, backend=backend, races=races, rules=rules)
    return Report(graph.name or "<anonymous>", findings)
