"""HLO text analysis: per-device collective traffic from a compiled module.

``compiled.cost_analysis()`` has no collective information, so we parse the
(SPMD-partitioned, hence per-device) HLO text and apply a ring-algorithm
traffic model per op:

  all-reduce          2 * size * (n-1)/n     (reduce-scatter + all-gather)
  all-gather          size * (n-1)/n         (size = gathered result)
  reduce-scatter      size_result * (n-1)    (operand = result * n)
  all-to-all          size * (n-1)/n
  collective-permute  size                   (point-to-point)

``n`` is the collective group size parsed from replica_groups. Sizes are the
per-partition HLO shapes, so the returned numbers are bytes over ICI links
per device per step.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Any

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %x = f32[16,128]{1,0} all-reduce(...)   or tuple results
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
# replica_groups={{0,1,2,3},{...}}  or  replica_groups=[8,2]<=[16]
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9]+),([0-9]+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    return 2  # conservative default when groups are implicit


def collective_traffic(hlo_text: str) -> dict[str, Any]:
    """Per-device ICI traffic (bytes) by collective kind + op counts."""
    bytes_by_kind: dict[str, float] = defaultdict(float)
    count_by_kind: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # async pairs (-start/-done) describe one transfer; count -start only
        if "-done(" in line:
            continue
        size = _shape_bytes(m.group("shape"))
        n = max(_group_size(line), 1)
        if op == "all-reduce":
            moved = 2.0 * size * (n - 1) / n
        elif op == "all-gather":
            moved = size * (n - 1) / n
        elif op == "reduce-scatter":
            moved = size * (n - 1)
        elif op == "all-to-all":
            moved = size * (n - 1) / n
        else:  # collective-permute
            moved = float(size)
        bytes_by_kind[op] += moved
        count_by_kind[op] += 1
    return {
        "bytes_by_kind": dict(bytes_by_kind),
        "count_by_kind": dict(count_by_kind),
        "total_bytes": float(sum(bytes_by_kind.values())),
    }


def op_histogram(hlo_text: str, ops=("fusion", "dot", "convolution", "custom-call")) -> dict:
    hist: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        for op in ops:
            if f" {op}(" in line:
                hist[op] += 1
    return dict(hist)
