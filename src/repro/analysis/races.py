"""Closure-capture race detector + dynamic happens-before checker.

DESIGN.md §15. The §8 dataflow story is "pass values along edges, don't
share state" — but Python makes sharing effortless: two task bodies that
close over the same variable, write the same global, or poke the same
object's attribute race silently on the thread backend and diverge
silently on the process backend (workers mutate a copy).

**Static half** (:func:`task_writes` / :func:`detect_races`): a ``dis``
scan of each task body (bound methods and partials unwrapped, nested
``def``/lambda/comprehension code objects followed) collecting

* ``STORE_DEREF`` on a *free* variable — a write through a shared
  closure cell (keyed by cell identity, so two bodies capturing the same
  variable collide and two bodies capturing different cells don't);
* ``STORE_GLOBAL`` — keyed by ``(module, name)``;
* ``STORE_ATTR`` where the receiver is statically evident — ``self`` of
  a bound method, a captured cell, or a module global — keyed by
  ``(id(receiver), attribute)``.

Two distinct tasks writing the same key with **no happens-before path**
through the edge graph (reachability over strong *and* weak edges; tasks
in one loop SCC are serialized per pass and count as ordered) is a
``shared-state-race`` finding. Opaque receivers (locals, subscripts,
call results) are skipped — the report favors precision over recall.

**Dynamic half** (:class:`RaceObserver`): an observer assigning each
task a vector clock joined from its predecessors' finish clocks at
``on_start`` and incremented at ``on_finish``. After a real run,
:meth:`RaceObserver.check` cross-checks the static report: a statically
flagged pair whose clocks are incomparable was *actually* unordered this
run (and ``overlapped`` tells you whether wall-clock intervals on
distinct workers truly interleaved). The clocks derive from graph edges
only, so the observer is the runtime witness for exactly the ordering
the linter reasoned about.
"""
from __future__ import annotations

import threading
import time
import types
from typing import Any, Iterable, Optional

from repro.core.graph import TaskGraph
from repro.core.observer import PoolObserver
from repro.core.task import Task

from .lint import ERROR, Finding, LintContext, unwrap_callable

__all__ = ["task_writes", "detect_races", "RaceObserver"]

_STORE_OPS = {"STORE_DEREF", "STORE_GLOBAL", "STORE_ATTR"}


def _receiver_load(instrs: list, j: int, attr: str) -> Optional[Any]:
    """The load instruction that pushed ``STORE_ATTR``'s receiver, or None.

    Plain assignment (``x.a = v``) puts the receiver load directly before
    the store. Augmented assignment (``x.a += v``) compiles to
    ``LOAD x; DUP_TOP; LOAD_ATTR a; ...; ROT_TWO; STORE_ATTR a`` — walk
    back to the duplicated load. Opaque receivers (subscripts, call
    results) return None.
    """
    if j == 0:
        return None
    prev = instrs[j - 1]
    if prev.opname in ("LOAD_FAST", "LOAD_DEREF", "LOAD_GLOBAL", "LOAD_NAME"):
        return prev
    if prev.opname in ("ROT_TWO", "SWAP"):  # SWAP replaces ROT_TWO in 3.11+
        for k in range(j - 2, 0, -1):
            ins = instrs[k]
            if ins.opname == "LOAD_ATTR" and ins.argval == attr:
                if instrs[k - 1].opname in ("DUP_TOP", "COPY"):
                    return instrs[k - 2] if k >= 2 else None
        return None
    return None


def _scan_code(
    code: types.CodeType,
    cells: dict[str, Any],
    self_names: frozenset[str],
    self_obj: Any,
    func: types.FunctionType,
    out: dict[tuple, str],
) -> None:
    """One code object's write scan; recurses into nested code consts.

    ``cells`` maps free-variable names visible in this scope to the
    actual cell objects of the *task body's* closure; names bound to
    cells created inside the body (its own cellvars) are local state and
    deliberately absent.
    """
    import dis

    instrs = list(dis.get_instructions(code))
    for j, ins in enumerate(instrs):
        op = ins.opname
        if op == "STORE_DEREF":
            cell = cells.get(ins.argval)
            if cell is not None:
                out[("cell", id(cell))] = f"captured variable '{ins.argval}'"
        elif op == "STORE_GLOBAL":
            out[("global", func.__module__, ins.argval)] = (
                f"global '{ins.argval}' of module '{func.__module__}'"
            )
        elif op == "STORE_ATTR":
            attr = ins.argval
            prev = _receiver_load(instrs, j, attr)
            if prev is None:
                continue
            pop = prev.opname
            if pop == "LOAD_FAST" and prev.argval in self_names:
                out[("attr", id(self_obj), attr)] = (
                    f"attribute '{type(self_obj).__name__}.{attr}'"
                )
            elif pop == "LOAD_DEREF" and prev.argval in cells:
                cell = cells[prev.argval]
                try:
                    obj = cell.cell_contents
                except ValueError:  # empty cell: key on the cell itself
                    obj = cell
                out[("attr", id(obj), attr)] = (
                    f"attribute '{prev.argval}.{attr}'"
                )
            elif pop in ("LOAD_GLOBAL", "LOAD_NAME"):
                obj = func.__globals__.get(prev.argval, _scan_code)
                if obj is not _scan_code:
                    out[("attr", id(obj), attr)] = (
                        f"attribute '{prev.argval}.{attr}'"
                    )
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            # names free in the nested scope that resolve to the body's own
            # closure stay shared; names closing over the body's locals are
            # new (unshared) cells and drop out of the map here
            nested_cells = {n: cells[n] for n in const.co_freevars if n in cells}
            _scan_code(const, nested_cells, frozenset(), None, func, out)


def task_writes(task: Task) -> dict[tuple, str]:
    """Statically-evident shared-state writes of one task body.

    Returns ``{key: human description}`` where ``key`` identifies the
    written location (cell identity / global name / receiver id +
    attribute — module docs). Bodies that cannot be disassembled (C
    callables, ``fn=None``) report no writes.
    """
    if task.fn is None:
        return {}
    func, self_obj = unwrap_callable(task.fn)
    if func is None:
        return {}
    code = func.__code__
    cells = dict(zip(code.co_freevars, func.__closure__ or ()))
    self_names = frozenset()
    if self_obj is not None and code.co_argcount >= 1:
        self_names = frozenset((code.co_varnames[0],))
    out: dict[tuple, str] = {}
    _scan_code(code, cells, self_names, self_obj, func, out)
    return out


def _reachable_ids(start: Task, adj: dict[int, list[Task]]) -> set[int]:
    seen: set[int] = set()
    stack = [start]
    while stack:
        t = stack.pop()
        for s in adj.get(id(t), ()):
            if id(s) not in seen:
                seen.add(id(s))
                stack.append(s)
    return seen


def detect_races(
    graph: TaskGraph, *, ctx: Optional[LintContext] = None
) -> list[Finding]:
    """``shared-state-race`` findings for ``graph`` (module docs).

    A finding names both tasks and the written location. Pairs ordered by
    a happens-before path (either direction, over strong *and* weak
    edges) are not races — including loop bodies serialized by their SCC.
    """
    ctx = ctx or LintContext(graph)
    writers: dict[tuple, list[tuple[Task, str]]] = {}
    for t in ctx.tasks:
        for key, descr in task_writes(t).items():
            writers.setdefault(key, []).append((t, descr))
    gname = graph.name or "<anonymous>"
    findings: list[Finding] = []
    reach_cache: dict[int, set[int]] = {}

    def reach(t: Task) -> set[int]:
        r = reach_cache.get(id(t))
        if r is None:
            r = reach_cache[id(t)] = _reachable_ids(t, ctx.succ_all)
        return r

    seen_pairs: set[tuple[int, int, tuple]] = set()
    for key, who in writers.items():
        if len(who) < 2:
            continue
        for i in range(len(who)):
            a, descr = who[i]
            for b, _descr_b in who[i + 1 :]:
                if a is b:
                    continue
                if id(b) in reach(a) or id(a) in reach(b):
                    continue  # ordered by the edge graph
                pair = (min(id(a), id(b)), max(id(a), id(b)), key)
                if pair in seen_pairs:
                    continue
                seen_pairs.add(pair)
                findings.append(
                    Finding(
                        "shared-state-race",
                        ERROR,
                        f"tasks {ctx.name(a)!r} and {ctx.name(b)!r} both write "
                        f"{descr} with no happens-before path between them",
                        (ctx.name(a), ctx.name(b)),
                        gname,
                    )
                )
    return findings


class RaceObserver(PoolObserver):
    """Vector-clock happens-before witness for one graph's runs.

    Attach alongside a run (``Executor(observers=[obs])`` or
    ``pool.add_observer``) and query afterwards::

        obs = RaceObserver(graph)
        with Executor(2, observers=[obs]) as ex:
            ex.run(graph).result(10)
        assert not obs.concurrent(a, b)          # graph-ordered
        confirmed = obs.check(detect_races(graph))

    Clocks derive **only from graph edges**: a task's start clock is the
    component-wise max of its in-container predecessors' finish clocks,
    and its finish clock increments its own component. Two tasks are
    :meth:`concurrent` when neither clock dominates — the same relation
    the static detector reasons about, observed on a real schedule.
    Tasks re-run by a loop keep their latest clocks (per-pass ordering is
    what the §10 loop semantics guarantee). Wall-clock intervals per
    worker are recorded too: :meth:`overlapped` reports whether two
    bodies *really* interleaved on distinct workers this run.
    """

    def __init__(self, graph: TaskGraph) -> None:
        self._index = {id(t): i for i, t in enumerate(graph.tasks)}
        self._n = len(graph.tasks)
        self._names = {id(t): (t.name or f"t{i}") for i, t in enumerate(graph.tasks)}
        preds: dict[int, list[int]] = {id(t): [] for t in graph.tasks}
        for u, v, _strong in graph.edges():
            if id(v) in preds and id(u) in self._index:
                preds[id(v)].append(id(u))
        self._preds = preds
        self._lock = threading.Lock()
        self._start: dict[int, list[int]] = {}
        self._finish: dict[int, list[int]] = {}
        self._spans: dict[int, tuple[float, float, int]] = {}
        self._t0: dict[int, float] = {}
        self._workers: dict[int, int] = {}

    # -- observer protocol -----------------------------------------------------

    def on_start(self, task: Task, worker: int) -> None:
        tid = id(task)
        if tid not in self._index:
            return  # subflow / foreign task: outside this graph's clock space
        now = time.perf_counter()
        with self._lock:
            clk = [0] * self._n
            for pid in self._preds[tid]:
                fin = self._finish.get(pid)
                if fin is not None:
                    for k in range(self._n):
                        if fin[k] > clk[k]:
                            clk[k] = fin[k]
            self._start[tid] = clk
            self._t0[tid] = now
            self._workers[tid] = worker

    def on_finish(self, task: Task, worker: int) -> None:
        tid = id(task)
        if tid not in self._index:
            return
        now = time.perf_counter()
        with self._lock:
            clk = list(self._start.get(tid) or [0] * self._n)
            clk[self._index[tid]] += 1
            self._finish[tid] = clk
            t0 = self._t0.get(tid, now)
            self._spans[tid] = (t0, now, worker)

    # -- queries ---------------------------------------------------------------

    def happens_before(self, a: Task, b: Task) -> bool:
        """``a``'s observed finish clock ≤ ``b``'s observed start clock."""
        with self._lock:
            fa = self._finish.get(id(a))
            sb = self._start.get(id(b))
        if fa is None or sb is None:
            return False
        return all(x <= y for x, y in zip(fa, sb))

    def concurrent(self, a: Task, b: Task) -> bool:
        """Neither task's clock dominates: unordered by graph edges."""
        return not self.happens_before(a, b) and not self.happens_before(b, a)

    def overlapped(self, a: Task, b: Task) -> bool:
        """Wall-clock intervals intersected on distinct workers this run."""
        with self._lock:
            sa = self._spans.get(id(a))
            sb = self._spans.get(id(b))
        if sa is None or sb is None:
            return False
        (a0, a1, wa), (b0, b1, wb) = sa, sb
        return wa != wb and a0 < b1 and b0 < a1

    def check(self, findings: Iterable[Finding]) -> list[dict[str, Any]]:
        """Cross-check static ``shared-state-race`` findings on this run.

        For each finding, reports ``status`` ``"confirmed-concurrent"``
        (clocks incomparable — the static verdict held at runtime),
        ``"ordered-this-run"`` (this schedule happened to serialize them:
        still a race, just not witnessed), or ``"not-observed"`` (a named
        task never ran). ``overlapped`` marks true wall-clock interleaving.
        """
        by_name = {name: tid for tid, name in self._names.items()}
        out: list[dict[str, Any]] = []
        for f in findings:
            if f.rule != "shared-state-race" or len(f.tasks) < 2:
                continue
            ta, tb = by_name.get(f.tasks[0]), by_name.get(f.tasks[1])
            entry: dict[str, Any] = {"finding": f, "overlapped": False}
            if ta is None or tb is None:
                entry["status"] = "not-observed"
            else:
                with self._lock:
                    fa, sb = self._finish.get(ta), self._start.get(tb)
                    fb, sa = self._finish.get(tb), self._start.get(ta)
                    span_a, span_b = self._spans.get(ta), self._spans.get(tb)
                if fa is None or fb is None or sa is None or sb is None:
                    entry["status"] = "not-observed"
                else:
                    ab = all(x <= y for x, y in zip(fa, sb))
                    ba = all(x <= y for x, y in zip(fb, sa))
                    entry["status"] = (
                        "ordered-this-run" if (ab or ba) else "confirmed-concurrent"
                    )
                    if span_a is not None and span_b is not None:
                        (a0, a1, wa), (b0, b1, wb) = span_a, span_b
                        entry["overlapped"] = wa != wb and a0 < b1 and b0 < a1
            out.append(entry)
        return out
