"""pjit step builders: train_step / prefill / decode_step with full sharding.

This is the layer the dry-run lowers: it owns the in/out shardings for
params, optimizer state (ZeRO), batches and KV caches, and the donation
policy (params+opt donated in train; caches donated in decode).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import batch_axes_of
from repro.models import Model
from repro.optim import AdamWConfig, adamw_update
from repro.optim.adamw import adamw_abstract_state

from .ctx import ParallelCtx
from .sharding import param_specs, rules_for, zero_specs


def make_ctx(mesh: Mesh, *, seq_shard: bool = True, expert_parallel: bool = True) -> ParallelCtx:
    return ParallelCtx(
        mesh,
        batch_axes=batch_axes_of(mesh),
        seq_shard=seq_shard,
        expert_parallel=expert_parallel,
    )


def model_param_specs(model: Model, mesh: Mesh):
    rules = rules_for(model.cfg)
    return param_specs(model.abstract_params(), model.logical_axes(), rules, mesh)


def opt_state_specs(model: Model, ocfg: AdamWConfig, mesh: Mesh, pspecs, batch_axes):
    abstract_p = model.abstract_params()
    z = zero_specs(pspecs, abstract_p, mesh, batch_axes)
    specs = {"m": z, "v": z, "count": P()}
    if ocfg.keep_master:
        specs["master"] = z
    return specs


def _batch_part(B: int, mesh: Mesh, batch_axes):
    """Batch dim mesh axes, or None when B is too small to shard (B=1 cells
    keep the data axes idle — reported honestly in the roofline)."""
    n = 1
    for ax in batch_axes:
        n *= mesh.shape[ax]
    return batch_axes if (B % n == 0 and B >= n) else None


def batch_specs(model: Model, batch_abstract: dict, batch_axes, mesh: Mesh) -> dict:
    out = {}
    for k, v in batch_abstract.items():
        if k in ("caches",):
            continue
        if v.ndim == 0:
            out[k] = P()
            continue
        out[k] = P(_batch_part(v.shape[0], mesh, batch_axes), *([None] * (v.ndim - 1)))
    return out


# -- cache sharding -----------------------------------------------------------


def cache_specs(abstract_caches: Any, mesh: Mesh, batch_axes) -> Any:
    """PartitionSpecs for a (possibly scan-stacked) cache pytree.

    Strategy (see DESIGN.md §5): batch over data axes; KV heads over model
    (GSPMD-padded when the count is awkward); MQA caches shard head_dim;
    MLA compressed caches replicate over model (they are small — that is the
    point of MLA) while attention math shards over heads; SSM state shards
    its heads dim; conv streams shard channels.
    """
    n_model = mesh.shape["model"]

    def bpart(B):
        return _batch_part(B, mesh, batch_axes)

    def spec(path, leaf) -> P:
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1]
        nd = leaf.ndim
        if name == "pos":
            return P(*([None] * nd))
        if name in ("k", "v"):  # (..., B, S, KV, Dh)
            KV, Dh = leaf.shape[-2], leaf.shape[-1]
            lead = [None] * (nd - 4)
            if KV % n_model == 0 and KV >= n_model:
                kv_ax, dh_ax = "model", None
            elif Dh % n_model == 0 and Dh >= n_model:
                # awkward/few KV heads: shard head_dim (scores psum per layer)
                kv_ax, dh_ax = None, "model"
            else:
                kv_ax, dh_ax = None, None
            return P(*lead, bpart(leaf.shape[-4]), None, kv_ax, dh_ax)
        if name in ("ckv", "krope"):  # (..., B, S, D) compressed MLA cache:
            # shard the SEQUENCE over model (the lora dim is tiny; per-token
            # softmax stats psum is cheap) — EXPERIMENTS §Perf hillclimb C.
            S_len = leaf.shape[-2]
            lead = [None] * (nd - 3)
            seq_ax = "model" if (S_len % n_model == 0 and S_len >= n_model) else None
            return P(*lead, bpart(leaf.shape[-3]), seq_ax, None)
        if name == "conv":  # (..., B, K, C)
            C = leaf.shape[-1]
            lead = [None] * (nd - 3)
            return P(*lead, bpart(leaf.shape[-3]), None, "model" if C % n_model == 0 else None)
        if name == "state":  # (..., B, H, Pd, N)
            H, N = leaf.shape[-3], leaf.shape[-1]
            lead = [None] * (nd - 4)
            if H % n_model == 0 and H >= n_model:
                return P(*lead, bpart(leaf.shape[-4]), "model", None, None)
            if N % n_model == 0 and N >= n_model:
                return P(*lead, bpart(leaf.shape[-4]), None, None, "model")
            return P(*lead, bpart(leaf.shape[-4]), None, None, None)
        lead = [None] * (nd - 1)
        return P(bpart(leaf.shape[0]), *lead)

    return jax.tree_util.tree_map_with_path(spec, abstract_caches)


# -- step builders ---------------------------------------------------------------


def build_train_step(
    model: Model,
    mesh: Mesh,
    ocfg: AdamWConfig,
    lr_fn: Callable[[jax.Array], jax.Array],
    batch_abstract: dict,
    *,
    donate: bool = True,
):
    """Returns (jitted step, state_shardings dict, abstract state)."""
    ctx = make_ctx(mesh)
    batch_axes = ctx.batch_axes
    pspecs = model_param_specs(model, mesh)
    ospecs = opt_state_specs(model, ocfg, mesh, pspecs, batch_axes)
    bspecs = batch_specs(model, batch_abstract, batch_axes, mesh)
    s = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree, is_leaf=lambda x: isinstance(x, P)
    )

    def step_fn(params, opt_state, batch, step):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, ctx), has_aux=True
        )(params)
        lr = lr_fn(step)
        new_params, new_opt, om = adamw_update(ocfg, lr, params, grads, opt_state)
        out_metrics = {"loss": loss, **metrics, **om}
        return new_params, new_opt, out_metrics

    metric_names = ("loss", "ce", "aux", "tokens", "grad_norm", "lr")
    jitted = jax.jit(
        step_fn,
        in_shardings=(s(pspecs), s(ospecs), s(bspecs), NamedSharding(mesh, P())),
        out_shardings=(
            s(pspecs),
            s(ospecs),
            {k: NamedSharding(mesh, P()) for k in metric_names},
        ),
        donate_argnums=(0, 1) if donate else (),
    )
    abstract = {
        "params": model.abstract_params(),
        "opt": adamw_abstract_state(ocfg, model.abstract_params()),
    }
    return jitted, {"params": pspecs, "opt": ospecs, "batch": bspecs}, abstract


def build_prefill(model: Model, mesh: Mesh, batch_abstract: dict):
    ctx = make_ctx(mesh)
    batch_axes = ctx.batch_axes
    pspecs = model_param_specs(model, mesh)
    bspecs = batch_specs(model, batch_abstract, batch_axes, mesh)
    B = batch_abstract["tokens"].shape[0]
    S = batch_abstract["tokens"].shape[1] + (
        model.cfg.num_image_tokens if model.cfg.family == "vlm" else 0
    )
    cshapes = model.cache_shapes(B, S)
    cspecs = cache_specs(cshapes, mesh, batch_axes)
    s = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree, is_leaf=lambda x: isinstance(x, P)
    )

    vocab_part = "model" if model.cfg.vocab_size % mesh.shape["model"] == 0 else None

    def prefill_fn(params, batch):
        return model.prefill(params, batch, ctx)

    jitted = jax.jit(
        prefill_fn,
        in_shardings=(s(pspecs), s(bspecs)),
        out_shardings=(
            NamedSharding(mesh, P(_batch_part(B, mesh, batch_axes), None, vocab_part)),
            s(cspecs),
        ),
    )
    return jitted, {"params": pspecs, "batch": bspecs, "caches": cspecs}


def build_decode_step(model: Model, mesh: Mesh, batch_abstract: dict):
    """decode: one token for every sequence, donated KV cache."""
    ctx = make_ctx(mesh)
    batch_axes = ctx.batch_axes
    pspecs = model_param_specs(model, mesh)
    Bt = batch_abstract["tokens"].shape[0]
    cspecs = cache_specs(batch_abstract["caches"], mesh, batch_axes)
    s = lambda tree: jax.tree.map(
        lambda sp: NamedSharding(mesh, sp), tree, is_leaf=lambda x: isinstance(x, P)
    )

    vocab_part = "model" if model.cfg.vocab_size % mesh.shape["model"] == 0 else None

    def decode_fn(params, tokens, caches, index):
        return model.decode_step(params, tokens, caches, index, ctx)

    jitted = jax.jit(
        decode_fn,
        in_shardings=(
            s(pspecs),
            NamedSharding(mesh, P(_batch_part(Bt, mesh, batch_axes), None)),
            s(cspecs),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(
            NamedSharding(mesh, P(_batch_part(Bt, mesh, batch_axes), None, vocab_part)),
            s(cspecs),
        ),
        donate_argnums=(2,),
    )
    return jitted, {"params": pspecs, "caches": cspecs}
