"""Logical-axis sharding rules → PartitionSpecs / NamedShardings.

Every parameter carries a tuple of logical axis names (built by the same
code path that builds the arrays — models/common.Alloc). Rules map logical
axes to mesh axes; a rule is dropped (replicated) when the dimension is not
divisible by the mesh-axis size *and* padding is disabled. With
``allow_uneven=True`` (default) GSPMD pads the last shards — the padding
waste for awkward head counts (56, 24, 20, 25) is reported in the roofline.

ZeRO: optimizer-state specs additionally shard the largest replicated dim
over the data axis (``zero_spec``).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> mesh axis (None = replicate)
DEFAULT_RULES: dict[Optional[str], Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv": "model",
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,  # deepseek-v2 overrides to 'data' (2D expert sharding)
    "ssm_inner": "model",
    "ssm_heads": None,
    "lora": None,
    "embed": None,
    "layers": None,
    None: None,
}


def rules_for(cfg) -> dict:
    rules = dict(DEFAULT_RULES)
    for k, v in getattr(cfg, "sharding_rules", ()) or ():
        rules[k] = v
    return rules


# fallback priority when the preferred dim is not divisible by the mesh axis
# (jit rejects uneven shardings): shard a contracted/output dim instead —
# row-parallel style; GSPMD inserts the reduction. Order matters: prefer the
# large embedding/hidden dims.
_FALLBACK_ORDER = ("embed", "mlp", "vocab", "ssm_inner", "lora")


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...], rules: dict, mesh: Mesh) -> P:
    parts: list = []
    used: set = set()
    for ax_name, dim in zip(axes, shape):
        mesh_ax = rules.get(ax_name)
        if mesh_ax is None or mesh_ax in used:
            parts.append(None)
            continue
        size = mesh.shape[mesh_ax]
        if dim % size != 0 or dim < size:  # jit requires exact divisibility
            parts.append(None)
            continue
        parts.append(mesh_ax)
        used.add(mesh_ax)
    # Fallback: a >=2D param that ended up unsharded on `model` (awkward head
    # counts 56/25/24/20/8, odd vocabs 51865/50280/49155) gets `model` on the
    # best divisible alternative dim instead of being replicated.
    if "model" not in used and len(shape) >= 2:
        n_model = mesh.shape.get("model", 1)

        def priority(i: int) -> tuple:
            name = axes[i]
            try:
                rank = _FALLBACK_ORDER.index(name)
            except ValueError:
                rank = len(_FALLBACK_ORDER)
            return (rank, -shape[i])

        for i in sorted(range(len(shape)), key=priority):
            if parts[i] is None and shape[i] % n_model == 0 and shape[i] >= n_model:
                if axes[i] == "layers":
                    continue  # never shard the scan dim
                parts[i] = "model"
                break
    return P(*parts)


def param_specs(abstract: Any, axes_tree: Any, rules: dict, mesh: Mesh) -> Any:
    """PartitionSpec tree matching the abstract-param tree.

    ``axes_tree`` has tuple leaves (which are pytree containers), so it is
    flattened only down to the abstract tree's leaf positions.
    """
    flat_abs, treedef = jax.tree.flatten(abstract)
    flat_axes = treedef.flatten_up_to(axes_tree)
    flat = [
        spec_for(tuple(ax), tuple(leaf.shape), rules, mesh)
        for leaf, ax in zip(flat_abs, flat_axes)
    ]
    return jax.tree.unflatten(treedef, flat)


def shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def zero_spec(
    spec: P, shape: Tuple[int, ...], mesh: Mesh, data_axes: Tuple[str, ...] = ("data",)
) -> P:
    """Add data-axis sharding to the largest still-replicated divisible dim
    (ZeRO partitioning of optimizer state / master weights)."""
    n_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    # never double-map a mesh axis (e.g. deepseek-v2 expert_mlp already on data)
    already = set()
    for cur in parts:
        if cur is None:
            continue
        for a in (cur if isinstance(cur, tuple) else (cur,)):
            already.add(a)
    if any(a in already for a in data_axes):
        return P(*parts)
    best, best_dim = -1, 0
    for i, (cur, dim) in enumerate(zip(parts, shape)):
        if cur is None and dim % n_data == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best >= 0:
        parts[best] = data_axes if len(data_axes) > 1 else data_axes[0]
    return P(*parts)


def zero_specs(
    spec_tree: Any, abstract: Any, mesh: Mesh, data_axes: Tuple[str, ...] = ("data",)
) -> Any:
    return jax.tree.map(
        lambda s, a: zero_spec(s, tuple(a.shape), mesh, data_axes),
        spec_tree,
        abstract,
        is_leaf=lambda s: isinstance(s, P),
    )


def estimate_padding_waste(abstract: Any, spec_tree: Any, mesh: Mesh) -> dict:
    """Bytes wasted by GSPMD padding on uneven shards (roofline honesty)."""
    total, padded = 0, 0

    def one(leaf, spec):
        nonlocal total, padded
        nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        pbytes = nbytes
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
            if ax is None:
                continue
            size = mesh.shape[ax] if isinstance(ax, str) else int(
                np.prod([mesh.shape[a] for a in ax])
            )
            pbytes = pbytes // dim * (-(-dim // size) * size)
        total += nbytes
        padded += pbytes

    jax.tree.map(one, abstract, spec_tree, is_leaf=lambda s: isinstance(s, P))
    return {
        "logical_bytes": total,
        "padded_bytes": padded,
        "waste_frac": (padded - total) / max(total, 1),
    }
