"""Pipeline parallelism driven by the paper's task-graph scheduler.

The schedule COMES FROM the paper's machinery (DESIGN.md §2): the
(microbatch × stage) forward grid is a dependency-counted task graph;
``repro.core.schedule.simulate`` executes it with the paper's policy and
emits the tick table ``stage s works on microbatch (t - s) at tick t``.
The executor embeds that static table in a ``shard_map`` + ``ppermute``
stepper over a mesh axis (``pod`` on the production mesh):

  * every rank holds one stage's parameters (in_spec P('pod') on the
    stacked stage dim);
  * a lax.scan over ticks applies the stage function when the table says
    so (masked when idle — the pipeline bubble is real compute idleness);
  * activations move stage→stage with ``ppermute`` at each tick boundary;
  * the loss is computed on the last stage and psum'd.

Backward runs through jax.grad: the transpose of ppermute is the reverse
permute, so the generated backward is the mirrored pipeline schedule. With
remat on the stage function the activation footprint per stage is the
1F1B-style bound validated against ``peak_activation_buffers`` in tests.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.core.schedule import simulate
from repro.parallel.ctx import shard_map

_HAS_PUBLIC_SHARD_MAP = hasattr(jax, "shard_map")


def forward_tick_table(num_stages: int, num_microbatches: int) -> np.ndarray:
    """mb_for[tick, stage] = microbatch index or -1, derived by simulating
    the paper's scheduler on the forward grid."""
    from repro.core.schedule import PipelineOp, SimTask

    S, M = num_stages, num_microbatches
    tasks = []
    fid = {}
    for m in range(M):
        for s in range(S):
            fid[(m, s)] = len(tasks)
            tasks.append(
                SimTask(
                    name=f"F{m}.{s}", worker=s, priority=-float(m),
                    payload=PipelineOp("F", m, s),
                )
            )
    for m in range(M):
        for s in range(1, S):
            tasks[fid[(m, s - 1)]].successors.append(fid[(m, s)])
            tasks[fid[(m, s)]].num_predecessors += 1
    res = simulate(tasks, num_stages, allow_steal=False)
    ticks = int(round(res.makespan))
    table = -np.ones((ticks, num_stages), np.int32)
    for w, tl in enumerate(res.timelines):
        for tid, s0, _s1 in tl:
            op = tasks[tid].payload
            table[int(round(s0)), w] = op.microbatch
    return table


def build_pipelined_loss(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    loss_fn: Callable[[jax.Array, jax.Array], jax.Array],
    mesh: Mesh,
    *,
    axis: str = "pod",
    num_microbatches: int,
    remat: bool = True,
):
    """Returns loss(params_stacked, x_mb, y_mb) -> scalar.

    params_stacked: pytree with leading stage dim (sharded P(axis));
    x_mb, y_mb: (M, mb, ...) microbatched inputs/targets, replicated.
    stage_fn(stage_params, x) -> x; loss_fn(x_final, y) -> scalar mean.
    """
    S = mesh.shape[axis]
    table = forward_tick_table(S, num_microbatches)  # static schedule
    ticks = table.shape[0]
    mb_of = jnp.asarray(table)  # (ticks, S)
    fwd = jax.checkpoint(stage_fn) if remat else stage_fn

    def body(params_local, x_mb, y_mb):
        # params_local: this stage's params (leading dim 1 squeezed)
        params_local = jax.tree.map(lambda l: l[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = x_mb.shape[1:]

        def tick(carry, t):
            buf, acc = carry  # buf: activation entering this stage
            mb = mb_of[t, stage]
            active = mb >= 0
            # stage 0 reads its microbatch from the input queue
            x_in = jnp.where(
                (stage == 0) & active,
                x_mb[jnp.clip(mb, 0, num_microbatches - 1)],
                buf,
            )
            out = fwd(params_local, x_in)
            out = jnp.where(active, out, buf)
            # last stage: loss for the finished microbatch
            contrib = jnp.where(
                (stage == S - 1) & active,
                loss_fn(out, y_mb[jnp.clip(mb, 0, num_microbatches - 1)]),
                0.0,
            )
            # hand activations downstream (ring; last->0 edge is ignored
            # because stage 0 always reads fresh input)
            nxt = jax.lax.ppermute(out, axis, [(i, (i + 1) % S) for i in range(S)])
            return (nxt, acc + contrib), None

        # acc is carried as (1,), not a scalar: the legacy (0.4.x) shard_map
        # transpose rule mis-specs scalar scan-carry residuals
        buf0 = jnp.zeros(mb_shape, x_mb.dtype)
        acc0 = jnp.zeros((1,), jnp.float32)
        # the carry becomes device-varying after the first ppermute; mark the
        # initial values as varying so the scan carry types are stable
        if hasattr(jax.lax, "pcast"):
            buf0 = jax.lax.pcast(buf0, (axis,), to="varying")
            acc0 = jax.lax.pcast(acc0, (axis,), to="varying")
        (buf, acc), _ = jax.lax.scan(tick, (buf0, acc0), jnp.arange(ticks))
        # mean over microbatches, summed across stages (only last contributes)
        total = jax.lax.psum(acc, axis) / num_microbatches  # (1,)
        # legacy jax: return a per-stage copy (mapped out spec) because the
        # 0.4.x replication checker cannot track the ppermute-varying carry
        return total[0] if _HAS_PUBLIC_SHARD_MAP else total

    # loss must come back identical on every rank: psum above handles it.

    def loss(params_stacked, x_mb, y_mb):
        out = shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(), P()),
            out_specs=P() if _HAS_PUBLIC_SHARD_MAP else P(axis),
            check_rep=_HAS_PUBLIC_SHARD_MAP,
        )(params_stacked, x_mb, y_mb)
        # legacy: (S,) identical psum'ed copies — mean is value- and
        # gradient-identical to the replicated scalar
        return out if _HAS_PUBLIC_SHARD_MAP else out.mean()

    return loss, table
