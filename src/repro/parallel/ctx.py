"""ParallelCtx: the runtime handle models use to stay mesh-aware.

Carries the mesh + axis-name conventions and provides activation sharding
constraints (sequence-parallel residual stream). ``ctx=None`` everywhere
means single-device execution (CPU smoke tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = True):
    """Version-portable ``shard_map``.

    Public ``jax.shard_map`` where available; the experimental module on the
    pinned 0.4.x line. ``check_rep=False`` is forwarded only to the
    experimental API — its replication checker has no rule for the
    ``checkpoint_name`` primitive the MoE path tags collectives with.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=check_rep)


@dataclass(frozen=True)
class ParallelCtx:
    mesh: Mesh
    batch_axes: Tuple[str, ...] = ("data",)  # axes sharding the batch dim
    model_axis: str = "model"
    seq_shard: bool = True  # sequence-parallel residual stream between blocks
    expert_parallel: bool = True

    @property
    def n_model(self) -> int:
        return self.mesh.shape[self.model_axis]

    @property
    def n_batch(self) -> int:
        n = 1
        for ax in self.batch_axes:
            n *= self.mesh.shape[ax]
        return n

    def activation_spec(self, x: jax.Array) -> Optional[P]:
        """Residual-stream spec for (B, S, d) activations."""
        if x.ndim != 3:
            return None
        B, S, _ = x.shape
        batch = self.batch_axes if B % self.n_batch == 0 and B >= self.n_batch else None
        seq = (
            self.model_axis
            if self.seq_shard and S % self.n_model == 0 and S >= self.n_model
            else None
        )
        return P(batch, seq, None)

    def constrain_activations(self, x: jax.Array) -> jax.Array:
        spec = self.activation_spec(x)
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def batch_spec(self, ndim: int = 2) -> P:
        return P(self.batch_axes, *([None] * (ndim - 1)))
