from .ctx import ParallelCtx
from .sharding import (
    DEFAULT_RULES,
    estimate_padding_waste,
    param_specs,
    rules_for,
    shardings,
    spec_for,
    zero_specs,
)

__all__ = [
    "ParallelCtx",
    "DEFAULT_RULES",
    "estimate_padding_waste",
    "param_specs",
    "rules_for",
    "shardings",
    "spec_for",
    "zero_specs",
]
