"""Training launcher: CPU-runnable entry point over the fault-tolerant
Trainer (examples/train_lm.py is the tutorial version; this is the CLI).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --ckpt /tmp/ckpt

On a real TPU pod every host runs this with its own host_id; the synthetic
source shards by host and the mesh comes from make_production_mesh().
"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_NAMES, get_config, get_reduced
from repro.runtime import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = TrainerConfig(
        num_steps=args.steps,
        checkpoint_every=args.ckpt_every,
        log_every=max(args.steps // 20, 1),
        seq_len=args.seq,
        global_batch=args.batch,
        lr=args.lr,
        fail_at_step=args.fail_at,
    )
    with Trainer(cfg, tcfg, args.ckpt) as tr:
        out = tr.run_with_restarts() if args.fail_at else tr.run(resume=args.resume)
    for row in out["metrics"]:
        print(
            f"step {row['step']:>6d}  loss {row['loss']:.4f}  "
            f"grad_norm {row['grad_norm']:.3f}  lr {row['lr']:.2e}"
        )


if __name__ == "__main__":
    main()
