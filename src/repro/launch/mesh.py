"""Production mesh builders (assignment-fixed shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and the dry-run
must set XLA_FLAGS before that happens).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(ax for ax in mesh.axis_names if ax in ("pod", "data"))
