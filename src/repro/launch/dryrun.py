import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
)
# The two lines above MUST run before any jax import: jax locks the device
# count at first init. Everything below is ordinary code.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  jit(step).lower(**ShapeDtypeStruct inputs) . compile()
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, printing
``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for
§Roofline), parsing collective traffic out of the partitioned HLO, and
writing one JSON artifact per cell to benchmarks/artifacts/.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.analysis.hlo import collective_traffic, op_histogram
from repro.analysis.roofline import model_flops, terms_from_analysis
from repro.configs import ARCH_NAMES, get_config, param_count
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.optim import AdamWConfig, cosine_schedule
from repro.parallel.steps import build_decode_step, build_prefill, build_train_step

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts"


def lower_cell(arch: str, shape_name: str, multi_pod: bool, scan_probe=None, scan_unroll=False):
    cfg = get_config(arch)
    spec = cfg.shapes()[shape_name]
    model = build_model(cfg, scan_probe=scan_probe, scan_unroll=scan_unroll)
    mesh = make_production_mesh(multi_pod=multi_pod)
    batch_abstract = model.input_specs(shape_name, spec)
    kind = spec["kind"]
    with mesh:
        if kind == "train":
            ocfg = AdamWConfig(
                moments_dtype="bfloat16" if param_count(cfg)["total"] > 1e11 else "float32"
            )
            step, shardings, abstract = build_train_step(
                model, mesh, ocfg, cosine_schedule(3e-4, 2000, 100_000), batch_abstract
            )
            lowered = step.lower(
                abstract["params"],
                abstract["opt"],
                batch_abstract,
                jax.ShapeDtypeStruct((), jax.numpy.int32),
            )
        elif kind == "prefill":
            step, shardings = build_prefill(model, mesh, batch_abstract)
            lowered = step.lower(model.abstract_params(), batch_abstract)
        else:  # decode
            step, shardings = build_decode_step(model, mesh, batch_abstract)
            lowered = step.lower(
                model.abstract_params(),
                batch_abstract["tokens"],
                batch_abstract["caches"],
                batch_abstract["index"],
            )
    return cfg, spec, mesh, lowered


def run_cell(arch: str, shape_name: str, multi_pod: bool, *, verbose: bool = True) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    cfg, spec, mesh, lowered = lower_cell(arch, shape_name, multi_pod)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_info = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(mem, k, None)
        if v is not None:
            mem_info[k] = int(v)
    cost = compiled.cost_analysis() or {}
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    hlo = compiled.as_text()
    coll = collective_traffic(hlo)
    hist = op_histogram(hlo)

    # --- scan-depth correction -------------------------------------------------
    # XLA cost analysis counts a while-loop body ONCE, regardless of trip
    # count (verified by calibration), and layers live in scans. One probe
    # compile with every multi-layer scan group at count=2 and fully
    # UNROLLED gives base + 2*body; the full compile gives base + body;
    # their difference is the per-group body cost, so
    #   corrected = raw + (total_scan_layers - groups) * body_sum / groups
    # (valid because each arch's multi-layer scan groups are homogeneous).
    cfg_model = build_model(get_config(arch))
    stats = cfg_model.scan_group_stats()
    probe_info = {}
    if stats["groups"] > 0:
        _, _, _, lw = lower_cell(arch, shape_name, multi_pod, scan_probe=2, scan_unroll=True)
        cp = lw.compile()
        pc = cp.cost_analysis() or {}
        probe = {
            "flops": float(pc.get("flops", 0.0)),
            "bytes": float(pc.get("bytes accessed", 0.0)),
            "coll": collective_traffic(cp.as_text())["total_bytes"],
        }
        g, total_layers = stats["groups"], stats["layers"]
        raws = {"flops": flops, "bytes": bytes_accessed, "coll": coll["total_bytes"]}

        def corrected(key):
            body_sum = max(probe[key] - raws[key], 0.0)  # = sum of body costs
            return raws[key] + (total_layers - g) * body_sum / g

        probe_info = {
            "probe2_unrolled": probe,
            "scan_groups": g,
            "scan_layers": total_layers,
            "flops_raw": flops,
            "bytes_raw": bytes_accessed,
            "coll_raw": coll["total_bytes"],
        }
        flops = corrected("flops")
        bytes_accessed = corrected("bytes")
        coll = dict(coll, total_bytes=corrected("coll"))

    chips = 512 if multi_pod else 256
    terms = terms_from_analysis(flops, bytes_accessed, coll["total_bytes"])
    mf = model_flops(cfg, spec["seq_len"], spec["global_batch"], spec["kind"])
    useful_per_chip = mf["total"] / chips
    ratio = useful_per_chip / flops if flops else 0.0

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": spec["kind"],
        "seq_len": spec["seq_len"],
        "global_batch": spec["global_batch"],
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_info,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collectives": coll,
        "op_histogram": hist,
        "scan_correction": probe_info,
        "roofline": {
            **terms.to_dict(),
            "model_flops_total": mf["total"],
            "model_flops_attention": mf["attention"],
            "model_flops_per_chip": useful_per_chip,
            "useful_flops_ratio": ratio,
        },
    }
    if verbose:
        dev_bytes = mem_info.get("argument_size_in_bytes", 0) + mem_info.get(
            "temp_size_in_bytes", 0
        )
        print(
            f"[OK] {arch:>22s} {shape_name:<12s} {mesh_name:<8s}"
            f" compile={t_compile:6.1f}s args+temp={dev_bytes / 2**30:7.2f}GiB"
            f" flops/dev={flops:.3e} coll={coll['total_bytes'] / 2**20:9.1f}MiB"
            f" dominant={terms.dominant}",
            flush=True,
        )
    return result


def save_result(result: dict) -> pathlib.Path:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}.json"
    path = ART_DIR / name
    path.write_text(json.dumps(result, indent=1, default=float))
    return path


def all_cells() -> list:
    cells = []
    for arch in ARCH_NAMES:
        for shape_name in get_config(arch).shapes():
            cells.append((arch, shape_name))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=("single", "multi", "both"))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        if not args.arch:
            ap.error("--arch required unless --all")
        shapes = [args.shape] if args.shape else list(get_config(args.arch).shapes())
        cells = [(args.arch, s) for s in shapes]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape_name in cells:
        for multi_pod in meshes:
            mesh_name = "2x16x16" if multi_pod else "16x16"
            out = ART_DIR / f"{arch}__{shape_name}__{mesh_name}.json"
            if args.skip_existing and out.exists():
                prev = json.loads(out.read_text())
                if prev.get("ok"):
                    print(f"[skip] {arch} {shape_name} {mesh_name}", flush=True)
                    continue
            try:
                result = run_cell(arch, shape_name, multi_pod)
            except Exception as e:  # noqa: BLE001 - report, continue sweep
                failures += 1
                result = {
                    "arch": arch,
                    "shape": shape_name,
                    "mesh": mesh_name,
                    "ok": False,
                    "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:],
                }
                print(f"[FAIL] {arch} {shape_name} {mesh_name}: {e}", flush=True)
            save_result(result)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
