"""Fault-tolerant training driver.

Composition of every substrate in the framework:
  * jitted train_step from parallel/steps.py (sharded params/opt/batch)
  * ThreadPool-prefetched data pipeline (repro.data)
  * async atomic checkpoints + resume (repro.checkpoint)
  * watchdog heartbeat + failure injection for fault-tolerance tests
  * elastic restore: a checkpoint from any mesh restores onto this mesh

Designed for the multi-controller pattern at scale: every host runs this
driver; the data source shards by host id; checkpoint writes are per-host
shards (here: single-host writes everything). The restart loop — crash,
re-exec, restore-latest, continue — is exactly what a 1000-node job does on
preemption; ``run_with_restarts`` simulates it in-process for tests.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.core import ThreadPool
from repro.data import Prefetcher, SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, cosine_schedule
from repro.parallel.steps import build_train_step


@dataclass
class TrainerConfig:
    num_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 10
    keep_checkpoints: int = 3
    prefetch_depth: int = 2
    seed: int = 0
    # fault injection: raise at this step (once) to test restart/resume
    fail_at_step: Optional[int] = None
    heartbeat_timeout_s: float = 300.0


class Trainer:
    def __init__(
        self,
        model_cfg,
        tcfg: TrainerConfig,
        ckpt_dir: str,
        *,
        mesh=None,
        data_source=None,
    ) -> None:
        self.model_cfg = model_cfg
        self.tcfg = tcfg
        self.model = build_model(model_cfg)
        self.mesh = mesh
        self.pool = ThreadPool(4, name="trainer")
        self.ckpt = CheckpointManager(ckpt_dir, pool=self.pool, keep=tcfg.keep_checkpoints)
        self.ocfg = AdamWConfig(lr=tcfg.lr)
        self.lr_fn = cosine_schedule(tcfg.lr, tcfg.warmup, tcfg.num_steps)
        self.data = data_source or SyntheticTokens(
            model_cfg.vocab_size, tcfg.seq_len, tcfg.global_batch, seed=tcfg.seed
        )
        self._failed_once = False
        self.metrics_log: list[dict] = []
        self._heartbeat = time.monotonic()

    # -- state --------------------------------------------------------------------

    def init_state(self) -> dict:
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        return {
            "params": params,
            "opt": adamw_init(self.ocfg, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _build_step(self):
        if self.mesh is not None:
            spec = {
                "seq_len": self.tcfg.seq_len,
                "global_batch": self.tcfg.global_batch,
                "kind": "train",
            }
            batch_abstract = self.model.input_specs("train", spec)
            step, shardings, _ = build_train_step(
                self.model, self.mesh, self.ocfg, self.lr_fn, batch_abstract, donate=False
            )
            return step

        def step_fn(params, opt_state, batch, step):
            from repro.optim import adamw_update

            (loss, metrics), grads = jax.value_and_grad(
                lambda p: self.model.loss(p, batch), has_aux=True
            )(params)
            lr = self.lr_fn(step)
            new_params, new_opt, om = adamw_update(self.ocfg, lr, params, grads, opt_state)
            return new_params, new_opt, {"loss": loss, **metrics, **om}

        return jax.jit(step_fn)

    # -- run -----------------------------------------------------------------------

    def run(self, *, resume: bool = True) -> dict:
        state = self.init_state()
        start_step = 0
        if resume and self.ckpt.latest_step() is not None:
            state, meta = self.ckpt.restore(state)
            start_step = int(meta["step"])
        step_fn = self._build_step()
        prefetch = Prefetcher(
            self.data, pool=self.pool, depth=self.tcfg.prefetch_depth, start_step=start_step
        )
        params, opt = state["params"], state["opt"]
        try:
            for step in range(start_step, self.tcfg.num_steps):
                self._check_heartbeat()
                if (
                    self.tcfg.fail_at_step is not None
                    and step == self.tcfg.fail_at_step
                    and not self._failed_once
                ):
                    self._failed_once = True
                    raise RuntimeError(f"injected failure at step {step}")
                batch = prefetch.get()
                params, opt, metrics = step_fn(params, opt, batch, jnp.asarray(step))
                self._heartbeat = time.monotonic()
                if step % self.tcfg.log_every == 0 or step == self.tcfg.num_steps - 1:
                    row = {k: float(v) for k, v in metrics.items()}
                    row["step"] = step
                    self.metrics_log.append(row)
                if (step + 1) % self.tcfg.checkpoint_every == 0:
                    self.ckpt.save_async(
                        step + 1,
                        {"params": params, "opt": opt, "step": jnp.asarray(step + 1)},
                        meta={"step": step + 1, "cursor": prefetch.cursor},
                    )
            # final checkpoint (skip if the loop just saved this step)
            if self.tcfg.num_steps % self.tcfg.checkpoint_every != 0:
                self.ckpt.save_async(
                    self.tcfg.num_steps,
                    {"params": params, "opt": opt, "step": jnp.asarray(self.tcfg.num_steps)},
                    meta={"step": self.tcfg.num_steps, "cursor": prefetch.cursor},
                )
            self.ckpt.wait()
            return {"params": params, "opt": opt, "metrics": self.metrics_log}
        finally:
            prefetch.close()

    def run_with_restarts(self, max_restarts: int = 3) -> dict:
        """The 1000-node preemption loop, in-process: crash -> restore ->
        continue. Used by the fault-tolerance tests and examples."""
        attempts = 0
        while True:
            try:
                return self.run(resume=True)
            except RuntimeError as e:
                attempts += 1
                if attempts > max_restarts:
                    raise
                self.ckpt.wait()
                print(f"[trainer] restart {attempts} after: {e}", flush=True)

    # -- watchdog ---------------------------------------------------------------------

    def _check_heartbeat(self) -> None:
        if time.monotonic() - self._heartbeat > self.tcfg.heartbeat_timeout_s:
            raise TimeoutError("watchdog: no step completed within heartbeat window")

    def close(self) -> None:
        try:
            self.ckpt.wait(60)
        finally:
            self.pool.close()

    def __enter__(self) -> "Trainer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
