"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

The SSD form computes the selective-SSM recurrence as chunked matmuls
(MXU-friendly on TPU, DESIGN.md §6):

  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t ⊗ x_t          (per head)
  y_t = C_t · h_t + D * x_t

Chunking sequence S into (nc × cl): within a chunk the recurrence unrolls
into a masked quadratic form (``intra``), and chunk-final states propagate
through a tiny scan over chunks (``inter``). ``ssd_reference`` is the
pure-jnp oracle; the Pallas kernel in repro/kernels/ssd.py implements the
same contraction pattern with VMEM tiling.

Layout follows the Mamba2 reference: one fused input projection producing
[z | x | B | C | dt], a depthwise causal conv over [x|B|C], per-head scalar
A (log-parameterised) and D, gated RMSNorm, output projection. n_groups=1.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Alloc, rms_norm


def ssm_dims(cfg) -> dict:
    if cfg.family == "hybrid":
        d_inner = cfg.num_heads * cfg.head_dim  # match attention width
    else:
        d_inner = cfg.ssm_expand * cfg.d_model
    nheads = cfg.ssm_heads or d_inner // cfg.ssm_head_dim
    return dict(
        d_inner=d_inner,
        nheads=nheads,
        headdim=d_inner // nheads,
        dstate=cfg.ssm_state,
        conv_dim=d_inner + 2 * cfg.ssm_state,
    )


def ssm_params(cfg, a: Alloc) -> dict:
    dims = ssm_dims(cfg)
    d, di, nh, N = cfg.d_model, dims["d_inner"], dims["nheads"], dims["dstate"]
    conv_dim = dims["conv_dim"]
    proj_out = 2 * di + 2 * N + nh  # [z | x | B | C | dt]
    return {
        "in_proj": a.param("in_proj", (d, proj_out), ("embed", "ssm_inner")),
        "conv_w": a.param("conv_w", (cfg.conv_kernel, conv_dim), (None, "ssm_inner")),
        "conv_b": a.param("conv_b", (conv_dim,), ("ssm_inner",), init="zeros"),
        "a_log": a.param("a_log", (nh,), ("ssm_heads",), init="ssm_a", dtype=jnp.float32),
        "d_skip": a.param("d_skip", (nh,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": a.param("dt_bias", (nh,), ("ssm_heads",), init="ssm_dt", dtype=jnp.float32),
        "norm": a.param("norm", (di,), ("ssm_inner",), init="zeros"),
        "out_proj": a.param("out_proj", (di, d), ("ssm_inner", "embed")),
    }


def ssm_cache_shape(cfg, batch: int, dtype) -> dict:
    dims = ssm_dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_kernel - 1, dims["conv_dim"]), dtype),
        "state": jax.ShapeDtypeStruct(
            (batch, dims["nheads"], dims["headdim"], dims["dstate"]), jnp.float32
        ),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} x[..., k]."""
    cl = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((cl, cl), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_reference(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) f32, post-softplus
    A: jax.Array,  # (H,) f32, negative
    Bm: jax.Array,  # (B, S, N)
    Cm: jax.Array,  # (B, S, N)
    *,
    chunk: int = 64,
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N) f32
    return_final_state: bool = False,
):
    """Chunked SSD scan, pure jnp (the oracle for the Pallas kernel)."""
    Bb, S, H, Pd = x.shape
    N = Bm.shape[-1]
    cl = min(chunk, S)
    S_orig = S
    if S % cl:  # pad with dt=0 steps: exp(0)=1 keeps state, 0*x adds nothing
        pad = cl - S % cl
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // cl

    xf = x.astype(jnp.float32)
    dA = dt * A  # (B, S, H)
    # chunked views
    xr = xf.reshape(Bb, nc, cl, H, Pd)
    dtr = dt.reshape(Bb, nc, cl, H)
    dAr = dA.reshape(Bb, nc, cl, H).transpose(0, 1, 3, 2)  # (B,nc,H,cl)
    Br = Bm.astype(jnp.float32).reshape(Bb, nc, cl, N)
    Cr = Cm.astype(jnp.float32).reshape(Bb, nc, cl, N)

    # intra-chunk quadratic term
    L = jnp.exp(_segsum(dAr))  # (B,nc,H,cl,cl)
    scores = jnp.einsum("bcin,bcjn->bcij", Cr, Br)  # (B,nc,cl,cl)
    M = scores[:, :, None] * L  # (B,nc,H,cl,cl)
    xdt = xr * dtr[..., None]  # dt-weighted inputs
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", M, xdt)

    # chunk-final states: sum_j exp(sum_{j<k<=end} dA) B_j ⊗ (dt_j x_j)
    dA_cum = jnp.cumsum(dAr, axis=-1)  # (B,nc,H,cl)
    decay_to_end = jnp.exp(dA_cum[..., -1:] - dA_cum)  # (B,nc,H,cl)
    states = jnp.einsum("bchj,bcjn,bcjhp->bchpn", decay_to_end, Br, xdt)

    # inter-chunk recurrence (tiny scan over nc)
    chunk_decay = jnp.exp(dA_cum[..., -1])  # (B,nc,H)
    init = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((Bb, H, Pd, N), jnp.float32)
    )

    def step(carry, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2))
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,P,N)

    # inter-chunk contribution: C_i · (decay into chunk) state_prev
    in_decay = jnp.exp(dA_cum)  # (B,nc,H,cl)
    y_inter = jnp.einsum("bcin,bchpn,bchi->bcihp", Cr, prev_states, in_decay)

    y = (y_intra + y_inter).reshape(Bb, S, H, Pd)[:, :S_orig].astype(x.dtype)
    if return_final_state:
        return y, final
    return y


def ssd_decode_step(
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H) f32
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, N)
    Cm: jax.Array,  # (B, N)
    state: jax.Array,  # (B, H, P, N) f32
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update (O(1) in sequence length)."""
    dA = jnp.exp(dt * A)  # (B, H)
    xdt = x.astype(jnp.float32) * dt[..., None]
    new_state = state * dA[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bm.astype(jnp.float32), xdt
    )
    y = jnp.einsum("bhpn,bn->bhp", new_state, Cm.astype(jnp.float32))
    return y.astype(x.dtype), new_state


def _causal_conv(seq: jax.Array, w: jax.Array, b: jax.Array, prepend: Optional[jax.Array]):
    """Depthwise causal conv over (B, S, C) with kernel (K, C)."""
    K = w.shape[0]
    if prepend is None:
        pad = jnp.zeros((seq.shape[0], K - 1, seq.shape[2]), seq.dtype)
    else:
        pad = prepend.astype(seq.dtype)
    full = jnp.concatenate([pad, seq], axis=1)  # (B, S+K-1, C)
    out = sum(full[:, i : full.shape[1] - (K - 1 - i), :] * w[i] for i in range(K))
    return jax.nn.silu(out + b), full[:, full.shape[1] - (K - 1) :, :]


def ssm_apply(
    cfg,
    p: dict,
    u: jax.Array,  # (B, S, d_model)
    *,
    cache: Optional[dict] = None,
    return_cache: bool = False,
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence (cache=None) or recurrent decode (cache given, S==1)."""
    dims = ssm_dims(cfg)
    di, nh, Pd, N = dims["d_inner"], dims["nheads"], dims["headdim"], dims["dstate"]
    B, S, _ = u.shape

    zxbcdt = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xBC, dt_raw = jnp.split(zxbcdt, [di, di + dims["conv_dim"]], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    A = -jnp.exp(p["a_log"])  # (nh,)

    if cache is None:
        xBC, tail = _causal_conv(xBC, p["conv_w"], p["conv_b"], None)
        xc, Bc, Cc = jnp.split(xBC, [di, di + N], axis=-1)
        x = xc.reshape(B, S, nh, Pd)
        if return_cache:
            y, final = ssd_reference(
                x, dt, A, Bc, Cc, chunk=min(cfg.ssm_chunk, S), return_final_state=True
            )
            new_cache = {"conv": tail, "state": final}
        else:
            if use_kernel and S > 1:
                from repro.kernels import ops as kops

                y = kops.ssd(x, dt, A, Bc, Cc, chunk=cfg.ssm_chunk)
            else:
                y = ssd_reference(x, dt, A, Bc, Cc, chunk=min(cfg.ssm_chunk, S))
            new_cache = None
    else:
        # decode: conv ring buffer + recurrent state update
        conv_in = jnp.concatenate([cache["conv"], xBC], axis=1)  # (B, K, conv)
        w, bbias = p["conv_w"], p["conv_b"]
        conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, w) + bbias)[:, None, :]
        xc, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
        x = xc.reshape(B, nh, Pd)
        y1, new_state = ssd_decode_step(
            x, dt[:, 0], A, Bc[:, 0], Cc[:, 0], cache["state"]
        )
        y = y1[:, None]
        new_cache = {"conv": conv_in[:, 1:], "state": new_state}

    yd = y.reshape(B, S, di) + (
        x.reshape(B, S, di) * jnp.repeat(p["d_skip"], Pd).astype(y.dtype)
    )
    yd = yd * jax.nn.silu(z.astype(jnp.float32)).astype(yd.dtype)  # gate
    yd = rms_norm(yd, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", yd, p["out_proj"])
    return out, new_cache
