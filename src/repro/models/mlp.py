"""Feed-forward blocks: SwiGLU/GeGLU (gated) and plain GELU MLP (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import Alloc, act_fn


def gated_mlp_params(cfg, a: Alloc, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w_gate": a.param("w_gate", (d, ff), ("embed", "mlp")),
        "w_up": a.param("w_up", (d, ff), ("embed", "mlp")),
        "w_down": a.param("w_down", (ff, d), ("mlp", "embed")),
    }


def gated_mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    act = act_fn(cfg.act if cfg.act in ("silu", "gelu") else "silu")
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", act(g) * u, p["w_down"])


def dense_mlp_params(cfg, a: Alloc, d_ff: int | None = None) -> dict:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    return {
        "w1": a.param("w1", (d, ff), ("embed", "mlp")),
        "b1": a.param("b1", (ff,), ("mlp",), init="zeros"),
        "w2": a.param("w2", (ff, d), ("mlp", "embed")),
        "b2": a.param("b2", (d,), ("embed",), init="zeros"),
    }


def dense_mlp(cfg, p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["w1"]) + p["b1"], approximate=True)
    return jnp.einsum("bsf,fd->bsd", h, p["w2"]) + p["b2"]


def mlp_params(cfg, a: Alloc, d_ff: int | None = None) -> dict:
    if cfg.act == "gelu_mlp":
        return dense_mlp_params(cfg, a, d_ff)
    return gated_mlp_params(cfg, a, d_ff)


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    if cfg.act == "gelu_mlp":
        return dense_mlp(cfg, p, x)
    return gated_mlp(cfg, p, x)
