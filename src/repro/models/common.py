"""Shared model-building utilities.

``Alloc`` is the single source of truth for parameters: the same model code
path produces (depending on mode) initialized arrays, logical-axis trees for
sharding, or ShapeDtypeStructs for allocation-free dry runs.
"""
from __future__ import annotations

import hashlib
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Logical axis vocabulary (mapped to mesh axes in repro.parallel.sharding):
#   layers   scan-stacked layer dim (never sharded)
#   embed    d_model
#   vocab    vocabulary
#   heads    attention heads / q-head dim groups
#   kv       kv heads
#   mlp      feed-forward hidden
#   experts  MoE expert dim
#   expert_mlp  per-expert hidden (sharded over data for very large MoE)
#   lora     MLA compression dims
#   conv/state/ssm_heads  mamba dims

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


def _path_key(root: jax.Array, path: str) -> jax.Array:
    h = int.from_bytes(hashlib.sha1(path.encode()).digest()[:4], "little")
    return jax.random.fold_in(root, h)


class Alloc:
    """Parameter allocator with three modes:

    init      -> returns initialized jnp arrays (mode for real runs)
    abstract  -> returns jax.ShapeDtypeStruct (dry-run, no allocation)
    axes      -> returns the logical-axes tuple (sharding-rule input)
    """

    def __init__(self, mode: str, key: Optional[jax.Array] = None, dtype=jnp.bfloat16):
        assert mode in ("init", "abstract", "axes")
        self.mode = mode
        self.key = key
        self.dtype = dtype
        self._path: list[str] = []

    # scoped path management so call sites stay terse
    class _Scope:
        def __init__(self, alloc: "Alloc", name: str):
            self.alloc, self.name = alloc, name

        def __enter__(self):
            self.alloc._path.append(self.name)

        def __exit__(self, *exc):
            self.alloc._path.pop()

    def scope(self, name: str) -> "Alloc._Scope":
        return Alloc._Scope(self, name)

    def param(
        self,
        name: str,
        shape: Sequence[int],
        axes: Tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
        dtype: Any = None,
    ):
        assert len(shape) == len(axes), f"{name}: shape {shape} vs axes {axes}"
        if self.mode == "axes":
            return axes
        dtype = dtype or self.dtype
        if self.mode == "abstract":
            return jax.ShapeDtypeStruct(tuple(shape), dtype)
        path = "/".join(self._path + [name])
        k = _path_key(self.key, path)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            if scale is None:  # fan-in variance scaling over contracted dims
                fan_in = shape[0] if len(shape) <= 2 else int(np.prod(shape[:-1]))
                scale = fan_in ** -0.5
            return (jax.random.normal(k, tuple(shape), jnp.float32) * scale).astype(dtype)
        if init == "embed":
            return (jax.random.normal(k, tuple(shape), jnp.float32) * (scale or 1.0)).astype(dtype)
        if init == "uniform":
            lim = scale or (shape[0] ** -0.5)
            return jax.random.uniform(k, tuple(shape), jnp.float32, -lim, lim).astype(dtype)
        if init == "ssm_dt":  # softplus-inverse-spaced dt bias (mamba init)
            lo, hi = 0.001, 0.1
            u = jax.random.uniform(k, tuple(shape), jnp.float32)
            dt = jnp.exp(u * (np.log(hi) - np.log(lo)) + np.log(lo))
            return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        if init == "ssm_a":  # A in [1, 16), stored as log
            u = jax.random.uniform(k, tuple(shape), jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(dtype)
        raise ValueError(f"unknown init {init!r}")


# -- numerics ------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": lambda x: jax.nn.gelu(x, approximate=True)}[name]


# -- rotary embeddings -----------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    inv = rope_frequencies(dh, theta)  # (Dh/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # (..., S, 1, Dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- masks ------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask_bias(
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    window: Optional[int] = None,
    prefix_len: Optional[jax.Array] = None,
    valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Additive attention bias, f32: 0 = attend, NEG_INF = masked.

    q_pos: (Sq,), k_pos: (Sk,) absolute positions. window: sliding-window
    radius (keys within [q-window+1, q]). prefix_len: positions < prefix_len
    attend bidirectionally (PaLI-Gemma prefix-LM). valid_len: keys at
    positions >= valid_len masked (decode with partially-filled cache).
    """
    q = q_pos[:, None].astype(jnp.int32)
    k = k_pos[None, :].astype(jnp.int32)
    ok = k <= q
    if prefix_len is not None:
        ok = ok | (k < prefix_len)
    if window is not None:
        ok = ok & (k > q - window)
        if prefix_len is not None:
            ok = ok | ((k < prefix_len) & (k > q - window))
    if valid_len is not None:
        ok = ok & (k < valid_len)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
