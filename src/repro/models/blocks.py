"""Decoder/encoder blocks and scan-based layer stacks.

Families:
  dense / vlm     pre-norm attn + gated MLP
  moe             pre-norm attn + (routed MoE | dense MLP for first_dense)
  ssm             pre-norm Mamba2 SSD block (no separate MLP)
  hybrid (hymba)  parallel attention + SSD heads fused, then MLP
  encdec          whisper-style LayerNorm blocks, decoder adds cross-attn

Stacks scan over stacked layer params (compile time O(1) in depth). Configs
with ``global_layers`` (hymba: full-attention layers amid sliding-window
layers, with differently-shaped KV caches) use a segmented stack: scans over
the uniform SWA segments, plain calls for the global layers.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    gqa_attention,
    gqa_cache_shape,
    gqa_params,
    mla_attention,
    mla_cache_shape,
    mla_params,
)
from .common import Alloc, layer_norm, rms_norm
from .mlp import mlp_apply, mlp_params
from .moe import moe_apply, moe_params
from .ssm import ssm_apply, ssm_cache_shape, ssm_params


class StackedAlloc:
    """Prepends a ``layers`` dim to every param (for scan-stacked layers)."""

    def __init__(self, a: Alloc, num_layers: int):
        self._a, self._L = a, num_layers
        self.mode = a.mode

    def param(self, name, shape, axes, **kw):
        return self._a.param(name, (self._L, *shape), ("layers", *axes), **kw)

    def scope(self, name):
        return self._a.scope(name)


def _norm_params(cfg, a, name: str):
    if cfg.norm == "ln":
        return {
            "w": a.param(f"{name}_w", (cfg.d_model,), ("embed",), init="ones"),
            "b": a.param(f"{name}_b", (cfg.d_model,), ("embed",), init="zeros"),
        }
    return {"w": a.param(f"{name}_w", (cfg.d_model,), ("embed",), init="zeros")}


def _norm(cfg, p, x):
    if cfg.norm == "ln":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["w"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------


def block_params(cfg, a, *, kind: str = "decoder", moe_layer: bool = True) -> dict:
    """kind: decoder | encoder | xdecoder (decoder with cross-attention)."""
    p: dict[str, Any] = {}
    with a.scope("attn"):
        if cfg.attention == "mla":
            p["attn"] = mla_params(cfg, a)
        elif cfg.attention == "gqa":
            p["attn"] = gqa_params(cfg, a)
    if cfg.attention != "none":
        p["attn_norm"] = _norm_params(cfg, a, "attn_norm")
    if cfg.family in ("ssm", "hybrid"):
        with a.scope("ssm"):
            p["ssm"] = ssm_params(cfg, a)
        p["ssm_norm"] = _norm_params(cfg, a, "ssm_norm")
    if kind == "xdecoder":
        with a.scope("cross"):
            p["cross"] = gqa_params(cfg, a)
        p["cross_norm"] = _norm_params(cfg, a, "cross_norm")
    if cfg.d_ff > 0 or (cfg.is_moe and moe_layer):
        p["mlp_norm"] = _norm_params(cfg, a, "mlp_norm")
        if cfg.is_moe and moe_layer:
            with a.scope("moe"):
                p["moe"] = moe_params(cfg, a)
        else:
            with a.scope("mlp"):
                p["mlp"] = mlp_params(cfg, a)
    return p


def block_apply(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    bidirectional: bool = False,
    prefix_len: Optional[int] = None,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
    emit_slices: bool = False,
    enc_out: Optional[jax.Array] = None,  # encoder states for cross-attn
    ctx=None,
    window: Optional[int] = None,  # None = full attention (global layers)
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x_out, new_cache, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict = {}

    if cfg.attention != "none" and "attn" in p:
        h = _norm(cfg, p["attn_norm"], x)
        attn_fn = mla_attention if cfg.attention == "mla" else gqa_attention
        a_out, a_cache = attn_fn(
            cfg,
            p["attn"],
            h,
            positions,
            window=window,
            prefix_len=prefix_len,
            bidirectional=bidirectional,
            cache=cache.get("attn") if cache else None,
            cache_index=cache_index,
            return_cache=return_cache,
            emit_slices=emit_slices,
            use_kernel=cfg.use_kernels,
        )
        if cfg.family == "hybrid":
            # parallel attn + SSD heads on the same normalized input (hymba)
            s_in = _norm(cfg, p["ssm_norm"], x)
            s_out, s_cache = ssm_apply(
                cfg, p["ssm"], s_in, cache=cache.get("ssm") if cache else None,
                return_cache=return_cache, use_kernel=cfg.use_kernels,
            )
            x = x + 0.5 * (a_out + s_out)
            if s_cache is not None:
                new_cache["ssm"] = s_cache
        else:
            x = x + a_out
        if a_cache is not None:
            new_cache["attn"] = a_cache
    elif cfg.family in ("ssm", "hybrid"):
        s_in = _norm(cfg, p["ssm_norm"], x)
        s_out, s_cache = ssm_apply(
            cfg, p["ssm"], s_in, cache=cache.get("ssm") if cache else None,
            return_cache=return_cache, use_kernel=cfg.use_kernels,
        )
        x = x + s_out
        if s_cache is not None:
            new_cache["ssm"] = s_cache

    if "cross" in p:
        h = _norm(cfg, p["cross_norm"], x)
        c_out, c_cache = _cross_attention(
            cfg, p["cross"], h, enc_out, cache=cache.get("cross") if cache else None,
            return_cache=return_cache,
        )
        x = x + c_out
        if c_cache is not None:
            if emit_slices and cache is not None:
                # encoder K/V are static during decode: emit a sentinel and
                # let the stack reuse the donated cache unchanged
                new_cache["cross"] = jnp.zeros((), jnp.int32)
            else:
                new_cache["cross"] = c_cache

    if "moe" in p:
        h = _norm(cfg, p["mlp_norm"], x)
        m_out, aux = moe_apply(cfg, p["moe"], h, ctx=ctx)
        x = x + m_out
    elif "mlp" in p:
        h = _norm(cfg, p["mlp_norm"], x)
        x = x + mlp_apply(cfg, p["mlp"], h)

    return x, (new_cache if new_cache else None), aux


def _cross_attention(cfg, p, x, enc_out, *, cache=None, return_cache=False):
    """Cross-attention: queries from decoder, keys/values from encoder.

    During decode the projected encoder K/V are static — cached once.
    """
    from .attention import attend

    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cache is not None:
        k, v = cache["k"], cache["v"]
    else:
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    bias = jnp.zeros((1, S, k.shape[1]), jnp.float32)  # full cross visibility
    out = attend(q, k, v, bias)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    # keep the cache flowing during decode (encoder K/V are static)
    new_cache = {"k": k, "v": v} if (return_cache or cache is not None) else None
    return y, new_cache


# ---------------------------------------------------------------------------
# cache shapes
# ---------------------------------------------------------------------------


def block_cache_shape(
    cfg, batch: int, seq: int, dtype, *, is_global: bool = True, xdec_enc_seq: Optional[int] = None
) -> dict:
    """Abstract cache for ONE layer. seq = the KV length this layer keeps."""
    c: dict[str, Any] = {}
    if cfg.attention == "mla":
        c["attn"] = mla_cache_shape(cfg, batch, seq, dtype)
    elif cfg.attention == "gqa":
        ring = (not is_global) and cfg.window is not None and cfg.window < seq
        kv_len = min(seq, cfg.window) if ring else seq
        c["attn"] = gqa_cache_shape(cfg, batch, kv_len, dtype, ring=ring)
    if cfg.family in ("ssm", "hybrid"):
        c["ssm"] = ssm_cache_shape(cfg, batch, dtype)
    if xdec_enc_seq is not None:
        c["cross"] = gqa_cache_shape(cfg, batch, xdec_enc_seq, dtype)
    return c
