"""Mixture-of-Experts layer: top-k router + two execution paths.

``dense``    every token through every expert, gate-weighted sum. Exact,
             mesh-agnostic; used by CPU smoke tests and as the numerical
             oracle for the EP path.

``ep``       production expert parallelism under ``shard_map``: tokens are
             sharded over (pod, data) × model (sequence), experts over
             `model`. Dispatch is gather/scatter (no GShard dispatch-einsum
             FLOPs): per-shard capacity buffers are filled by scatter, sent
             expert-major with ``all_to_all`` over the model axis, run
             through grouped GEMMs, and returned. Capacity overflow drops
             (GShard semantics); tests pick capacity_factor high enough that
             ep == dense exactly.

Router + auxiliary load-balancing loss are computed OUTSIDE the shard_map so
gradients and the aux term stay in plain global-land.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import Alloc, act_fn


def moe_params(cfg, a: Alloc) -> dict:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    p = {
        "router": a.param("router", (d, E), ("embed", "experts"), dtype=jnp.float32),
        "w_gate": a.param("w_gate", (E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_up": a.param("w_up", (E, d, ff), ("experts", "embed", "expert_mlp")),
        "w_down": a.param("w_down", (E, ff, d), ("experts", "expert_mlp", "embed")),
    }
    if cfg.num_shared_experts:
        sff = cfg.num_shared_experts * ff
        p["shared"] = {
            "w_gate": a.param("shared_w_gate", (d, sff), ("embed", "mlp")),
            "w_up": a.param("shared_w_up", (d, sff), ("embed", "mlp")),
            "w_down": a.param("shared_w_down", (sff, d), ("mlp", "embed")),
        }
    return p


def route(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (weights (B,S,K) f32, ids (B,S,K) i32, aux)."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)  # renormalize
    # Switch-style load-balancing auxiliary loss
    E = cfg.num_experts
    density = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_loss * E * jnp.sum(density * mean_prob)
    return weights, ids, aux


def _expert_ffn(cfg, w_gate, w_up, w_down, xs: jax.Array) -> jax.Array:
    """Grouped SwiGLU: xs (E, C, d) with per-expert weights (E, d, ff)."""
    act = act_fn(cfg.act if cfg.act in ("silu", "gelu") else "silu")
    g = jnp.einsum("ecd,edf->ecf", xs, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xs, w_up)
    return jnp.einsum("ecf,efd->ecd", act(g) * u, w_down)


# ---------------------------------------------------------------------------
# dense path (oracle / smoke tests)
# ---------------------------------------------------------------------------


def moe_dense(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    weights, ids, aux = route(cfg, p, x)
    act = act_fn(cfg.act if cfg.act in ("silu", "gelu") else "silu")
    g = jnp.einsum("bsd,edf->ebsf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->ebsf", x, p["w_up"])
    y_all = jnp.einsum("ebsf,efd->ebsd", act(g) * u, p["w_down"])  # (E,B,S,d)
    onehot = jax.nn.one_hot(ids, cfg.num_experts, dtype=x.dtype)  # (B,S,K,E)
    combine = jnp.einsum("bske,bsk->ebs", onehot, weights.astype(x.dtype))
    y = jnp.einsum("ebs,ebsd->bsd", combine, y_all)
    if cfg.num_shared_experts:
        sp = p["shared"]
        y = y + jnp.einsum(
            "bsf,fd->bsd",
            act(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
            * jnp.einsum("bsd,df->bsf", x, sp["w_up"]),
            sp["w_down"],
        )
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel path (shard_map)
# ---------------------------------------------------------------------------


def _dispatch_local(cfg, x2d, ids, capacity: int):
    """Per-shard gather/scatter dispatch.

    x2d: (T, d); ids: (T, K). Returns (buffer (E, C, d), slot (T*K,),
    keep (T, K)). No dispatch-einsum FLOPs — pure scatter.
    """
    T, d = x2d.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    flat_ids = ids.reshape(-1)  # (T*K,) expert of each copy
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    pos = jnp.sum(pos * onehot, axis=-1)  # (T*K,)
    keep = pos < capacity
    slot = flat_ids * capacity + pos  # index into (E*C) buffer
    slot = jnp.where(keep, slot, E * capacity)  # overflow -> scratch row
    token_of_copy = jnp.repeat(jnp.arange(T), K)
    buf = jnp.zeros((E * capacity + 1, d), x2d.dtype).at[slot].add(x2d[token_of_copy])
    return buf[:-1].reshape(E, capacity, d), slot, keep.reshape(T, K)


def _combine_local(y_buf, weights, slot, keep):
    """Inverse of dispatch: gather each copy's expert output, gate, sum.

    y_buf: (E, C, d); weights/keep: (T, K); slot: (T*K,) into E*C (+scratch).
    """
    E, C, d = y_buf.shape
    T, K = keep.shape
    flat = jnp.concatenate([y_buf.reshape(E * C, d), jnp.zeros((1, d), y_buf.dtype)])
    y_copies = flat[slot].reshape(T, K, d)
    w = (weights * keep).astype(y_buf.dtype)
    return jnp.einsum("tkd,tk->td", y_copies, w)


def capacity_for(cfg, tokens_per_shard: int) -> int:
    c = math.ceil(tokens_per_shard * cfg.experts_per_token / cfg.num_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def moe_ep(cfg, p: dict, x: jax.Array, ctx) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE. ``ctx`` is a repro.parallel.ParallelCtx."""
    B, S, d = x.shape
    weights, ids, aux = route(cfg, p, x)
    mesh = ctx.mesh
    model_axis = ctx.model_axis
    n_model = mesh.shape[model_axis]
    batch_axes = ctx.batch_axes  # e.g. ('pod', 'data')
    n_data = 1
    for ax in batch_axes:
        n_data *= mesh.shape[ax]
    E = cfg.num_experts
    assert E % n_model == 0, f"{E} experts not divisible by model={n_model}"
    K = cfg.experts_per_token
    seq_sharded = S % n_model == 0 and S >= n_model  # train/prefill: SP tokens
    T_local = (B // n_data) * (S // n_model if seq_sharded else S)
    if seq_sharded:
        C = capacity_for(cfg, T_local)
    else:
        # decode: capacity must cover the worst case (all local tokens on one
        # expert) — dropping a decode token corrupts its stream.
        C = max(8, -(-T_local // 8) * 8)

    x_spec = P(batch_axes, model_axis if seq_sharded else None, None)
    # 2D expert sharding (deepseek-v2): per-expert hidden dim lives sharded
    # over the data axis (ZeRO-3 style) and is all-gathered just-in-time
    # inside the body — transient full weights, persistent 1/n_data storage.
    ff_axis = dict(cfg.sharding_rules or ()).get("expert_mlp")
    if ff_axis is not None:
        wg_spec = P(model_axis, None, ff_axis)  # (E, d, ff)
        wd_spec = P(model_axis, ff_axis, None)  # (E, ff, d)
    else:
        wg_spec = wd_spec = P(model_axis)

    from jax.ad_checkpoint import checkpoint_name

    def body(x_l, w_l, ids_l, w_gate, w_up, w_down):
        if ff_axis is not None:  # FSDP gather of the expert FFN weights
            w_gate = checkpoint_name(
                jax.lax.all_gather(w_gate, ff_axis, axis=2, tiled=True), "moe_fsdp_gather")
            w_up = checkpoint_name(
                jax.lax.all_gather(w_up, ff_axis, axis=2, tiled=True), "moe_fsdp_gather")
            w_down = checkpoint_name(
                jax.lax.all_gather(w_down, ff_axis, axis=1, tiled=True), "moe_fsdp_gather")
        Bl, Sl, _ = x_l.shape
        Tl = Bl * Sl
        x2d = x_l.reshape(Tl, d)
        buf, slot, keep = _dispatch_local(cfg, x2d, ids_l.reshape(Tl, K), C)
        if seq_sharded:
            # expert-major exchange: (E,C,d) -> (E/n, n*C, d) per model rank
            buf = checkpoint_name(
                jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1, tiled=True),
                "moe_a2a")
            y_buf = _expert_ffn(cfg, w_gate, w_up, w_down, buf)
            y_buf = checkpoint_name(
                jax.lax.all_to_all(y_buf, model_axis, split_axis=1, concat_axis=0, tiled=True),
                "moe_a2a")
        else:
            # decode: tokens replicated over model; each rank runs its local
            # expert slice then psums the scattered outputs back together.
            e_loc = E // n_model
            idx = jax.lax.axis_index(model_axis) * e_loc
            buf_l = jax.lax.dynamic_slice_in_dim(buf, idx, e_loc, axis=0)
            y_l = _expert_ffn(cfg, w_gate, w_up, w_down, buf_l)
            y_full = jnp.zeros((E, C, d), y_l.dtype)
            y_full = jax.lax.dynamic_update_slice_in_dim(y_full, y_l, idx, axis=0)
            y_buf = jax.lax.psum(y_full, model_axis)
        y2d = _combine_local(y_buf, w_l.reshape(Tl, K), slot, keep)
        return y2d.reshape(Bl, Sl, d)

    from repro.parallel.ctx import shard_map

    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(x_spec, x_spec, x_spec, wg_spec, wg_spec, wd_spec),
        out_specs=x_spec,
        check_rep=False,  # checkpoint_name has no replication rule on 0.4.x
    )(x, weights, ids, p["w_gate"], p["w_up"], p["w_down"])

    if cfg.num_shared_experts:
        sp = p["shared"]
        act = act_fn(cfg.act if cfg.act in ("silu", "gelu") else "silu")
        y = y + jnp.einsum(
            "bsf,fd->bsd",
            act(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
            * jnp.einsum("bsd,df->bsf", x, sp["w_up"]),
            sp["w_down"],
        )
    return y, aux


def moe_apply(cfg, p: dict, x: jax.Array, ctx=None) -> Tuple[jax.Array, jax.Array]:
    if ctx is not None and ctx.expert_parallel:
        return moe_ep(cfg, p, x, ctx)
    return moe_dense(cfg, p, x)
