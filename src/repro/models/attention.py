"""Attention variants: GQA/MQA (RoPE, optional window/bias), and MLA
(DeepSeek-V2 multi-head latent attention with compressed KV cache).

All functions are pure; caches are dict pytrees suitable for scan-stacking.
The einsum reference path is what the dry-run lowers; on TPU,
``repro.kernels.flash_attention`` replaces the core when cfg.use_kernels.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Alloc, apply_rope, causal_mask_bias, rms_norm

# ---------------------------------------------------------------------------
# core attend (reference path; kernel hook)
# ---------------------------------------------------------------------------


ATTN_CHUNK = 2048  # q-block size for the chunked reference path


def attend(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Sk, KV, Dh)
    v: jax.Array,  # (B, Sk, KV, Dv)
    bias: jax.Array,  # (B or 1, Sq, Sk) additive f32
    *,
    use_kernel: bool = False,
    causal_hint: bool = False,
) -> jax.Array:
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    if use_kernel and Sq > 1:
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, bias=bias, causal=causal_hint)
    if Sq > ATTN_CHUNK and Sq % ATTN_CHUNK == 0:
        # q-chunked reference path: never materialises the (Sq, Sk) score
        # matrix for the whole sequence at once — the XLA-fallback analogue
        # of the flash kernel's VMEM streaming (EXPERIMENTS §Perf). The
        # Pallas kernel replaces this on real TPUs.
        nq = Sq // ATTN_CHUNK
        qc = q.reshape(B, nq, ATTN_CHUNK, H, Dh).transpose(1, 0, 2, 3, 4)
        bc = bias.reshape(bias.shape[0], nq, ATTN_CHUNK, -1).transpose(1, 0, 2, 3)

        def one(args):
            qq, bb = args
            return _attend_dense(qq, k, v, bb)

        out = jax.lax.map(one, (qc, bc))
        return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])
    return _attend_dense(q, k, v, bias)


def _attend_dense(q, k, v, bias):
    B, Sq, H, Dh = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = Dh**-0.5
    qg = q.reshape(B, Sq, KV, G, Dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale + bias[:, None, None, :, :]
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def _pad_param(a: Alloc, name, real_shape, padded_shape, axes, pad_axis: int, **kw):
    """A param stored at ``padded_shape`` whose pad region is exactly zero.

    In init mode the real-shaped tensor is initialized and zero-padded; in
    abstract/axes modes only the padded shape matters. Works under
    StackedAlloc (leading layers dim shifts the pad axis).
    """
    if a.mode != "init" or real_shape == padded_shape:
        return a.param(name, padded_shape, axes, **kw)
    real = a.param(name, real_shape, axes, **kw)
    offset = real.ndim - len(real_shape)  # stacked layers prefix
    pads = [(0, 0)] * real.ndim
    pads[pad_axis + offset] = (0, padded_shape[pad_axis] - real_shape[pad_axis])
    return jnp.pad(real, pads)


def gqa_params(cfg, a: Alloc) -> dict:
    d, Dh = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    Hp, KVp = cfg.heads_padded, cfg.kv_heads_padded
    p = {
        "wq": _pad_param(a, "wq", (d, H, Dh), (d, Hp, Dh), ("embed", "heads", None), 1),
        "wk": _pad_param(a, "wk", (d, KV, Dh), (d, KVp, Dh), ("embed", "kv", None), 1),
        "wv": _pad_param(a, "wv", (d, KV, Dh), (d, KVp, Dh), ("embed", "kv", None), 1),
        "wo": _pad_param(a, "wo", (H, Dh, d), (Hp, Dh, d), ("heads", None, "embed"), 0),
    }
    if cfg.qkv_bias:
        p["bq"] = a.param("bq", (Hp, Dh), ("heads", None), init="zeros")
        p["bk"] = a.param("bk", (KVp, Dh), ("kv", None), init="zeros")
        p["bv"] = a.param("bv", (KVp, Dh), ("kv", None), init="zeros")
    return p


def gqa_cache_shape(cfg, batch: int, seq: int, dtype, *, ring: bool = False) -> dict:
    KV, Dh = cfg.kv_heads_padded, cfg.head_dim
    c = {
        "k": jax.ShapeDtypeStruct((batch, seq, KV, Dh), dtype),
        "v": jax.ShapeDtypeStruct((batch, seq, KV, Dh), dtype),
    }
    if ring:  # sliding-window ring buffer: absolute position of each slot
        c["pos"] = jax.ShapeDtypeStruct((seq,), jnp.int32)
    return c


def gqa_attention(
    cfg,
    p: dict,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,) absolute positions of x
    *,
    window: Optional[int] = None,
    prefix_len: Optional[jax.Array] = None,
    bidirectional: bool = False,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,  # write offset into the cache
    return_cache: bool = False,
    emit_slices: bool = False,  # decode: return only the written K/V slice
    use_kernel: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence (prefill/train) or single-token (decode) attention.

    ``emit_slices`` avoids materialising a second full cache inside layer
    scans: the scan emits (B, 1, KV, Dh) slices and the stack merges them
    into the donated cache with ONE dynamic_update_slice per leaf outside
    the loop (EXPERIMENTS §Perf).

    decode: pass ``cache`` + ``cache_index``; x has S=1 and keys/values are
    written at ``cache_index`` then attended over the whole (masked) cache.
    A cache carrying ``pos`` is a sliding-window ring buffer: writes go to
    slot ``cache_index % W`` and masking uses the stored absolute positions.
    """
    B, S, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        Sk = cache["k"].shape[1]
        if "pos" in cache:  # ring buffer (S must be 1)
            slot = jnp.mod(cache_index, Sk)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), slot, axis=1
            )
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), slot, axis=1
            )
            pos_buf = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], positions.astype(jnp.int32), slot, axis=0
            )
            q_pos = positions[0]
            ok = (pos_buf >= 0) & (pos_buf <= q_pos)
            if window is not None:
                ok = ok & (pos_buf > q_pos - window)
            bias = jnp.where(ok, 0.0, -1e30).astype(jnp.float32)[None, None, :]
            out = attend(q, k_cache, v_cache, bias)
            if emit_slices:
                new_cache = {"k_new": k, "v_new": v}
            else:
                new_cache = {"k": k_cache, "v": v_cache, "pos": pos_buf}
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_index, axis=1
            )
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_index, axis=1
            )
            k_pos = jnp.arange(Sk)
            bias = causal_mask_bias(
                positions, k_pos, window=window, prefix_len=prefix_len,
                valid_len=cache_index + S,
            )[None]
            out = attend(q, k_cache, v_cache, bias)
            if emit_slices:
                new_cache = {"k_new": k, "v_new": v}
            else:
                new_cache = {"k": k_cache, "v": v_cache}
    else:
        if bidirectional:
            bias = jnp.zeros((1, S, S), jnp.float32)
        else:
            bias = causal_mask_bias(
                positions, positions, window=window, prefix_len=prefix_len
            )[None]
        causal_hint = prefix_len is None and window is None and not bidirectional
        out = attend(q, k, v, bias, use_kernel=use_kernel, causal_hint=causal_hint)
        if return_cache:
            if window is not None:  # return a ring cache of the last W keys,
                # laid out so position p lives at slot p % W (the decode
                # write invariant): roll the linear tail into ring order.
                W = min(window, S)
                shift = (S - W) % W
                new_cache = {
                    "k": jnp.roll(k[:, S - W :], shift, axis=1),
                    "v": jnp.roll(v[:, S - W :], shift, axis=1),
                    "pos": jnp.roll(positions[S - W :].astype(jnp.int32), shift),
                }
            else:
                new_cache = {"k": k, "v": v}
        else:
            new_cache = None

    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed latent KV cache
# ---------------------------------------------------------------------------


def mla_params(cfg, a: Alloc) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    nope, rope_d, v_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    lq, lkv = cfg.q_lora_rank, cfg.kv_lora_rank
    p = {}
    if lq:
        p["wq_a"] = a.param("wq_a", (d, lq), ("embed", "lora"))
        p["q_norm"] = a.param("q_norm", (lq,), ("lora",), init="zeros")
        p["wq_b"] = a.param("wq_b", (lq, H, nope + rope_d), ("lora", "heads", None))
    else:
        p["wq"] = a.param("wq", (d, H, nope + rope_d), ("embed", "heads", None))
    p["wkv_a"] = a.param("wkv_a", (d, lkv + rope_d), ("embed", "lora"))
    p["kv_norm"] = a.param("kv_norm", (lkv,), ("lora",), init="zeros")
    p["wk_b"] = a.param("wk_b", (lkv, H, nope), ("lora", "heads", None))
    p["wv_b"] = a.param("wv_b", (lkv, H, v_d), ("lora", "heads", None))
    p["wo"] = a.param("wo", (H, v_d, d), ("heads", None, "embed"))
    return p


def mla_cache_shape(cfg, batch: int, seq: int, dtype) -> dict:
    return {
        "ckv": jax.ShapeDtypeStruct((batch, seq, cfg.kv_lora_rank), dtype),
        "krope": jax.ShapeDtypeStruct((batch, seq, cfg.qk_rope_head_dim), dtype),
    }


def _mla_qkv(cfg, p, x, positions):
    B, S, _ = x.shape
    H = cfg.num_heads
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        cq = rms_norm(jnp.einsum("bsd,dl->bsl", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsl,lhk->bshk", cq, p["wq_b"])
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv_a = jnp.einsum("bsd,dl->bsl", x, p["wkv_a"])
    ckv = rms_norm(kv_a[..., : cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank :]  # (B, S, rope_d) shared across heads
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, ckv, k_rope


def mla_attention(
    cfg,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    return_cache: bool = False,
    emit_slices: bool = False,
    use_kernel: bool = False,
    **_unused,
) -> Tuple[jax.Array, Optional[dict]]:
    B, S, d = x.shape
    H = cfg.num_heads
    nope, rope_d, v_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = (nope + rope_d) ** -0.5
    q_nope, q_rope, ckv, k_rope = _mla_qkv(cfg, p, x, positions)

    if cache is not None:
        # decode: absorbed form — score/value directly against the compressed
        # cache; per-token cache traffic is kv_lora+rope (576) instead of
        # 2*H*Dh (32768 for 128 heads): the paper-faithful 93% KV reduction.
        ckv_c = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), cache_index, axis=1
        )
        krope_c = jax.lax.dynamic_update_slice_in_dim(
            cache["krope"], k_rope.astype(cache["krope"].dtype), cache_index, axis=1
        )
        Sk = ckv_c.shape[1]
        q_eff = jnp.einsum("bqhn,lhn->bqhl", q_nope, p["wk_b"])  # absorb W_UK
        scores = (
            jnp.einsum("bqhl,bsl->bhqs", q_eff, ckv_c, preferred_element_type=jnp.float32)
            + jnp.einsum("bqhr,bsr->bhqs", q_rope, krope_c, preferred_element_type=jnp.float32)
        ) * scale
        bias = causal_mask_bias(positions, jnp.arange(Sk), valid_len=cache_index + S)[None]
        scores = scores + bias[:, None, :, :]
        w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
        ctx = jnp.einsum("bhqs,bsl->bqhl", w, ckv_c)
        out = jnp.einsum("bqhl,lhv->bqhv", ctx, p["wv_b"])  # absorb W_UV
        if emit_slices:
            new_cache = {"ckv_new": ckv, "krope_new": k_rope}
        else:
            new_cache = {"ckv": ckv_c, "krope": krope_c}
    else:
        # prefill/train: expanded form (better matmul shapes at long Sq)
        k_nope = jnp.einsum("bsl,lhn->bshn", ckv, p["wk_b"])
        v = jnp.einsum("bsl,lhv->bshv", ckv, p["wv_b"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rope_d))], axis=-1
        )
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        bias = causal_mask_bias(positions, positions)[None]
        out = attend(q, k, v, bias, use_kernel=use_kernel, causal_hint=True)
        new_cache = {"ckv": ckv, "krope": k_rope} if return_cache else None

    y = jnp.einsum("bshv,hvd->bsd", out.astype(x.dtype), p["wo"])
    return y, new_cache
