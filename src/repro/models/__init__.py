from .lm import Model, build_model, stack_plan

__all__ = ["Model", "build_model", "stack_plan"]
