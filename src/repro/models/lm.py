"""Top-level models: decoder-only LMs (dense/moe/ssm/hybrid/vlm) and the
whisper-style encoder-decoder, with train loss, prefill and decode steps.

Layer stacks are grouped by a ``stack_plan``: runs of identical layers scan
over stacked params (O(1) compile in depth); heterogeneous layers (hymba's
global-attention layers, deepseek-v2's leading dense layer) are standalone
groups so their caches/params can differ in shape.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import StackedAlloc, block_apply, block_cache_shape, block_params, _norm, _norm_params
from .common import Alloc, DTYPES


# ---------------------------------------------------------------------------
# stack plan
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StackGroup:
    kind: str  # scan | single
    count: int
    name: str
    moe: bool
    is_global: bool  # full attention (ignores cfg.window)


def stack_plan(
    cfg, num_layers: Optional[int] = None, *, block_kind: str = "decoder"
) -> list[StackGroup]:
    L = num_layers if num_layers is not None else cfg.num_layers
    g_set = set(cfg.global_layers) if block_kind != "encoder" else set()
    first_dense = cfg.first_dense_layers if block_kind == "decoder" else L + 1

    def attrs(layer: int) -> tuple[bool, bool]:
        is_global = layer in g_set
        is_moe = cfg.is_moe and block_kind == "decoder" and layer >= first_dense
        return is_global, is_moe

    groups: list[StackGroup] = []
    i = 0
    while i < L:
        is_global, is_moe = attrs(i)
        if is_global:
            groups.append(StackGroup("single", 1, f"g{len(groups)}", is_moe, True))
            i += 1
        else:
            j = i
            while j < L and attrs(j) == (False, is_moe):
                j += 1
            groups.append(StackGroup("scan", j - i, f"s{len(groups)}", is_moe, False))
            i = j
    return groups


def stack_params(cfg, a, plan: list[StackGroup], *, block_kind: str = "decoder") -> dict:
    p = {}
    for grp in plan:
        with a.scope(grp.name):
            alloc = StackedAlloc(a, grp.count) if grp.kind == "scan" else a
            p[grp.name] = block_params(cfg, alloc, kind=block_kind, moe_layer=grp.moe)
    return p


def stack_cache_shapes(cfg, plan, batch: int, seq: int, dtype, *, xdec_enc_seq=None) -> dict:
    out = {}
    for grp in plan:
        one = block_cache_shape(
            cfg, batch, seq, dtype, is_global=grp.is_global, xdec_enc_seq=xdec_enc_seq
        )
        if grp.kind == "scan":
            one = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((grp.count, *s.shape), s.dtype), one
            )
        out[grp.name] = one
    return out


def _merge_decode_cache(cache_in, emitted, index):
    """Apply scan-emitted decode slices to the donated cache (one
    dynamic_update_slice per leaf, outside the layer loop)."""
    dus = jax.lax.dynamic_update_slice_in_dim

    def merge(node_in, node_em):
        if isinstance(node_em, dict):
            if "k_new" in node_em:
                Sk = node_in["k"].shape[-3]
                ring = "pos" in node_in
                slot = jnp.mod(index, Sk) if ring else index
                ax = node_in["k"].ndim - 3
                out = {
                    "k": dus(
                        node_in["k"], node_em["k_new"].astype(node_in["k"].dtype), slot, axis=ax
                    ),
                    "v": dus(
                        node_in["v"], node_em["v_new"].astype(node_in["v"].dtype), slot, axis=ax
                    ),
                }
                if ring:
                    pax = node_in["pos"].ndim - 1
                    upd = jnp.full((*node_in["pos"].shape[:-1], 1), index, node_in["pos"].dtype)
                    out["pos"] = dus(node_in["pos"], upd, slot, axis=pax)
                return out
            if "ckv_new" in node_em:
                ax = node_in["ckv"].ndim - 2
                return {
                    "ckv": dus(
                        node_in["ckv"],
                        node_em["ckv_new"].astype(node_in["ckv"].dtype),
                        index,
                        axis=ax,
                    ),
                    "krope": dus(
                        node_in["krope"],
                        node_em["krope_new"].astype(node_in["krope"].dtype),
                        index,
                        axis=ax,
                    ),
                }
            return {k: merge(node_in[k], node_em.get(k)) for k in node_in}
        if isinstance(node_in, dict) or node_em is None:
            # sentinel (possibly scan-stacked to (L,)) for static caches:
            # reuse the donated input unchanged (cross-attention encoder K/V)
            return node_in
        return node_em  # full replacement (SSM state / conv stream)

    return merge(cache_in, emitted)


def stack_apply(
    cfg,
    p: dict,
    plan: list[StackGroup],
    x: jax.Array,
    positions: jax.Array,
    *,
    mode: str = "forward",  # forward | prefill | decode
    caches: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    prefix_len: Optional[int] = None,
    bidirectional: bool = False,
    enc_out: Optional[jax.Array] = None,
    ctx=None,
    remat: bool = False,
    remat_policy: str = "full",
    unroll: bool = False,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (x, caches_out, aux_loss_sum)."""
    if remat_policy == "save_collectives":
        # don't recompute cross-device work in the backward pass: keep the
        # MoE all-to-all outputs and FSDP weight gathers (EXPERIMENTS §Perf)
        policy = jax.checkpoint_policies.save_only_these_names(
            "moe_fsdp_gather", "moe_a2a"
        )
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    total_aux = jnp.zeros((), jnp.float32)
    caches_out: dict = {}
    constrain = ctx.constrain_activations if ctx is not None else (lambda y: y)
    x = constrain(x)

    for grp in plan:
        gp = p[grp.name]
        window = None if grp.is_global else cfg.window

        def run_block(params, cache, xx):
            return block_apply(
                cfg,
                params,
                xx,
                positions,
                bidirectional=bidirectional,
                prefix_len=prefix_len,
                cache=cache,
                cache_index=cache_index,
                return_cache=(mode == "prefill"),
                emit_slices=(mode == "decode"),
                enc_out=enc_out,
                ctx=ctx,
                window=window,
            )

        if grp.kind == "single":
            fn = run_block
            if remat:
                fn = jax.checkpoint(fn, policy=policy, static_argnums=())
            cache_in = caches.get(grp.name) if caches else None
            x, nc, aux = fn(gp, cache_in, x)
            x = constrain(x)
            total_aux = total_aux + aux
            if nc is not None:
                if mode == "decode":
                    nc = _merge_decode_cache(cache_in, nc, cache_index)
                caches_out[grp.name] = nc
        else:
            cache_in = caches.get(grp.name) if caches else None

            def body(carry, xs):
                params, cache = xs
                xx, _ = carry
                xx, nc, aux = run_block(params, cache, xx)
                xx = constrain(xx)
                emit_cache = nc if nc is not None else 0
                return (xx, None), (emit_cache, aux)

            scan_body = body
            if remat:
                scan_body = jax.checkpoint(body, policy=policy)
            xs = (gp, cache_in) if cache_in is not None else (gp, None)
            if cache_in is None:
                # scan requires xs leaves with a leading dim: wrap params only
                (x, _), (ncs, auxs) = jax.lax.scan(
                    lambda c, params: scan_body(c, (params, None)), (x, None), gp,
                    unroll=unroll,
                )
            else:
                (x, _), (ncs, auxs) = jax.lax.scan(
                    scan_body, (x, None), (gp, cache_in), unroll=unroll
                )
            total_aux = total_aux + jnp.sum(auxs)
            if mode == "decode":
                caches_out[grp.name] = _merge_decode_cache(cache_in, ncs, cache_index)
            elif mode == "prefill":
                caches_out[grp.name] = ncs
    return x, (caches_out if caches_out else None), total_aux


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------


def sinusoidal_emb(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def cross_entropy(
    logits: jax.Array, targets: jax.Array, mask: Optional[jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Token-mean CE in f32. Returns (loss, token_count)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    ce = lse - ll
    if mask is None:
        return jnp.mean(ce), jnp.array(ce.size, jnp.float32)
    m = mask.astype(jnp.float32)
    n = jnp.maximum(jnp.sum(m), 1.0)
    return jnp.sum(ce * m) / n, n


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Family-dispatching functional model. All methods are pure.

    ``scan_probe``: override every multi-layer scan group's count (used by
    the dry-run to correct XLA's count-while-bodies-once cost analysis via
    two-point depth extrapolation — see launch/dryrun.py).
    """

    def __init__(self, cfg, scan_probe: Optional[int] = None, scan_unroll: bool = False):
        self.cfg = cfg
        self.scan_unroll = scan_unroll
        self.plan = stack_plan(cfg)
        self.enc_plan = (
            stack_plan(cfg, cfg.encoder_layers, block_kind="encoder") if cfg.is_encdec else None
        )
        if scan_probe is not None:
            probe = lambda plan: [
                StackGroup(g.kind, scan_probe if (g.kind == "scan" and g.count > 1) else g.count,
                           g.name, g.moe, g.is_global)
                for g in plan
            ]
            self.plan = probe(self.plan)
            if self.enc_plan is not None:
                self.enc_plan = probe(self.enc_plan)
        self.dtype = DTYPES[cfg.dtype]

    def scan_group_stats(self) -> dict:
        """(#multi-layer scan groups, total layers in them) across plans."""
        groups, layers = 0, 0
        for plan in [self.plan] + ([self.enc_plan] if self.enc_plan else []):
            for g in plan:
                if g.kind == "scan" and g.count > 1:
                    groups += 1
                    layers += g.count
        return {"groups": groups, "layers": layers}

    # -- params ---------------------------------------------------------------

    def _build(self, a: Alloc) -> dict:
        cfg = self.cfg
        d, V = cfg.d_model, cfg.vocab_size
        p: dict[str, Any] = {}
        p["embed"] = a.param("embed", (V, d), ("vocab", "embed"), init="embed", scale=d**-0.5)
        block_kind = "xdecoder" if cfg.is_encdec else "decoder"
        with a.scope("decoder"):
            p["layers"] = stack_params(cfg, a, self.plan, block_kind=block_kind)
        p["final_norm"] = _norm_params(cfg, a, "final_norm")
        if not cfg.tie_embeddings:
            p["lm_head"] = a.param("lm_head", (d, V), ("embed", "vocab"))
        if cfg.is_encdec:
            with a.scope("encoder"):
                p["enc_layers"] = stack_params(cfg, a, self.enc_plan, block_kind="encoder")
            p["enc_norm"] = _norm_params(cfg, a, "enc_norm")
        if cfg.family == "vlm":
            p["vision_proj"] = a.param("vision_proj", (cfg.vision_dim, d), (None, "embed"))
        return p

    def init(self, key: jax.Array) -> dict:
        return self._build(Alloc("init", key, dtype=self.dtype))

    def abstract_params(self) -> dict:
        return self._build(Alloc("abstract", dtype=self.dtype))

    def logical_axes(self) -> dict:
        return self._build(Alloc("axes", dtype=self.dtype))

    # -- embedding helpers -------------------------------------------------------

    def _embed_tokens(self, p, tokens):
        cfg = self.cfg
        x = jnp.take(p["embed"], tokens, axis=0).astype(self.dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(np.sqrt(cfg.d_model), self.dtype)
        return x

    def _input_states(self, p, batch) -> Tuple[jax.Array, Optional[int]]:
        """Token embedding (+ vlm patch prefix). Returns (x, prefix_len)."""
        cfg = self.cfg
        x = self._embed_tokens(p, batch["tokens"])
        prefix_len = None
        if cfg.family == "vlm" and "patches" in batch:
            pv = jnp.einsum("bnv,vd->bnd", batch["patches"].astype(self.dtype), p["vision_proj"])
            x = jnp.concatenate([pv, x], axis=1)
            prefix_len = cfg.num_image_tokens
        if not cfg.use_rope:
            S = x.shape[1]
            x = x + sinusoidal_emb(jnp.arange(S), cfg.d_model).astype(self.dtype)[None]
        return x, prefix_len

    def _encode(self, p, frames, ctx=None, remat=False):
        cfg = self.cfg
        x = frames.astype(self.dtype)
        S = x.shape[1]
        x = x + sinusoidal_emb(jnp.arange(S), cfg.d_model).astype(self.dtype)[None]
        x, _, _ = stack_apply(
            cfg, p["enc_layers"], self.enc_plan, x, jnp.arange(S),
            mode="forward", bidirectional=True, ctx=ctx, remat=remat,
            unroll=self.scan_unroll,
        )
        return _norm(cfg, p["enc_norm"], x)

    def _head(self, p, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("bsd,vd->bsv", x, p["embed"])
        return jnp.einsum("bsd,dv->bsv", x, p["lm_head"])

    # -- train -----------------------------------------------------------------

    def loss(self, p, batch, ctx=None) -> Tuple[jax.Array, dict]:
        cfg = self.cfg
        remat = cfg.remat != "none"
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(p, batch["frames"], ctx=ctx, remat=remat)
        x, prefix_len = self._input_states(p, batch)
        S = x.shape[1]
        x, _, aux = stack_apply(
            cfg, p["layers"], self.plan, x, jnp.arange(S),
            mode="forward", prefix_len=prefix_len, enc_out=enc_out, ctx=ctx, remat=remat,
            remat_policy=cfg.remat, unroll=self.scan_unroll,
        )
        x = _norm(cfg, p["final_norm"], x)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if prefix_len:  # vlm: loss only over the text suffix
            x = x[:, prefix_len:]
        if cfg.loss_chunk and S > cfg.loss_chunk:
            ce, n = self._chunked_ce(p, x, targets, mask, cfg.loss_chunk)
        else:
            logits = self._head(p, x)
            ce, n = cross_entropy(logits, targets, mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux, "tokens": n}

    def _chunked_ce(self, p, x, targets, mask, chunk: int):
        B, S, _ = x.shape
        nc = S // chunk
        xc = x[:, : nc * chunk].reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
        tc = targets[:, : nc * chunk].reshape(B, nc, chunk).transpose(1, 0, 2)
        mc = (
            mask[:, : nc * chunk].reshape(B, nc, chunk).transpose(1, 0, 2)
            if mask is not None
            else jnp.ones_like(tc, jnp.float32)
        )

        @jax.checkpoint
        def one(args):
            xx, tt, mm = args
            lf = self._head(p, xx).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(lf, axis=-1)
            ll = jnp.take_along_axis(lf, tt[..., None].astype(jnp.int32), axis=-1)[..., 0]
            return jnp.sum((lse - ll) * mm), jnp.sum(mm)

        sums, ns = jax.lax.map(one, (xc, tc, mc))
        n = jnp.maximum(jnp.sum(ns), 1.0)
        return jnp.sum(sums) / n, n

    # -- serving ------------------------------------------------------------------

    def prefill(self, p, batch, ctx=None, *, last_pos=None) -> Tuple[jax.Array, dict]:
        """Fill the KV cache for a prompt; logits for the next-token position.

        ``last_pos`` (scalar int, optional) selects which position's logits
        to return; default is the final one. The serving engine uses this to
        prefill right-padded prompt buckets: the pad tokens fill cache slots
        beyond ``last_pos`` but are causally invisible to it, and decode
        masks them via ``valid_len`` before they are ever attended.
        """
        cfg = self.cfg
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._encode(p, batch["frames"], ctx=ctx)
        x, prefix_len = self._input_states(p, batch)
        S = x.shape[1]
        x, caches, _ = stack_apply(
            cfg, p["layers"], self.plan, x, jnp.arange(S),
            mode="prefill", prefix_len=prefix_len, enc_out=enc_out, ctx=ctx,
            unroll=self.scan_unroll,
        )
        x = _norm(cfg, p["final_norm"], x)
        if last_pos is None:
            x_last = x[:, -1:]
        else:
            x_last = jax.lax.dynamic_slice_in_dim(x, last_pos, 1, axis=1)
        logits = self._head(p, x_last)
        return logits, caches

    def decode_step(self, p, tokens, caches, index, ctx=None) -> Tuple[jax.Array, dict]:
        """One new token given a cache. tokens: (B, 1); index: () int32."""
        cfg = self.cfg
        x = self._embed_tokens(p, tokens)
        if not cfg.use_rope:
            x = x + sinusoidal_emb(index[None], cfg.d_model).astype(self.dtype)[None]
        positions = index[None]
        x, caches_out, _ = stack_apply(
            cfg, p["layers"], self.plan, x, positions,
            mode="decode", caches=caches, cache_index=index, ctx=ctx,
            unroll=self.scan_unroll,
        )
        x = _norm(cfg, p["final_norm"], x)
        return self._head(p, x), caches_out

    # -- shapes for the dry-run -------------------------------------------------

    def cache_shapes(self, batch: int, seq: int) -> dict:
        cfg = self.cfg
        return stack_cache_shapes(
            cfg, self.plan, batch, seq, self.dtype,
            xdec_enc_seq=cfg.encoder_seq if cfg.is_encdec else None,
        )

    def input_specs(self, shape_name: str, spec: dict) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
        cfg = self.cfg
        S, B = spec["seq_len"], spec["global_batch"]
        kind = spec["kind"]
        i32 = jnp.int32
        tok = lambda s: jax.ShapeDtypeStruct((B, s), i32)
        out: dict[str, Any] = {}
        S_text = S - (cfg.num_image_tokens if cfg.family == "vlm" else 0)
        if kind == "train":
            out["tokens"] = tok(S_text)
            out["targets"] = tok(S_text)
            if cfg.family == "vlm":
                out["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_image_tokens, cfg.vision_dim), self.dtype
                )
            if cfg.is_encdec:
                out["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), self.dtype)
        elif kind == "prefill":
            out["tokens"] = tok(S_text)
            if cfg.family == "vlm":
                out["patches"] = jax.ShapeDtypeStruct(
                    (B, cfg.num_image_tokens, cfg.vision_dim), self.dtype
                )
            if cfg.is_encdec:
                out["frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), self.dtype)
        elif kind == "decode":
            out["tokens"] = tok(1)
            out["caches"] = self.cache_shapes(B, S)
            out["index"] = jax.ShapeDtypeStruct((), i32)
        else:
            raise ValueError(kind)
        return out


def extend_caches(caches: dict, extra: int, *, window: Optional[int] = None) -> dict:
    """Pad attention caches by ``extra`` positions (decode continuation).

    Thin wrapper kept for API stability: the per-family cache-layout walk
    now lives in ``repro.serve.kv`` (imported lazily — models must not
    depend on the serving layer at import time), which also powers the
    slot-based serving cache.

    ``window``: when given, sliding-window ring buffers are re-laid out to
    the full ``min(window, prompt + extra)`` modulus. Without it a ring
    prefilled from a prompt shorter than the window keeps its undersized
    modulus and evicts keys that are still inside the attention window —
    the historical behavior, preserved for callers that don't pass cfg.
    """
    from repro.serve.kv import pad_caches_to, ring_modulus

    ring_w = None
    if window is not None:
        w0 = ring_modulus(caches)
        if w0 is not None:
            ring_w = min(window, w0 + extra)
    return pad_caches_to(caches, extra, ring_w=ring_w)


def build_model(cfg, scan_probe: Optional[int] = None, scan_unroll: bool = False) -> Model:
    return Model(cfg, scan_probe=scan_probe, scan_unroll=scan_unroll)
