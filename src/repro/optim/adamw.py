"""AdamW from scratch (no optax): dtype policies, ZeRO-friendly state.

State layout (a pytree mirroring params):
  m, v        first/second moments, dtype = moments_dtype (bf16 for >=100B)
  master      fp32 master copy of the bf16 params (kept when params are
              low-precision; updates apply to the master, params re-cast)
  count       step counter

Sharding: the state trees reuse the param PartitionSpecs with an extra
data-axis shard on the largest replicated dim (parallel/sharding.zero_specs)
— ZeRO-1 semantics under GSPMD (XLA gathers as needed).

Weight decay is masked off 1-D params (norms, biases) by default.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moments_dtype: str = "float32"  # "bfloat16" for very large models
    keep_master: bool = True  # fp32 master copy when params are bf16


def _moments_dtype(cfg: AdamWConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.moments_dtype]


def decay_mask(params: Any) -> Any:
    """True where weight decay applies: every tensor with ndim >= 2."""
    return jax.tree.map(lambda p: p.ndim >= 2, params)


def adamw_init(cfg: AdamWConfig, params: Any) -> dict:
    mdt = _moments_dtype(cfg)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    state = {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    if cfg.keep_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_abstract_state(cfg: AdamWConfig, abstract_params: Any) -> dict:
    mdt = _moments_dtype(cfg)
    sds = lambda p, dt: jax.ShapeDtypeStruct(p.shape, dt)
    state = {
        "m": jax.tree.map(lambda p: sds(p, mdt), abstract_params),
        "v": jax.tree.map(lambda p: sds(p, mdt), abstract_params),
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if cfg.keep_master:
        state["master"] = jax.tree.map(lambda p: sds(p, jnp.float32), abstract_params)
    return state


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: AdamWConfig,
    lr: jax.Array,
    params: Any,
    grads: Any,
    state: dict,
) -> Tuple[Any, dict, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    mdt = _moments_dtype(cfg)
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.where(
        gnorm > cfg.grad_clip, cfg.grad_clip / jnp.maximum(gnorm, 1e-12), 1.0
    ) if cfg.grad_clip else jnp.ones(())
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mask = decay_mask(params)
    masters = state.get("master", params)

    def upd(p, g, m, v, mst, dk):
        gf = g.astype(jnp.float32) * scale
        m1 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        v1 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        step = (m1 / c1) / (jnp.sqrt(v1 / c2) + cfg.eps)
        base = mst.astype(jnp.float32)
        if dk and cfg.weight_decay:
            step = step + cfg.weight_decay * base
        new_master = base - lr * step
        return new_master.astype(p.dtype), m1.astype(mdt), v1.astype(mdt), new_master

    flat_p, treedef = jax.tree.flatten(params)
    flat = [
        upd(p, g, m, v, mst, dk)
        for p, g, m, v, mst, dk in zip(
            flat_p,
            jax.tree.leaves(grads),
            jax.tree.leaves(state["m"]),
            jax.tree.leaves(state["v"]),
            jax.tree.leaves(masters),
            jax.tree.leaves(mask),
        )
    ]
    new_params = jax.tree.unflatten(treedef, [f[0] for f in flat])
    new_state = {
        "m": jax.tree.unflatten(treedef, [f[1] for f in flat]),
        "v": jax.tree.unflatten(treedef, [f[2] for f in flat]),
        "count": count,
    }
    if cfg.keep_master:
        new_state["master"] = jax.tree.unflatten(treedef, [f[3] for f in flat])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics


def cosine_schedule(
    base_lr: float, warmup: int, total: int, min_frac: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step: jax.Array) -> jax.Array:
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.where(s < warmup, warm, cos)

    return lr
