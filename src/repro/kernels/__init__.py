"""Pallas TPU kernels for the compute hot spots (validated in interpret
mode on CPU; see DESIGN.md §6): flash attention + Mamba2 SSD scan."""
from . import ops, ref
from .flash_attention import flash_attention_bhsd
from .ssd import ssd_bshp

__all__ = ["ops", "ref", "flash_attention_bhsd", "ssd_bshp"]
