"""Pure-jnp oracles for the Pallas kernels (per-kernel allclose targets)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_reference  # noqa: F401  (the SSD oracle)


def attention_ref(
    q: jax.Array,  # (B, H, Sq, Dh)
    k: jax.Array,  # (B, KV, Sk, Dh)
    v: jax.Array,  # (B, KV, Sk, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_len: Optional[int] = None,
) -> jax.Array:
    """Dense f32 softmax attention with GQA head grouping."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, Dh).astype(jnp.float32) * Dh**-0.5
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, kf)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if k_len is not None:
        mask &= k_pos < k_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", w, vf)
    return o.reshape(B, H, Sq, Dh).astype(q.dtype)
