"""Flash attention Pallas TPU kernel (blocked online softmax, GQA-aware).

TPU adaptation notes (DESIGN.md §6): the GPU flash-attention algorithm is
re-tiled for the TPU memory hierarchy — Q tiles of (block_q, head_dim) live
in VMEM; K/V stream through VMEM one (block_k, head_dim) tile per grid step;
the running max/denominator/accumulator persist in VMEM scratch across the
K-block grid axis (TPU grids execute sequentially, so scratch is the carry).
All matmul shapes are (128 × head_dim)-aligned for the MXU; softmax
statistics are f32.

Grid: (batch × q_heads, num_q_blocks, num_k_blocks); K/V tiles are indexed
through the folded (batch, kv_head) coordinate so GQA groups share tiles.

Supports: causal masking, sliding window, valid-length (padded keys) and
full (bidirectional) attention. The pure-jnp oracle lives in ref.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,  # (1, block_q, Dh)
    k_ref,  # (1, block_k, Dh)
    v_ref,  # (1, block_k, Dh)
    o_ref,  # (1, block_q, Dh)
    m_scr,  # (block_q,) f32 running max
    l_scr,  # (block_q,) f32 running denominator
    acc_scr,  # (block_q, Dh) f32 accumulator
    *,
    scale: float,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    causal: bool,
    window: Optional[int],
    k_len: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def compute():
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (block_q, block_k)
        mask = k_pos < k_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * alpha[:, None] + pv
        m_scr[...] = m_new

    # tile-level skip: upper-triangular tiles under causality, tiles entirely
    # left of the window — the blocked analogue of flash attention's
    # "skip fully-masked blocks" (also what makes causal ~2x cheaper).
    relevant = None
    if causal:
        relevant = ki * block_k <= (qi + 1) * block_q - 1
    if window is not None:
        in_win = (ki + 1) * block_k - 1 > qi * block_q - window
        relevant = in_win if relevant is None else jnp.logical_and(relevant, in_win)
    if relevant is None:
        compute()
    else:
        pl.when(relevant)(compute)

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, Dh)
    k: jax.Array,  # (B, KV, Sk, Dh)
    v: jax.Array,  # (B, KV, Sk, Dh)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    k_len: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Core entry point; layout (batch, heads, seq, head_dim)."""
    B, H, Sq, Dh = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    scale = Dh**-0.5
    k_len = Sk if k_len is None else k_len

    qf = q.reshape(B * H, Sq, Dh)
    kf = k.reshape(B * KV, Sk, Dh)
    vf = v.reshape(B * KV, Sk, Dh)

    def q_index(bh, qi, ki):
        return (bh, qi, 0)

    def kv_index(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * KV + h // G, ki, 0)

    kernel = functools.partial(
        _kernel,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        causal=causal,
        window=window,
        k_len=k_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, Dh), q_index),
            pl.BlockSpec((1, block_k, Dh), kv_index),
            pl.BlockSpec((1, block_k, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dh), q_index),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, Dh)
